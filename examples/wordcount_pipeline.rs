//! Word-frequency map-reduce — the paper's Java use case (Figs 15/16).
//!
//! Generates a 21-file Zipf corpus plus `textignore.txt`, then runs the
//! full Fig 1 pipeline: a 3-task cyclic mapper array job and a dependent
//! reducer that merges the per-file counts — first SISO (Fig 15), then
//! MIMO (Fig 16), comparing launch counts and elapsed time.
//!
//! ```text
//! cargo run --release --example wordcount_pipeline
//! ```

use std::sync::Arc;

use llmapreduce::apps::wordcount::read_counts;
use llmapreduce::prelude::*;
use llmapreduce::workload::text::generate_corpus;

fn main() -> Result<()> {
    let root = std::env::temp_dir().join("llmr-example-wordcount");
    let _ = std::fs::remove_dir_all(&root);
    let input = root.join("input");
    let output = root.join("output");

    println!("generating 21 documents + textignore.txt...");
    let (_docs, ignore) = generate_corpus(&input, 21, 2_000, 500, 7)?;

    // Fig 15: --np 3 --distribution cyclic, with mapper AND reducer.
    let opts = Options::new(&input, &output, "wordcount")
        .np(3)
        .distribution(Distribution::Cyclic)
        .reducer("wordcount-reducer");
    // JVM-boot stand-in so repeated launches are visible in the timings.
    let mapper = WordCountApp::with_startup_spin(
        Some(ignore),
        std::time::Duration::from_millis(5),
    );
    let apps = Apps {
        mapper,
        reducer: Some(Arc::new(WordCountReducer)),
    };

    // One shared engine serves both runs (the Engine API is `&self`).
    let engine = LocalEngine::new(3);
    let siso = llmapreduce::mapreduce::run(&opts, &apps, &engine)?;
    println!(
        "SISO (Fig 15): {} launches over {} files, elapsed {}",
        siso.map.total_launches(),
        siso.map.total_items(),
        llmapreduce::util::fmt_duration(siso.elapsed()),
    );

    // Fig 16: the same pipeline with --apptype mimo.
    let mimo_opts = opts.clone().apptype(AppType::Mimo);
    let mimo = llmapreduce::mapreduce::run(&mimo_opts, &apps, &engine)?;
    println!(
        "MIMO (Fig 16): {} launches, elapsed {}  (speed-up {:.2}x)",
        mimo.map.total_launches(),
        llmapreduce::util::fmt_duration(mimo.elapsed()),
        siso.elapsed().as_secs_f64() / mimo.elapsed().as_secs_f64(),
    );

    // The reduce output (default name llmapreduce.out).
    let redout = mimo.redout_path.expect("reducer ran");
    let counts = read_counts(&redout)?;
    let mut top: Vec<_> = counts.iter().collect();
    top.sort_by(|a, b| b.1.cmp(a.1).then(a.0.cmp(b.0)));
    println!("top words in {}:", redout.display());
    for (w, c) in top.iter().take(5) {
        println!("  {w:>8}  {c}");
    }
    // Stopwords were ignored per textignore.txt.
    assert!(!counts.contains_key("the"), "ignore list applied");
    Ok(())
}
