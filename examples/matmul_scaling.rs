//! END-TO-END DRIVER — the paper's §IV scalability study on real data.
//!
//! This is the repo's full-stack validation (EXPERIMENTS.md records a
//! run): all three layers compose on a real workload.
//!
//! 1. generate a real dataset: MATLIST files of square matrices, the
//!    exact workload of §IV ("a MATLAB code that reads in a list of
//!    square matrices and multiplies the matrices");
//! 2. run REAL map-reduce jobs through the LLMapReduce pipeline on the
//!    local engine — every file goes PPM-style through the AOT-compiled
//!    `matmul_chain` XLA artifact (L2 JAX + L1 Pallas), with the
//!    Frobenius-sum reducer;
//! 3. measure BLOCK vs MIMO for the headline speed-up, calibrate the
//!    cost model from the same run, and produce the Fig 18/19 sweep on
//!    the calibrated simulator (this container has one core; the paper's
//!    cluster had hundreds — DESIGN.md §3).
//!
//! ```text
//! make artifacts && cargo run --release --example matmul_scaling [nfiles]
//! ```

use std::sync::Arc;
use std::time::Duration;

use llmapreduce::bench::experiments::{block_vs_mimo, fig18_19_sweep, PAPER_WIDTHS};
use llmapreduce::metrics::report::{overhead_series, speedup_series};
use llmapreduce::prelude::*;
use llmapreduce::scheduler::cost::Calibration;
use llmapreduce::workload::matrices::generate_matrix_lists;

fn main() -> Result<()> {
    let nfiles: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(48); // full paper size 512 also works; 48 keeps CI fast

    let root = std::env::temp_dir().join("llmr-example-matmul");
    let _ = std::fs::remove_dir_all(&root);
    let input = root.join("input");
    let output = root.join("output");

    let manifest = Manifest::discover()?;
    let mapper = MatmulChainApp::new(&manifest)?;
    let (l, n) = mapper.static_shape();
    println!("generating {nfiles} MATLIST files ({l} chained {n}x{n} matrices each)...");
    let paths = generate_matrix_lists(&input, nfiles, l, n, 1)?;

    // --- Step 1: the real BLOCK vs MIMO measurement (Table I style) ----
    let np = 4;
    let opts = Options::new(&input, &output, "matmulchain")
        .np(np)
        .reducer("frobsum-reducer");
    let apps = Apps {
        mapper: mapper.clone(),
        reducer: Some(Arc::new(FrobeniusSumReducer)),
    };
    let engine = LocalEngine::new(np);
    let result = block_vs_mimo("matmul pipeline", &opts, &apps, &engine)?;
    println!("\n{}", result.table());
    println!("headline: MIMO {:.2}x over BLOCK on real execution\n", result.speedup());

    // The reduce output proves the numerics flowed end to end.
    let redout = output.join("llmapreduce.out");
    let red_text = std::fs::read_to_string(&redout)
        .map_err(|e| llmapreduce::Error::io(redout.clone(), e))?;
    println!("reduce output: {}", red_text.trim());

    // --- Step 2: calibrate the simulator from this same app ------------
    let sample: Vec<_> = paths
        .iter()
        .take(4)
        .map(|p| (p.clone(), p.with_extension("calib.out")))
        .collect();
    let cal = Calibration::measure(mapper.as_ref(), &sample, 3)?;
    println!(
        "\ncalibration: startup={} per-file={} (ratio {:.1})",
        llmapreduce::util::fmt_duration(cal.hint.startup),
        llmapreduce::util::fmt_duration(cal.hint.per_item),
        cal.startup_ratio(),
    );
    println!(
        "predicted MIMO ceiling at {} files/task: {:.2}x",
        nfiles / np,
        cal.predicted_mimo_speedup(nfiles / np),
    );

    // --- Step 3: the paper's 512-file sweep on the calibrated DES ------
    let sweep =
        fig18_19_sweep(512, &PAPER_WIDTHS, cal.hint, Duration::from_millis(1))?;
    println!("\nFig 18 (overhead per concurrent task):\n{}", overhead_series(&sweep));
    println!("Fig 19 (speed-up vs DEFAULT@1):\n{}", speedup_series(&sweep));
    Ok(())
}
