//! Quickstart — the paper's Fig 7 one-liner as a library call.
//!
//! Generates six synthetic RGB images (the Table I toy size), then runs
//!
//! ```text
//! LLMapReduce --mapper imageconvert --input input --output output --np 2
//! ```
//!
//! on the local engine: two array tasks, each converting three images to
//! grayscale through the AOT-compiled XLA artifact (L2 JAX graph over the
//! L1 Pallas kernel).  Run with:
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use llmapreduce::prelude::*;
use llmapreduce::workload::images::generate_images;

fn main() -> Result<()> {
    let root = std::env::temp_dir().join("llmr-example-quickstart");
    let _ = std::fs::remove_dir_all(&root);
    let input = root.join("input");
    let output = root.join("output");

    // The artifacts fix the image shape (manifest-driven).
    let manifest = Manifest::discover()?;
    let mapper = ImageConvertApp::new(&manifest)?;
    let (h, w) = mapper.image_shape();

    println!("generating 6 synthetic {h}x{w} images...");
    generate_images(&input, 6, h, w, 42)?;

    // Fig 7: each input image becomes part of an array job; --np=2 gives
    // two array tasks of three images each.  The handle API: submit
    // returns before anything executes, wait() assembles the report —
    // submit several invocations first and they share the engine.
    let opts = Options::new(&input, &output, "imageconvert").np(2);
    let apps = Apps {
        mapper,
        reducer: None,
    };
    let engine = LocalEngine::new(2);
    let session = Session::new(&engine);
    let invocation = session.submit(&opts, &apps)?;
    let report = invocation.wait()?;

    println!(
        "converted {} images in {} ({} app launches, startup total {})",
        report.map.total_items(),
        llmapreduce::util::fmt_duration(report.elapsed()),
        report.map.total_launches(),
        llmapreduce::util::fmt_duration(report.map.total_startup()),
    );
    for entry in std::fs::read_dir(&output).expect("output dir") {
        println!("  {}", entry.expect("entry").path().display());
    }

    // Same job with --apptype=mimo: one launch per task instead of one
    // per image — the paper's headline feature.  One-shot blocking form
    // (a submit-and-wait wrapper over the same handles), same engine.
    let mimo_opts = opts.clone().apptype(AppType::Mimo).ext("gray");
    let mimo = llmapreduce::mapreduce::run(&mimo_opts, &apps, &engine)?;
    println!(
        "MIMO: {} launches (was {}), elapsed {} (was {})",
        mimo.map.total_launches(),
        report.map.total_launches(),
        llmapreduce::util::fmt_duration(mimo.elapsed()),
        llmapreduce::util::fmt_duration(report.elapsed()),
    );
    Ok(())
}
