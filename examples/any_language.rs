//! The paper's generality claim (§I): "LLMapReduce can launch any
//! program in any language ... without the need to modify the
//! application."
//!
//! This example writes mapper/reducer *shell scripts* at runtime —
//! stand-ins for the paper's MATLAB/Java wrappers (Figs 6, 13, 14) —
//! and runs them through the same pipeline as the built-in apps:
//!
//! * SISO: `mapper.sh <input> <output>` per file (Fig 6's contract);
//! * MIMO: `mapper_multi.sh <pairlist>` once per task (Fig 11's
//!   contract — the script loops over "input output" lines);
//! * reduce: `reducer.sh <map_output_dir> <redout>` (Fig 14).
//!
//! ```text
//! cargo run --release --example any_language
//! ```

use std::fs;
use std::os::unix::fs::PermissionsExt;
use std::path::Path;

use llmapreduce::apps::command::{CommandApp, CommandMimoApp, CommandReducer};
use llmapreduce::prelude::*;
use llmapreduce::workload::text::generate_corpus;

fn write_exec(path: &Path, body: &str) {
    fs::write(path, body).expect("write script");
    let mut perm = fs::metadata(path).expect("meta").permissions();
    perm.set_mode(0o755);
    fs::set_permissions(path, perm).expect("chmod");
}

fn main() -> Result<()> {
    let root = std::env::temp_dir().join("llmr-example-anylang");
    let _ = fs::remove_dir_all(&root);
    fs::create_dir_all(&root).expect("mkdir");
    let input = root.join("input");
    generate_corpus(&input, 8, 300, 50, 3)?;

    // The user's "application": POSIX shell, counting lines+words per
    // file — LLMapReduce neither knows nor cares what language this is.
    let mapper = root.join("mapper.sh");
    write_exec(
        &mapper,
        "#!/bin/sh\n# LLMapReduce API: $1 = input, $2 = output (Fig 6)\nwc -l -w < \"$1\" > \"$2\"\n",
    );
    let mapper_multi = root.join("mapper_multi.sh");
    write_exec(
        &mapper_multi,
        "#!/bin/sh\n# MIMO API: $1 = pair-list file (Fig 11)\nwhile read -r i o; do wc -l -w < \"$i\" > \"$o\"; done < \"$1\"\n",
    );
    let reducer = root.join("reducer.sh");
    write_exec(
        &reducer,
        "#!/bin/sh\n# Reduce API: $1 = map output dir, $2 = redout (Fig 14)\ncat \"$1\"/*.out | awk '{l+=$1; w+=$2} END {print l, w}' > \"$2\"\n",
    );

    // --- SISO run (Fig 15 shape) ----------------------------------------
    let out1 = root.join("output-siso");
    let opts = Options::new(&input, &out1, mapper.display().to_string())
        .np(2)
        .reducer(reducer.display().to_string());
    let apps = Apps {
        mapper: CommandApp::new(vec![mapper.display().to_string()])?,
        reducer: Some(CommandReducer::new(vec![
            reducer.display().to_string()
        ])?),
    };
    let eng = LocalEngine::new(2);
    let siso = llmapreduce::mapreduce::run(&opts, &apps, &eng)?;
    println!(
        "SISO shell pipeline: {} files, {} process spawns, elapsed {}",
        siso.map.total_items(),
        siso.map.total_launches(),
        llmapreduce::util::fmt_duration(siso.elapsed()),
    );

    // --- MIMO run (Fig 16 shape): one spawn per task --------------------
    let out2 = root.join("output-mimo");
    let opts2 = Options::new(&input, &out2, mapper_multi.display().to_string())
        .np(2)
        .apptype(AppType::Mimo)
        .reducer(reducer.display().to_string());
    let apps2 = Apps {
        mapper: CommandMimoApp::new(
            vec![mapper_multi.display().to_string()],
            root.join("pairlists"),
        )?,
        reducer: Some(CommandReducer::new(vec![
            reducer.display().to_string()
        ])?),
    };
    let mimo = llmapreduce::mapreduce::run(&opts2, &apps2, &eng)?;
    println!(
        "MIMO shell pipeline: {} files, {} launches, elapsed {}",
        mimo.map.total_items(),
        mimo.map.total_launches(),
        llmapreduce::util::fmt_duration(mimo.elapsed()),
    );

    // Both reduce outputs agree: same totals independent of protocol.
    let r1 = fs::read_to_string(siso.redout_path.as_ref().unwrap())
        .expect("siso redout");
    let r2 = fs::read_to_string(mimo.redout_path.as_ref().unwrap())
        .expect("mimo redout");
    assert_eq!(r1, r2, "launch protocol must not change results");
    println!("reduce (total lines, words): {}", r1.trim());
    Ok(())
}
