//! Multi-level map-reduce over a directory hierarchy (§II / §II-A).
//!
//! Demonstrates the paper's title feature two ways:
//!
//! 1. `--subdir=true`: one LLMapReduce invocation over a nested input
//!    tree, with the directory structure replicated to the output
//!    (Fig 3);
//! 2. nested LLMapReduce: one *inner* map-reduce per top-level
//!    subdirectory plus an outer reducer merging the per-directory
//!    results — the pattern §II recommends "for processing whole
//!    hierarchies of data" when directories get large.
//!
//! ```text
//! cargo run --release --example multilevel_hierarchy
//! ```

use std::sync::Arc;

use llmapreduce::apps::wordcount::read_counts;
use llmapreduce::mapreduce::multilevel::run_nested;
use llmapreduce::prelude::*;
use llmapreduce::workload::text::generate_corpus;

fn main() -> Result<()> {
    let root = std::env::temp_dir().join("llmr-example-multilevel");
    let _ = std::fs::remove_dir_all(&root);
    let input = root.join("input");

    // A hierarchy: three "sensor" directories of documents.
    println!("generating hierarchy (3 sensors x 8 docs)...");
    for (k, sensor) in ["sensor-a", "sensor-b", "sensor-c"].iter().enumerate()
    {
        generate_corpus(&input.join(sensor), 8, 500, 100, k as u64)?;
    }

    // --- Variant 1: --subdir=true, one flat invocation ------------------
    let out1 = root.join("output-subdir");
    let opts = Options::new(&input, &out1, "wordcount").subdir(true).np(4);
    let apps = Apps {
        mapper: WordCountApp::new(None),
        reducer: None,
    };
    let engine = LocalEngine::new(4);
    let report = llmapreduce::mapreduce::run(&opts, &apps, &engine)?;
    println!(
        "--subdir=true: {} files mapped, tree replicated:",
        report.map.total_items()
    );
    for sensor in ["sensor-a", "sensor-b", "sensor-c"] {
        let n = std::fs::read_dir(out1.join(sensor))
            .map(|d| d.count())
            .unwrap_or(0);
        println!("  {}/{sensor}: {n} outputs", out1.display());
        assert!(n > 0, "output tree must mirror the input tree");
    }

    // --- Variant 2: nested map-reduce with an outer reducer -------------
    // All three per-sensor pipelines are submitted through one Session
    // before any is waited, so they share the engine's slot cap
    // concurrently instead of running sensor-by-sensor.
    let out2 = root.join("output-nested");
    let opts = Options::new(&input, &out2, "wordcount")
        .np(2)
        .reducer("wordcount-reducer");
    let apps = Apps {
        mapper: WordCountApp::new(None),
        reducer: Some(Arc::new(WordCountReducer)),
    };
    let engine = LocalEngine::new(2);
    let nested = run_nested(
        &opts,
        &apps,
        Some(Arc::new(WordCountReducer)),
        &engine,
    )?;
    println!(
        "\nnested: {} inner jobs, {} files total, wall {} (slot-time {})",
        nested.inner.len(),
        nested.total_items(),
        llmapreduce::util::fmt_duration(nested.elapsed()),
        llmapreduce::util::fmt_duration(nested.summed_elapsed()),
    );
    for (name, inner) in &nested.inner {
        println!(
            "  {name}: {} files -> {}",
            inner.map.total_items(),
            inner.redout_path.as_ref().expect("inner redout").display()
        );
    }
    let final_out = nested.final_out.expect("outer reducer ran");
    let counts = read_counts(&final_out)?;
    println!(
        "final merge {}: {} distinct words",
        final_out.display(),
        counts.len()
    );
    Ok(())
}
