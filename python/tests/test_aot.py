"""AOT pipeline: HLO text emission, manifest integrity, executability.

The last test closes the loop inside python: compile the emitted HLO text
back through xla_client and execute it, proving the artifact is valid for
any PJRT consumer (the Rust runtime uses the same text).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_build_writes_all_entries(tmp_path):
    manifest = aot.build(str(tmp_path))
    names = set(model.registry().keys())
    assert set(manifest["entries"].keys()) == names
    for name, entry in manifest["entries"].items():
        path = tmp_path / entry["file"]
        assert path.exists(), name
        text = path.read_text()
        assert text.startswith("HloModule"), f"{name} not HLO text"
        # Interchange contract: text only, never a serialized proto.
        assert "\x00" not in text


def test_manifest_shapes_match_registry(tmp_path):
    manifest = aot.build(str(tmp_path))
    reg = model.registry()
    for name, entry in manifest["entries"].items():
        _, args = reg[name]
        assert len(entry["inputs"]) == len(args)
        for spec, arg in zip(entry["inputs"], args):
            assert spec["shape"] == list(arg.shape)
            assert spec["dtype"] == str(arg.dtype)


def test_manifest_json_roundtrip(tmp_path):
    aot.build(str(tmp_path))
    with open(tmp_path / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text"


def test_emitted_hlo_parses_back(tmp_path):
    """The emitted text must re-parse as a valid HLO module with a tuple
    root (return_tuple=True contract the Rust loader relies on).

    Execution of the text artifact is covered end-to-end on the Rust side
    (rust/tests/runtime_roundtrip.rs) — the jaxlib in this image no longer
    compiles raw HLO, only MLIR, so the python check stops at parsing.
    """
    from jax._src.lib import xla_client as xc

    for name, (fn, args) in model.registry().items():
        text = aot.lower_entry(fn, args)
        mod = xc._xla.hlo_module_from_text(text)
        assert mod is not None, name
        # Round-trip through the parser preserves the entry computation.
        reparsed = mod.as_serialized_hlo_module_proto()
        assert len(reparsed) > 0, name


def test_hlo_entry_signature_mentions_inputs():
    """Parameter count in the HLO text matches the registry arity."""
    text = aot.lower_entry(
        model.matmul_pair,
        [jax.ShapeDtypeStruct((8, 8), jnp.float32)] * 2,
    )
    assert "ENTRY" in text, "no ENTRY computation in HLO text"
    # entry_computation_layout on the HloModule line carries the signature.
    header = text.splitlines()[0]
    assert header.count("f32[8,8]") >= 2, header
