"""L2 graph-quality checks on the lowered HLO (the perf targets of the
L2 layer: no redundant recomputation, fusion-friendly structure).

These run on the *lowered* modules, so they hold for exactly what the
Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels.matmul import matmul

jax.config.update("jax_platform_name", "cpu")


def flops_of(fn, args):
    lowered = jax.jit(fn).lower(*args)
    analysis = lowered.compile().cost_analysis()
    if isinstance(analysis, list):  # older jax returns [dict]
        analysis = analysis[0]
    return float(analysis.get("flops", 0.0))


def test_matmul_chain_has_no_recompute():
    """Chain of L matrices must cost ~(L-1) matmuls, not more.

    If the unrolled chain accidentally recomputed intermediates the flop
    count would exceed the analytic bound."""
    l, n = model.CHAIN_LEN, model.MATRIX_N
    args = [jax.ShapeDtypeStruct((l, n, n), jnp.float32)]
    flops = flops_of(model.matmul_chain, args)
    analytic = (l - 1) * 2 * n**3
    assert flops <= analytic * 1.1, f"{flops} vs analytic {analytic}"
    assert flops >= analytic * 0.5, f"{flops} suspiciously low"


def test_image_pipeline_cost_is_linear_in_pixels():
    h, w = model.IMAGE_H, model.IMAGE_W
    args = [jax.ShapeDtypeStruct((h, w, 3), jnp.float32)]
    flops = flops_of(model.image_pipeline, args)
    # grayscale ~5 flops/px + 9-tap conv ~17 flops/px + clip: bounded by
    # ~40 flops/px with fusion slack.
    per_px = flops / (h * w)
    assert per_px < 60, f"{per_px} flops/pixel — recompute suspected"


def test_scan_variant_matches_unrolled_chain():
    """scan-vs-unroll (the L2 design choice DESIGN.md calls out): a
    lax.scan formulation computes the same product; we ship the unrolled
    form because at L=4 it lowers to a smaller module (no loop carry) —
    this test pins the numerical equivalence so the choice stays free."""

    def chain_scan(mats):
        def step(acc, m):
            return matmul(acc, m), None

        out, _ = jax.lax.scan(step, mats[0], mats[1:])
        return (out,)

    l, n = 4, 32
    mats = jnp.asarray(
        np.random.RandomState(0).randn(l, n, n) * 0.2, jnp.float32
    )
    (a,) = model.matmul_chain(mats)
    (b,) = chain_scan(mats)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_artifact_text_has_single_entry_and_no_custom_calls():
    """The CPU PJRT loader cannot execute Mosaic custom-calls; interpret
    mode must have lowered every Pallas kernel to plain HLO ops."""
    for name, (fn, args) in model.registry().items():
        text = aot.lower_entry(fn, args)
        assert text.count("ENTRY ") == 1, name
        assert "custom-call" not in text.lower(), (
            f"{name}: Mosaic custom-call leaked into the artifact"
        )


def test_pipeline_module_is_fused_not_stacked():
    """image_pipeline lowers both kernels into one module whose size is
    far below the sum of two standalone modules plus glue — i.e. XLA saw
    one graph, not an op-by-op interpreter trace."""
    h, w = model.IMAGE_H, model.IMAGE_W
    args = [jax.ShapeDtypeStruct((h, w, 3), jnp.float32)]
    text = aot.lower_entry(model.image_pipeline, args)
    # One module, no duplicated giant constants; rough structural bound.
    assert len(text) < 64_000, f"{len(text)} chars — unexpected blowup"
