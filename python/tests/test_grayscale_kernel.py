"""L1 grayscale Pallas kernel vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.grayscale import grayscale, WEIGHT_R, WEIGHT_G, WEIGHT_B
from compile.kernels.ref import grayscale_ref

jax.config.update("jax_platform_name", "cpu")


def rand_img(h, w, seed):
    return jnp.asarray(np.random.RandomState(seed).rand(h, w, 3), jnp.float32)


@pytest.mark.parametrize("h,w", [(1, 1), (8, 8), (256, 256), (100, 37), (257, 64)])
def test_grayscale_matches_ref(h, w):
    img = rand_img(h, w, h * 1000 + w)
    np.testing.assert_allclose(grayscale(img), grayscale_ref(img), rtol=1e-6, atol=1e-6)


def test_weights_sum_to_one():
    # BT.601 luma: white must stay white.
    assert abs((WEIGHT_R + WEIGHT_G + WEIGHT_B) - 1.0) < 1e-12


def test_grayscale_white_black():
    white = jnp.ones((16, 16, 3), jnp.float32)
    black = jnp.zeros((16, 16, 3), jnp.float32)
    np.testing.assert_allclose(grayscale(white), jnp.ones((16, 16)), atol=1e-6)
    np.testing.assert_allclose(grayscale(black), jnp.zeros((16, 16)), atol=1e-6)


def test_grayscale_pure_channels():
    h = w = 8
    for chan, weight in [(0, WEIGHT_R), (1, WEIGHT_G), (2, WEIGHT_B)]:
        img = np.zeros((h, w, 3), np.float32)
        img[:, :, chan] = 1.0
        out = grayscale(jnp.asarray(img))
        np.testing.assert_allclose(out, np.full((h, w), weight), rtol=1e-6)


def test_grayscale_rejects_non_rgb():
    with pytest.raises(AssertionError):
        grayscale(jnp.zeros((4, 4, 4), jnp.float32))


@settings(max_examples=25, deadline=None)
@given(h=st.integers(1, 300), w=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_grayscale_arbitrary_shapes(h, w, seed):
    img = rand_img(h, w, seed)
    np.testing.assert_allclose(grayscale(img), grayscale_ref(img), rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(bh=st.sampled_from([1, 2, 32, 128, 256]), seed=st.integers(0, 1000))
def test_grayscale_block_invariance(bh, seed):
    img = rand_img(128, 32, seed)
    np.testing.assert_allclose(
        grayscale(img, bh=bh), grayscale(img, bh=128), rtol=1e-6, atol=1e-6
    )
