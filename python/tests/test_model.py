"""L2 model graphs: shapes, semantics vs oracles, registry hygiene."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.ref import grayscale_ref, matmul_chain_ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape), jnp.float32)


def test_image_convert_matches_ref():
    img = jnp.asarray(
        np.random.RandomState(0).rand(model.IMAGE_H, model.IMAGE_W, 3), jnp.float32
    )
    (out,) = model.image_convert(img)
    np.testing.assert_allclose(out, np.clip(grayscale_ref(img), 0, 1), rtol=1e-6)
    assert out.shape == (model.IMAGE_H, model.IMAGE_W)


def test_image_convert_clips():
    img = jnp.full((8, 8, 3), 2.0, jnp.float32)  # out-of-range input
    (out,) = model.image_convert(img)
    assert float(jnp.max(out)) <= 1.0


def test_matmul_chain_matches_ref():
    mats = rand((model.CHAIN_LEN, 32, 32), 1) * 0.1
    (out,) = model.matmul_chain(mats)
    np.testing.assert_allclose(out, matmul_chain_ref(mats), rtol=1e-4, atol=1e-4)


def test_matmul_pair():
    a, b = rand((16, 16), 2), rand((16, 16), 3)
    (out,) = model.matmul_pair(a, b)
    np.testing.assert_allclose(out, a @ b, rtol=1e-4, atol=1e-4)


def test_frobenius_reduce():
    mats = rand((4, 8, 8), 5)
    (out,) = model.frobenius_reduce(mats)
    expect = sum(np.linalg.norm(np.asarray(mats[i]), "fro") for i in range(4))
    np.testing.assert_allclose(out, expect, rtol=1e-5)


def test_registry_entries_lower():
    """Every registry entry must trace at its example shapes."""
    for name, (fn, args) in model.registry().items():
        lowered = jax.jit(fn).lower(*args)
        assert lowered is not None, name


def test_registry_names_are_artifact_safe():
    for name in model.registry():
        assert name.replace("_", "").isalnum(), name
