"""L1 matmul Pallas kernel vs pure-jnp oracle — the core correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import matmul, _pick_block
from compile.kernels.ref import matmul_ref

jax.config.update("jax_platform_name", "cpu")

RTOL = 1e-5
ATOL = 1e-5


def rand(shape, seed, dtype=np.float32):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape), dtype)


# ---------------------------------------------------------------------------
# Directed cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "m,k,n",
    [
        (8, 8, 8),
        (128, 128, 128),
        (256, 128, 64),
        (64, 96, 32),
        (1, 128, 1),
        (3, 5, 7),       # primes: block shrink path
        (130, 2, 130),   # tiny contraction dim
    ],
)
def test_matmul_matches_ref(m, k, n):
    a, b = rand((m, k), m * 1000 + k), rand((k, n), k * 1000 + n)
    np.testing.assert_allclose(matmul(a, b), matmul_ref(a, b), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 64), (128, 128, 128), (64, 8, 16)])
def test_matmul_block_shapes_equivalent(bm, bn, bk):
    """All tilings compute the same product."""
    a, b = rand((128, 128), 7), rand((128, 128), 8)
    np.testing.assert_allclose(
        matmul(a, b, bm=bm, bn=bn, bk=bk), matmul_ref(a, b), rtol=RTOL, atol=ATOL
    )


def test_matmul_identity():
    a = rand((64, 64), 3)
    eye = jnp.eye(64, dtype=jnp.float32)
    np.testing.assert_allclose(matmul(a, eye), a, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(matmul(eye, a), a, rtol=RTOL, atol=ATOL)


def test_matmul_zeros():
    a = rand((32, 48), 4)
    z = jnp.zeros((48, 16), jnp.float32)
    assert not np.any(np.asarray(matmul(a, z)))


def test_matmul_shape_mismatch_raises():
    with pytest.raises(AssertionError):
        matmul(rand((4, 5), 0), rand((6, 4), 1))


def test_pick_block_divides():
    for dim in [1, 2, 3, 7, 64, 100, 128, 129, 1000]:
        for want in [1, 8, 128, 4096]:
            b = _pick_block(dim, want)
            assert 1 <= b <= min(dim, want)
            assert dim % b == 0


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes and dtypes
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_arbitrary_shapes(m, k, n, seed):
    a, b = rand((m, k), seed), rand((k, n), seed + 1)
    np.testing.assert_allclose(matmul(a, b), matmul_ref(a, b), rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(
    dtype=st.sampled_from([np.float32, np.float64]),
    n=st.sampled_from([8, 32, 64]),
)
def test_matmul_dtypes(dtype, n):
    a, b = rand((n, n), 11, dtype), rand((n, n), 12, dtype)
    out = matmul(a, b)
    assert out.dtype == a.dtype
    np.testing.assert_allclose(out, matmul_ref(a, b), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32, 128]),
    bn=st.sampled_from([8, 16, 32, 128]),
    bk=st.sampled_from([8, 16, 32, 128]),
    seed=st.integers(0, 1000),
)
def test_matmul_tiling_invariance(bm, bn, bk, seed):
    """The product is invariant to the tiling choice (accumulation-order
    drift is inside the allclose tolerance)."""
    a, b = rand((64, 64), seed), rand((64, 64), seed + 1)
    np.testing.assert_allclose(
        matmul(a, b, bm=bm, bn=bn, bk=bk),
        matmul(a, b, bm=64, bn=64, bk=64),
        rtol=RTOL,
        atol=ATOL,
    )
