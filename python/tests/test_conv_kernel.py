"""L1 conv3x3 Pallas kernel vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.conv3x3 import conv3x3, BOX_BLUR, SHARPEN, SOBEL_X
from compile.kernels.ref import conv3x3_ref, image_pipeline_ref
from compile import model

jax.config.update("jax_platform_name", "cpu")


def rand(h, w, seed):
    return jnp.asarray(np.random.RandomState(seed).rand(h, w), jnp.float32)


@pytest.mark.parametrize("kernel", [BOX_BLUR, SHARPEN, SOBEL_X])
@pytest.mark.parametrize("h,w", [(1, 1), (8, 8), (33, 17), (64, 128)])
def test_conv_matches_ref(kernel, h, w):
    x = rand(h, w, h * 100 + w)
    np.testing.assert_allclose(
        conv3x3(x, kernel3x3=kernel),
        conv3x3_ref(x, kernel),
        rtol=1e-5,
        atol=1e-6,
    )


def test_identity_kernel_is_noop():
    ident = ((0.0, 0.0, 0.0), (0.0, 1.0, 0.0), (0.0, 0.0, 0.0))
    x = rand(16, 16, 3)
    np.testing.assert_allclose(conv3x3(x, kernel3x3=ident), x, atol=1e-7)


def test_box_blur_preserves_mean_inside():
    # Away from borders, a box blur of a constant plane is constant.
    x = jnp.full((16, 16), 0.6, jnp.float32)
    out = np.asarray(conv3x3(x, kernel3x3=BOX_BLUR))
    np.testing.assert_allclose(out[1:-1, 1:-1], 0.6, rtol=1e-6)
    # Borders see zero padding: strictly smaller.
    assert out[0, 0] < 0.6


def test_sobel_zero_on_constant():
    x = jnp.full((12, 12), 0.3, jnp.float32)
    out = np.asarray(conv3x3(x, kernel3x3=SOBEL_X))
    np.testing.assert_allclose(out[1:-1, 1:-1], 0.0, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(h=st.integers(1, 80), w=st.integers(1, 80), seed=st.integers(0, 2**31 - 1))
def test_conv_arbitrary_shapes(h, w, seed):
    x = rand(h, w, seed)
    np.testing.assert_allclose(
        conv3x3(x, kernel3x3=BOX_BLUR),
        conv3x3_ref(x, BOX_BLUR),
        rtol=1e-5,
        atol=1e-6,
    )


@settings(max_examples=10, deadline=None)
@given(
    k=st.lists(
        st.floats(-2, 2, allow_nan=False, width=32), min_size=9, max_size=9
    ),
    seed=st.integers(0, 1000),
)
def test_conv_arbitrary_stencils(k, seed):
    kernel = tuple(tuple(k[r * 3 + c] for c in range(3)) for r in range(3))
    x = rand(24, 24, seed)
    np.testing.assert_allclose(
        conv3x3(x, kernel3x3=kernel),
        conv3x3_ref(x, kernel),
        rtol=1e-4,
        atol=1e-5,
    )


def test_image_pipeline_model_matches_ref():
    rgb = jnp.asarray(
        np.random.RandomState(1).rand(model.IMAGE_H, model.IMAGE_W, 3),
        jnp.float32,
    )
    (out,) = model.image_pipeline(rgb)
    np.testing.assert_allclose(
        out, image_pipeline_ref(rgb, BOX_BLUR), rtol=1e-5, atol=1e-6
    )
    assert out.shape == (model.IMAGE_H, model.IMAGE_W)
