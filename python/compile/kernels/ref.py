"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the ground truth every kernel is checked against (pytest +
hypothesis in python/tests/).  They must stay dead simple — no pallas, no
tiling, just the textbook math.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Keep the weights in one place: ref and kernel must agree bit-for-bit on
# the constants (the tolerance in tests covers accumulation-order drift).
from .grayscale import WEIGHT_B, WEIGHT_G, WEIGHT_R


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """(M, K) @ (K, N) with f32 accumulation, like the kernel."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def matmul_chain_ref(mats: jax.Array) -> jax.Array:
    """Left-to-right product of a (L, N, N) stack of square matrices."""
    out = mats[0]
    for i in range(1, mats.shape[0]):
        out = matmul_ref(out, mats[i])
    return out


def grayscale_ref(rgb: jax.Array) -> jax.Array:
    """(H, W, 3) -> (H, W) ITU-R BT.601 luma."""
    return (
        WEIGHT_R * rgb[:, :, 0]
        + WEIGHT_G * rgb[:, :, 1]
        + WEIGHT_B * rgb[:, :, 2]
    )


def conv3x3_ref(x: jax.Array, kernel3x3) -> jax.Array:
    """'same' 3x3 convolution with zero padding — nine shifted MACs."""
    h, w = x.shape
    xp = jnp.pad(x, ((1, 1), (1, 1)))
    acc = jnp.zeros_like(x)
    for dy in range(3):
        for dx in range(3):
            acc = acc + float(kernel3x3[dy][dx]) * xp[dy:dy + h, dx:dx + w]
    return acc


def image_pipeline_ref(rgb: jax.Array, kernel3x3) -> jax.Array:
    """Grayscale -> 3x3 stencil -> clip, the Table II-style pipeline."""
    return jnp.clip(conv3x3_ref(grayscale_ref(rgb), kernel3x3), 0.0, 1.0)
