"""L1 Pallas kernel: 3x3 single-channel convolution (same padding).

The Table II workload is "a real user MATLAB application [that] does
image processing" — more than a grayscale map.  This kernel is the second
stage of the richer `image_pipeline` artifact: a 3x3 stencil (blur,
sharpen, edge ...) applied to the grayscale plane.

TPU shaping: the stencil is computed as nine shifted multiply-accumulates
over a zero-padded plane — pure elementwise VPU work, no gathers.  The
whole padded plane lives in one VMEM block: at the pipeline's static
shape (256x256 f32 ≈ 258 KiB padded) that is ~1.6% of a TPU core's
16 MiB VMEM, so halo tiling is unnecessary; for larger planes the block
would split over rows with a one-row halo (overlapping blocks are not
expressible in Pallas blocked indexing, so that variant would pass the
halo explicitly as extra operands).  DESIGN.md §4 records the budget.

interpret=True as everywhere: CPU PJRT cannot run Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, o_ref, *, taps, h, w):
    """(H+2, W+2) padded plane in VMEM -> (H, W) output plane.

    taps: ((dy, dx, weight), ...) static 3x3 stencil description; the
    loop unrolls at trace time into nine shifted fused multiply-adds.
    """
    x = x_ref[...]
    acc = jnp.zeros((h, w), x.dtype)
    for dy, dx, weight in taps:
        if weight == 0.0:
            continue
        acc = acc + weight * jax.lax.dynamic_slice(x, (dy, dx), (h, w))
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("kernel3x3",))
def conv3x3(x: jax.Array, *, kernel3x3: tuple) -> jax.Array:
    """'same' 3x3 convolution of an (H, W) plane with zero padding.

    kernel3x3: a 3x3 tuple-of-tuples of python floats (static — baked
    into the stencil at compile time, like the paper's fixed MATLAB
    filters).
    """
    h, w = x.shape
    taps = tuple(
        (dy, dx, float(kernel3x3[dy][dx]))
        for dy in range(3)
        for dx in range(3)
    )
    xp = jnp.pad(x, ((1, 1), (1, 1)))
    return pl.pallas_call(
        functools.partial(_conv_kernel, taps=taps, h=h, w=w),
        grid=(1,),
        in_specs=[pl.BlockSpec((h + 2, w + 2), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((h, w), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), x.dtype),
        interpret=True,
    )(xp)


# Common stencils (MATLAB fspecial analogues).
BOX_BLUR = tuple(tuple(1.0 / 9.0 for _ in range(3)) for _ in range(3))
SHARPEN = ((0.0, -1.0, 0.0), (-1.0, 5.0, -1.0), (0.0, -1.0, 0.0))
SOBEL_X = ((-1.0, 0.0, 1.0), (-2.0, 0.0, 2.0), (-1.0, 0.0, 1.0))
