"""L1 Pallas kernel: RGB -> grayscale conversion.

This is the compute of the paper's Section III-A use case: the MATLAB
``imageConvert()`` function (``imread`` -> ``rgb2gray`` -> write).  MATLAB's
``rgb2gray`` uses the ITU-R BT.601 luma coefficients, which we reproduce
exactly:

    Y = 0.298936021293775 * R + 0.587043074451121 * G + 0.114020904255103 * B

(the coefficients MATLAB documents for rgb2gray).

TPU shaping: the image is streamed through VMEM in row blocks.  Each grid
step holds a ``(bh, W)`` tile per channel; the weighted sum is a pure VPU
(vector unit) elementwise op over the lane dimension W.  Channels arrive as
three separate refs (planar layout) so each tile is a clean 2-D VMEM block
instead of a strided 3-D slice.

interpret=True: see matmul.py — CPU PJRT cannot run Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MATLAB rgb2gray / ITU-R BT.601 luma weights.
WEIGHT_R = 0.298936021293775
WEIGHT_G = 0.587043074451121
WEIGHT_B = 0.114020904255103


def _grayscale_kernel(r_ref, g_ref, b_ref, o_ref):
    """One (bh, W) row block: weighted channel sum on the VPU."""
    o_ref[...] = (
        WEIGHT_R * r_ref[...]
        + WEIGHT_G * g_ref[...]
        + WEIGHT_B * b_ref[...]
    )


def _pick_block(dim: int, want: int) -> int:
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bh",))
def grayscale(rgb: jax.Array, *, bh: int = 128) -> jax.Array:
    """Convert an (H, W, 3) f32 image in [0, 1] to an (H, W) gray image.

    The HWC input is split into planar channels outside the kernel (a
    layout change XLA fuses away) so each Pallas block is a contiguous
    (bh, W) VMEM tile.
    """
    h, w, c = rgb.shape
    assert c == 3, f"expected RGB (H, W, 3), got {rgb.shape}"
    bh = _pick_block(h, bh)

    r = rgb[:, :, 0]
    g = rgb[:, :, 1]
    b = rgb[:, :, 2]
    spec = pl.BlockSpec((bh, w), lambda i: (i, 0))
    return pl.pallas_call(
        _grayscale_kernel,
        grid=(h // bh,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((h, w), rgb.dtype),
        interpret=True,
    )(r, g, b)
