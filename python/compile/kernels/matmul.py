"""L1 Pallas kernel: tiled matrix multiply.

This is the compute hot-spot of the paper's scalability study (Section IV):
"a MATLAB code that reads in a list of square matrices and multiplies the
matrices".  Each map task chain-multiplies the matrices in its assigned
list file; the inner product is this kernel.

TPU shaping (see DESIGN.md section 4, Hardware adaptation):
  * grid = (M/bm, N/bn, K/bk) with K innermost so the VMEM accumulator
    scratch stays resident across the K loop (double-buffered HBM->VMEM
    streaming of the A and B tiles is expressed by the BlockSpecs).
  * default tiles 128x128x128 match the MXU systolic array;
    f32 accumulate regardless of input dtype.
  * interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
    custom-calls, so the kernel is lowered through the interpreter to plain
    HLO.  Real-TPU performance is estimated from the VMEM footprint and
    MXU utilization in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output tile; accumulates over the K grid dimension.

    a_ref: (bm, bk) VMEM tile of A
    b_ref: (bk, bn) VMEM tile of B
    o_ref: (bm, bn) output tile (written on the last K step)
    acc_ref: (bm, bn) f32 VMEM scratch accumulator
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU-shaped contraction: always accumulate in f32.
    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pick_block(dim: int, want: int) -> int:
    """Largest block <= want that divides dim (dims are padded upstream)."""
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128) -> jax.Array:
    """Tiled Pallas matmul: (M, K) @ (K, N) -> (M, N).

    Shapes need not be multiples of the tile size; blocks are shrunk to the
    largest divisor (callers in model.py use MXU-friendly sizes anyway).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch: {a.shape} @ {b.shape}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(a, b)
