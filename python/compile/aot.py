"""AOT compiler: lower every L2 model function to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).  The HLO text
parser on the Rust side reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Run once at build time (``make artifacts``):

    cd python && python -m compile.aot --out ../artifacts

Outputs, per registry entry:
    artifacts/<name>.hlo.txt      — the HLO module
    artifacts/manifest.json       — shapes/dtypes for Rust-side validation
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import registry


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (with return_tuple=True).

    return_tuple=True means every artifact's output is a tuple; the Rust
    loader unwraps with to_tuple1().
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "entries": {}}
    for name, (fn, args) in sorted(registry().items()):
        text = lower_entry(fn, args)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [
                {"shape": list(a.shape), "dtype": str(a.dtype)} for a in args
            ],
            "outputs": "tuple",  # return_tuple=True; unwrap with to_tuple1
        }
        print(f"  {name}: {len(text)} chars -> {path}")
    manifest_path = os.path.join(out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"  manifest -> {manifest_path}")
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts",
                        help="artifact output directory")
    args = parser.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
