"""L2: the map applications' compute graphs, written in JAX.

These are the JAX analogues of the paper's map applications:

  * ``image_convert``  — Section III-A: MATLAB ``imageConvert()``
    (RGB image -> grayscale image), built on the L1 grayscale kernel.
  * ``matmul_chain``   — Section IV scalability study: "a MATLAB code that
    reads in a list of square matrices and multiplies the matrices",
    built on the L1 tiled matmul kernel.
  * ``matmul_pair``    — single product, used by tests and as a smaller
    artifact for runtime unit tests.

Each function is pure and shape-static so it can be AOT-lowered once by
``aot.py`` into HLO text that the Rust runtime loads at startup.  Python is
never on the request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.conv3x3 import conv3x3, BOX_BLUR
from .kernels.grayscale import grayscale
from .kernels.matmul import matmul

# Canonical artifact shapes.  The Rust side (runtime/artifacts.rs) and the
# workload generators (workload/images.rs, workload/matrices.rs) are pinned
# to these; keep in sync with the manifest aot.py emits.
IMAGE_H = 256
IMAGE_W = 256
CHAIN_LEN = 4
MATRIX_N = 128


def image_convert(rgb: jax.Array) -> tuple[jax.Array]:
    """(H, W, 3) f32 in [0,1] -> (H, W) f32 grayscale (BT.601 luma).

    The L1 kernel does the weighted reduction; clamping keeps the output a
    valid image even for slightly out-of-range inputs (PPM decode jitter).
    """
    gray = grayscale(rgb)
    return (jnp.clip(gray, 0.0, 1.0),)


def image_pipeline(rgb: jax.Array) -> tuple[jax.Array]:
    """(H, W, 3) -> (H, W): grayscale + 3x3 box blur + clip.

    The Table II regime: "a real user MATLAB application [that] does
    image processing" — a multi-stage per-file pipeline, composing BOTH
    L1 kernels inside one lowered module so XLA fuses the plumbing.
    """
    gray = grayscale(rgb)
    blurred = conv3x3(gray, kernel3x3=BOX_BLUR)
    return (jnp.clip(blurred, 0.0, 1.0),)


def matmul_pair(a: jax.Array, b: jax.Array) -> tuple[jax.Array]:
    """(N, N) @ (N, N) via the tiled Pallas kernel."""
    return (matmul(a, b),)


def matmul_chain(mats: jax.Array) -> tuple[jax.Array]:
    """(L, N, N) -> (N, N): left-to-right chain product.

    L is static and small, so the chain is unrolled; every product goes
    through the L1 kernel and XLA fuses the inter-product plumbing.
    """
    out = mats[0]
    for i in range(1, mats.shape[0]):
        out = matmul(out, mats[i])
    return (out,)


def frobenius_reduce(mats: jax.Array) -> tuple[jax.Array]:
    """(B, N, N) -> scalar: sum of Frobenius norms.

    The reduce-side compute for the matmul pipeline example: the reducer
    aggregates per-file chain products into one scalar summary.
    """
    sq = jnp.sum(mats * mats, axis=(1, 2))
    return (jnp.sum(jnp.sqrt(sq)),)


# ---------------------------------------------------------------------------
# Artifact registry: name -> (function, example argument shapes)
# aot.py iterates this to produce artifacts/<name>.hlo.txt and the manifest.
# ---------------------------------------------------------------------------

def registry() -> dict:
    f32 = jnp.float32
    return {
        "image_convert": (
            image_convert,
            [jax.ShapeDtypeStruct((IMAGE_H, IMAGE_W, 3), f32)],
        ),
        "image_pipeline": (
            image_pipeline,
            [jax.ShapeDtypeStruct((IMAGE_H, IMAGE_W, 3), f32)],
        ),
        "matmul_pair": (
            matmul_pair,
            [
                jax.ShapeDtypeStruct((MATRIX_N, MATRIX_N), f32),
                jax.ShapeDtypeStruct((MATRIX_N, MATRIX_N), f32),
            ],
        ),
        "matmul_chain": (
            matmul_chain,
            [jax.ShapeDtypeStruct((CHAIN_LEN, MATRIX_N, MATRIX_N), f32)],
        ),
        "frobenius_reduce": (
            frobenius_reduce,
            [jax.ShapeDtypeStruct((8, MATRIX_N, MATRIX_N), f32)],
        ),
    }
