//! Minimal micro-benchmark harness (criterion substitute).
//!
//! Measures a closure over `warmup + iters` runs and reports robust
//! statistics.  Deliberately simple: monotonic clock, no outlier
//! rejection beyond the median/p95 split, deterministic iteration counts
//! so bench output is reproducible run to run on an idle machine.

use std::time::{Duration, Instant};

/// Statistics over one benchmarked closure.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
    pub max: Duration,
}

impl BenchStats {
    /// One-line summary for bench logs.
    pub fn summary(&self) -> String {
        format!(
            "{:<40} {:>10} median, {:>10} mean, {:>10} p95 ({} iters)",
            self.name,
            crate::util::fmt_duration(self.median),
            crate::util::fmt_duration(self.mean),
            crate::util::fmt_duration(self.p95),
            self.iters
        )
    }

    /// Throughput in items/second given items processed per iteration.
    pub fn throughput(&self, items_per_iter: usize) -> f64 {
        items_per_iter as f64 / self.median.as_secs_f64().max(1e-12)
    }
}

/// Run `f` `warmup` times untimed, then `iters` times timed.
pub fn bench_fn(
    name: impl Into<String>,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut(),
) -> BenchStats {
    assert!(iters >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let total: Duration = samples.iter().sum();
    let idx = |q: f64| {
        ((samples.len() as f64 - 1.0) * q).round() as usize
    };
    BenchStats {
        name: name.into(),
        iters,
        min: samples[0],
        median: samples[idx(0.5)],
        mean: total / iters as u32,
        p95: samples[idx(0.95)],
        max: *samples.last().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let mut n = 0u64;
        let s = bench_fn("spin", 2, 20, || {
            // Deterministic small work.
            for i in 0..10_000 {
                n = n.wrapping_add(i);
            }
        });
        assert!(s.min <= s.median);
        assert!(s.median <= s.p95);
        assert!(s.p95 <= s.max);
        assert_eq!(s.iters, 20);
        assert!(n > 0);
    }

    #[test]
    fn throughput_math() {
        let s = BenchStats {
            name: "x".into(),
            iters: 1,
            min: Duration::from_millis(10),
            median: Duration::from_millis(10),
            mean: Duration::from_millis(10),
            p95: Duration::from_millis(10),
            max: Duration::from_millis(10),
        };
        assert!((s.throughput(100) - 10_000.0).abs() < 1e-6);
    }

    #[test]
    fn summary_contains_name() {
        let s = bench_fn("named-bench", 0, 1, || {});
        assert!(s.summary().contains("named-bench"));
    }
}
