//! Bench harness and the paper-experiment drivers.
//!
//! `criterion` is unavailable in this offline build, so [`harness`] is a
//! small self-contained measurement loop (warm-up + N iterations, robust
//! stats), and [`experiments`] holds the drivers that regenerate every
//! table and figure of the paper's §IV.  Both the `cargo bench` targets
//! (`rust/benches/`) and the CLI (`llmapreduce bench ...`) call into here
//! so numbers in EXPERIMENTS.md come from one code path.

pub mod experiments;
pub mod harness;

pub use harness::{bench_fn, BenchStats};

/// Place a bench artifact (`BENCH_*.json`) at the repo root when
/// running inside the checkout (ROADMAP.md marks it); fall back to the
/// current directory.  Shared by the CLI `bench` command and the
/// `cargo bench` targets so every artifact lands in one place.
pub fn artifact_path(name: &str) -> std::path::PathBuf {
    let cwd = std::env::current_dir()
        .unwrap_or_else(|_| std::path::PathBuf::from("."));
    for dir in cwd.ancestors() {
        if dir.join("ROADMAP.md").is_file() {
            return dir.join(name);
        }
    }
    cwd.join(name)
}
