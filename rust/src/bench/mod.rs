//! Bench harness and the paper-experiment drivers.
//!
//! `criterion` is unavailable in this offline build, so [`harness`] is a
//! small self-contained measurement loop (warm-up + N iterations, robust
//! stats), and [`experiments`] holds the drivers that regenerate every
//! table and figure of the paper's §IV.  Both the `cargo bench` targets
//! (`rust/benches/`) and the CLI (`llmapreduce bench ...`) call into here
//! so numbers in EXPERIMENTS.md come from one code path.

pub mod experiments;
pub mod harness;

pub use harness::{bench_fn, BenchStats};
