//! Drivers that regenerate every table and figure in the paper's §IV.
//!
//! | Paper artifact | Driver            | Substrate                        |
//! |----------------|-------------------|----------------------------------|
//! | Table I        | [`table1_matlab`], [`table1_java`] | real local engine |
//! | Table II       | [`table2`]        | calibrated simulator             |
//! | Fig 18         | [`fig18_19_sweep`] + [`crate::metrics::report::overhead_series`] | simulator |
//! | Fig 19         | [`fig18_19_sweep`] + [`crate::metrics::report::speedup_series`]  | simulator |
//!
//! We match *shapes*, not the authors' absolute numbers (their testbed was
//! the MIT SuperCloud; ours is a calibrated DES — DESIGN.md §3).

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use crate::apps::wordcount::{WordCountApp, WordCountReducer};
use crate::apps::{CostHint, MapApp};
use crate::error::Result;
use crate::mapreduce::{run, Apps};
use crate::metrics::report::speedup_table;
use crate::metrics::{Measurement, Sweep};
use crate::options::{AppType, Options};
use crate::scheduler::sim::{ClusterConfig, SimEngine};
use crate::scheduler::{Engine, JobSpec, TaskSpec, TaskWork};
use crate::workload::text::generate_corpus;
use crate::workload::trace::TraceParams;

/// Result of a Table I / Table II comparison.
#[derive(Debug, Clone)]
pub struct SpeedupResult {
    pub example: String,
    pub block: Measurement,
    pub mimo: Measurement,
}

impl SpeedupResult {
    pub fn speedup(&self) -> f64 {
        self.block.elapsed.as_secs_f64()
            / self.mimo.elapsed.as_secs_f64().max(1e-12)
    }

    pub fn table(&self) -> String {
        speedup_table(&self.example, &self.block, &self.mimo)
    }
}

/// Run one BLOCK-vs-MIMO pair of real jobs on `engine` and compare.
pub fn block_vs_mimo(
    example: &str,
    base_opts: &Options,
    apps: &Apps,
    engine: &dyn Engine,
) -> Result<SpeedupResult> {
    let np = base_opts.np.unwrap_or(1);
    let block_opts = base_opts.clone().apptype(AppType::Siso);
    let block_report = run(&block_opts, apps, engine)?;
    let mimo_opts = base_opts.clone().apptype(AppType::Mimo);
    let mimo_report = run(&mimo_opts, apps, engine)?;
    Ok(SpeedupResult {
        example: example.to_string(),
        block: Measurement::from_report("BLOCK", np, &block_report.map),
        mimo: Measurement::from_report("MIMO", np, &mimo_report.map),
    })
}

/// Table I, MATLAB row: "converts 6 images over 2 array tasks" — the
/// image-conversion app over the XLA artifact; startup = XLA compile.
/// Caller provides the image input dir (from `workload::images`) and an
/// engine (local for real wall-clock).
pub fn table1_matlab(
    input: &Path,
    output: &Path,
    mapper: Arc<dyn MapApp>,
    engine: &dyn Engine,
) -> Result<SpeedupResult> {
    let opts = Options::new(input, output, mapper.name())
        .np(2)
        .pid(81001);
    let apps = Apps {
        mapper,
        reducer: None,
    };
    block_vs_mimo("Matlab (imageConvert)", &opts, &apps, engine)
}

/// Table I, Java row: "counts word frequency of 21 text files over 3
/// array tasks", with the merging reducer of Fig 15.  The JVM boot is
/// modelled by a deterministic startup spin (DESIGN.md §3).
pub fn table1_java(
    workdir: &Path,
    jvm_boot: Duration,
    engine: &dyn Engine,
) -> Result<SpeedupResult> {
    let input = workdir.join("input");
    let output = workdir.join("output");
    let (_docs, ignore) = generate_corpus(&input, 21, 2_000, 500, 0x1A7A)?;
    let mapper = WordCountApp::with_startup_spin(Some(ignore), jvm_boot);
    let opts = Options::new(&input, &output, "wordcount")
        .np(3)
        .reducer("wordcount-reducer")
        .distribution(crate::options::Distribution::Cyclic)
        .pid(81002);
    let apps = Apps {
        mapper,
        reducer: Some(Arc::new(WordCountReducer)),
    };
    block_vs_mimo("Java (WordFreqCmd)", &opts, &apps, engine)
}

/// Table II: the 43,580-file / 256-task trace on the calibrated simulator.
pub fn table2(params: TraceParams) -> Result<SpeedupResult> {
    let run_mode = |apptype| -> Result<Measurement> {
        let eng = SimEngine::new(ClusterConfig {
            dispatch_latency: Duration::from_millis(50),
            ..ClusterConfig::with_width(params.ntasks)
        });
        let report = eng.run(JobSpec::new(
            "user-matlab-image-app",
            params.tasks(apptype),
        ))?;
        Ok(Measurement::from_report(
            match apptype {
                AppType::Siso => "BLOCK",
                AppType::Mimo => "MIMO",
                AppType::Spmd => "SPMD",
            },
            params.ntasks,
            &report,
        ))
    };
    Ok(SpeedupResult {
        example: "Matlab (real user app, 43,580 files)".into(),
        block: run_mode(AppType::Siso)?,
        mimo: run_mode(AppType::Mimo)?,
    })
}

/// The three §IV launch options as synthetic task sets over `nfiles`
/// files at width `np` with calibrated costs.
fn option_job(
    option: &str,
    nfiles: usize,
    np: usize,
    hint: CostHint,
) -> Vec<TaskSpec> {
    match option {
        // DEFAULT: every file its own array task (np caps concurrency
        // through cluster width, not task count).
        "DEFAULT" => (0..nfiles)
            .map(|i| TaskSpec {
                task_id: i + 1,
                work: TaskWork::Synthetic {
                    startup: hint.startup,
                    per_item: hint.per_item,
                    items: 1,
                    launches: 1,
                },
            })
            .collect(),
        // BLOCK: np tasks, app restarts per file within the task.
        "BLOCK" => balanced_tasks(nfiles, np, hint, false),
        // MIMO: np tasks, one launch each.
        "MIMO" => balanced_tasks(nfiles, np, hint, true),
        other => panic!("unknown option {other}"),
    }
}

fn balanced_tasks(
    nfiles: usize,
    np: usize,
    hint: CostHint,
    mimo: bool,
) -> Vec<TaskSpec> {
    let base = nfiles / np;
    let rem = nfiles % np;
    (0..np)
        .map(|t| {
            let items = base + usize::from(t < rem);
            TaskSpec {
                task_id: t + 1,
                work: TaskWork::Synthetic {
                    startup: hint.startup,
                    per_item: hint.per_item,
                    items,
                    launches: if mimo {
                        usize::from(items > 0)
                    } else {
                        items
                    },
                },
            }
        })
        .collect()
}

/// The Fig 18/19 sweep: DEFAULT/BLOCK/MIMO × np ∈ `widths` over `nfiles`
/// files with calibrated `hint` costs, on the simulator.
pub fn fig18_19_sweep(
    nfiles: usize,
    widths: &[usize],
    hint: CostHint,
    dispatch: Duration,
) -> Result<Sweep> {
    let mut sweep = Sweep::default();
    for &np in widths {
        for option in ["DEFAULT", "BLOCK", "MIMO"] {
            let eng = SimEngine::new(ClusterConfig {
                dispatch_latency: dispatch,
                ..ClusterConfig::with_width(np)
            });
            let report = eng.run(JobSpec::new(
                format!("{option}-np{np}"),
                option_job(option, nfiles, np, hint),
            ))?;
            sweep.push(Measurement::from_report(option, np, &report));
        }
    }
    Ok(sweep)
}

/// The paper's sweep widths: "ranging from 1, 2, 4, 8, 16, 32, 64, 128,
/// and 256".
pub const PAPER_WIDTHS: [usize; 9] = [1, 2, 4, 8, 16, 32, 64, 128, 256];

// ---------------------------------------------------------------------------
// Ablation: block vs cyclic load balancing (§II's claim that workloads
// "can be distributed in a block or cyclic fashion to improve initial
// load balancing")
// ---------------------------------------------------------------------------

/// One ablation cell: distribution x file-cost pattern.
#[derive(Debug, Clone)]
pub struct AblationCell {
    pub distribution: crate::options::Distribution,
    pub pattern: &'static str,
    pub makespan: Duration,
    /// Max over tasks of summed compute (the straggler).
    pub straggler: Duration,
}

/// Run the block-vs-cyclic ablation: `nfiles` files whose per-file cost
/// follows `pattern` ("uniform" | "sorted" | "zipf"), distributed over
/// `np` tasks each way, on the simulator.  Sorted costs are the paper's
/// motivating case for cyclic: when the input listing correlates with
/// cost (e.g. time-ordered sensor captures growing over a day), block
/// assignment gives one task all the big files.
pub fn ablation_distribution(
    nfiles: usize,
    np: usize,
    base_item: Duration,
    seed: u64,
) -> Result<Vec<AblationCell>> {
    use crate::mapreduce::distribution::distribute;
    use crate::options::Distribution;
    use crate::util::rng::Rng;

    let patterns: [(&'static str, Box<dyn Fn(&mut Rng, usize) -> f64>); 3] = [
        ("uniform", Box::new(|_rng, _i| 1.0)),
        // Cost grows linearly with listing position.
        ("sorted", Box::new(move |_rng, i| {
            0.25 + 1.5 * i as f64 / nfiles.max(1) as f64
        })),
        // Heavy-tailed: a few files are 10x the median.
        ("zipf", Box::new(|rng, _i| {
            if rng.next_below(10) == 0 { 10.0 } else { 1.0 }
        })),
    ];

    let mut cells = Vec::new();
    for (pattern, costf) in &patterns {
        let mut rng = Rng::new(seed ^ pattern.len() as u64);
        let costs: Vec<Duration> = (0..nfiles)
            .map(|i| {
                Duration::from_nanos(
                    (base_item.as_nanos() as f64 * costf(&mut rng, i))
                        as u64,
                )
            })
            .collect();
        for dist in [Distribution::Block, Distribution::Cyclic] {
            let assignment = distribute(nfiles, np, dist);
            let mut tasks = Vec::with_capacity(np);
            let mut straggler = Duration::ZERO;
            for (t, idxs) in assignment.iter().enumerate() {
                let total: Duration =
                    idxs.iter().map(|&i| costs[i]).sum();
                straggler = straggler.max(total);
                // One launch per task (MIMO) so distribution is the only
                // variable under test.
                let items = idxs.len().max(1);
                tasks.push(TaskSpec {
                    task_id: t + 1,
                    work: TaskWork::Synthetic {
                        startup: Duration::ZERO,
                        per_item: total / items as u32,
                        items,
                        launches: 0,
                    },
                });
            }
            let eng = SimEngine::new(ClusterConfig {
                dispatch_latency: Duration::ZERO,
                ..ClusterConfig::with_width(np)
            });
            let report =
                eng.run(JobSpec::new(format!("{pattern}-{dist:?}"), tasks))?;
            cells.push(AblationCell {
                distribution: dist,
                pattern,
                makespan: report.makespan,
                straggler,
            });
        }
    }
    Ok(cells)
}

// ---------------------------------------------------------------------------
// SPMD ganging: Table-1-style launch-overhead amortization
// (persistent per-worker app instances over batch-packed tasks;
// DESIGN.md §7).  Emitted as BENCH_spmd.json.
// ---------------------------------------------------------------------------

/// One cell of the SPMD amortization table.
#[derive(Debug, Clone)]
pub struct SpmdPoint {
    /// `"per-task"` for the one-item-per-launch baseline (N=1),
    /// `"ganged"` otherwise.
    pub mode: String,
    pub items_per_task: usize,
    /// Total app launches across the job (= number of batches).
    pub launches: usize,
    pub makespan: Duration,
    /// Launch cost charged to each item: launches × startup / items.
    pub per_item_launch_overhead: Duration,
}

impl SpmdPoint {
    fn label(items_per_task: usize) -> String {
        if items_per_task == 1 { "per-task" } else { "ganged" }.to_string()
    }
}

/// Virtual-time amortization sweep: `items` files batch-packed at each
/// gang size, run serially on the pure-timing simulator with zero
/// dispatch latency and zero jitter, so the makespan is exactly
/// `launches × startup + items × per_item` and the emitted artifact is
/// reproducible bit-for-bit on any machine.
pub fn spmd_amortization_virtual(
    items: usize,
    hint: CostHint,
    gang_sizes: &[usize],
) -> Result<Vec<SpmdPoint>> {
    let mut points = Vec::new();
    for &n in gang_sizes {
        let tasks: Vec<TaskSpec> =
            crate::mapreduce::planner::pack_batches(items, n)
                .iter()
                .enumerate()
                .map(|(t, b)| TaskSpec {
                    task_id: t + 1,
                    work: TaskWork::Synthetic {
                        startup: hint.startup,
                        per_item: hint.per_item,
                        items: b.len(),
                        launches: usize::from(!b.is_empty()),
                    },
                })
                .collect();
        let launches: usize =
            tasks.iter().map(|t| t.work.launches()).sum();
        let eng = SimEngine::new(ClusterConfig {
            dispatch_latency: Duration::ZERO,
            ..ClusterConfig::with_width(1)
        });
        let report = eng.run(JobSpec::new(format!("spmd-n{n}"), tasks))?;
        points.push(SpmdPoint {
            mode: SpmdPoint::label(n),
            items_per_task: n,
            launches,
            makespan: report.makespan,
            per_item_launch_overhead: hint.startup * launches as u32
                / items.max(1) as u32,
        });
    }
    Ok(points)
}

/// Measured wall-clock variant: real word-count jobs (startup spin
/// modelling a heavy interpreter) through the full planner → engine
/// path, per-task vs ganged at each gang size.
pub fn spmd_amortization_measured(
    workdir: &Path,
    startup_spin: Duration,
    gang_sizes: &[usize],
) -> Result<Vec<SpmdPoint>> {
    let input = workdir.join("input");
    let (docs, ignore) = generate_corpus(&input, 16, 500, 100, 0x59D)?;
    let items = docs.len();
    let mapper = WordCountApp::with_startup_spin(Some(ignore), startup_spin);
    let mut points = Vec::new();
    for &n in gang_sizes {
        let output = workdir.join(format!("output-n{n}"));
        let opts = Options::new(&input, &output, "wordcount")
            .items_per_task(n)
            .pid(82000 + n as u32);
        let apps = Apps {
            mapper: mapper.clone(),
            reducer: None,
        };
        let engine = crate::scheduler::local::LocalEngine::new(2);
        let report = run(&opts, &apps, &engine)?;
        let m = Measurement::from_report(SpmdPoint::label(n), n, &report.map);
        points.push(SpmdPoint {
            mode: m.option,
            items_per_task: n,
            launches: m.launches,
            makespan: m.elapsed,
            per_item_launch_overhead: m.total_startup
                / items.max(1) as u32,
        });
    }
    Ok(points)
}

/// Serialize an amortization sweep as the `BENCH_spmd.json` document.
/// Schema (asserted by `tests/spmd.rs`): top-level `bench`, `source`,
/// `items`, `startup_us`, `per_item_us`, and a `points` array whose
/// rows carry `mode`, `items_per_task`, `launches`, `makespan_us`, and
/// `per_item_launch_overhead_us`.
pub fn spmd_bench_json(
    source: &str,
    items: usize,
    hint: CostHint,
    points: &[SpmdPoint],
) -> crate::util::json::Json {
    use crate::util::json::{obj, Json};
    obj(vec![
        ("bench", "spmd-amortization".into()),
        ("source", source.into()),
        ("items", items.into()),
        ("startup_us", (hint.startup.as_micros() as usize).into()),
        ("per_item_us", (hint.per_item.as_micros() as usize).into()),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        obj(vec![
                            ("mode", p.mode.as_str().into()),
                            ("items_per_task", p.items_per_task.into()),
                            ("launches", p.launches.into()),
                            (
                                "makespan_us",
                                (p.makespan.as_micros() as usize).into(),
                            ),
                            (
                                "per_item_launch_overhead_us",
                                (p.per_item_launch_overhead.as_micros()
                                    as usize)
                                    .into(),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// One labelled engine configuration of `cargo bench --bench remote`.
#[derive(Debug, Clone)]
pub struct RemotePoint {
    pub label: String,
    pub makespan: Duration,
    /// Mean per-task shipping overhead (assignment round-trip minus
    /// worker-measured execution); zero for in-process engines.
    pub ship_per_task: Duration,
    pub compute_per_task: Duration,
    /// Local-baseline makespan over this makespan (>1 = faster).
    pub speedup_vs_local: f64,
}

/// Serialize `cargo bench --bench micro` stats as the
/// `BENCH_micro.json` document.  Schema (validated in tests): top-level
/// `bench`, `source`, and a `points` array whose rows carry `name`,
/// `iters`, `median_ns`, `mean_ns`, `p95_ns`.  Nanoseconds, because the
/// hot paths measured here (JSON parse, fsync'd journal appends) sit
/// below a microsecond on warm hardware.
pub fn micro_bench_json(
    source: &str,
    stats: &[crate::bench::BenchStats],
) -> crate::util::json::Json {
    use crate::util::json::{obj, Json};
    obj(vec![
        ("bench", "micro".into()),
        ("source", source.into()),
        (
            "points",
            Json::Arr(
                stats
                    .iter()
                    .map(|s| {
                        obj(vec![
                            ("name", s.name.as_str().into()),
                            ("iters", s.iters.into()),
                            (
                                "median_ns",
                                (s.median.as_nanos() as usize).into(),
                            ),
                            (
                                "mean_ns",
                                (s.mean.as_nanos() as usize).into(),
                            ),
                            (
                                "p95_ns",
                                (s.p95.as_nanos() as usize).into(),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Serialize `cargo bench --bench remote` rows as the
/// `BENCH_remote.json` document.  Schema (validated in tests):
/// top-level `bench`, `source`, and a `points` array whose rows carry
/// `label`, `makespan_us`, `ship_per_task_us`, `compute_per_task_us`,
/// `speedup_vs_local`.
pub fn remote_bench_json(
    source: &str,
    points: &[RemotePoint],
) -> crate::util::json::Json {
    use crate::util::json::{obj, Json};
    obj(vec![
        ("bench", "remote-shipping".into()),
        ("source", source.into()),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        obj(vec![
                            ("label", p.label.as_str().into()),
                            (
                                "makespan_us",
                                (p.makespan.as_micros() as usize).into(),
                            ),
                            (
                                "ship_per_task_us",
                                (p.ship_per_task.as_micros() as usize)
                                    .into(),
                            ),
                            (
                                "compute_per_task_us",
                                (p.compute_per_task.as_micros() as usize)
                                    .into(),
                            ),
                            ("speedup_vs_local", p.speedup_vs_local.into()),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hint(startup_ms: u64, item_ms: u64) -> CostHint {
        CostHint {
            startup: Duration::from_millis(startup_ms),
            per_item: Duration::from_millis(item_ms),
        }
    }

    #[test]
    fn table2_speedup_matches_paper_band() {
        let r = table2(TraceParams::table2()).unwrap();
        let s = r.speedup();
        // Paper: 11.57x.  Allow the dispatch-latency wiggle.
        assert!(s > 10.0 && s < 13.0, "Table II speed-up {s}");
    }

    #[test]
    fn sweep_shapes_match_fig18() {
        let sweep = fig18_19_sweep(
            512,
            &[1, 16, 256],
            hint(100, 10),
            Duration::from_millis(1),
        )
        .unwrap();
        // MIMO overhead flat; BLOCK overhead falls with np.
        let m1 = sweep.get("MIMO", 1).unwrap().overhead_per_task;
        let m256 = sweep.get("MIMO", 256).unwrap().overhead_per_task;
        let b1 = sweep.get("BLOCK", 1).unwrap().overhead_per_task;
        let b256 = sweep.get("BLOCK", 256).unwrap().overhead_per_task;
        let ratio_m = m1.as_secs_f64() / m256.as_secs_f64();
        let ratio_b = b1.as_secs_f64() / b256.as_secs_f64();
        assert!(ratio_m < 3.0, "MIMO ~flat, got {ratio_m}");
        assert!(ratio_b > 50.0, "BLOCK falls ~linearly, got {ratio_b}");
    }

    #[test]
    fn sweep_shapes_match_fig19() {
        let sweep = fig18_19_sweep(
            512,
            &[1, 4, 64],
            hint(100, 10),
            Duration::from_millis(1),
        )
        .unwrap();
        let base = sweep.baseline().unwrap();
        for np in [1usize, 4, 64] {
            let s_def = sweep.get("DEFAULT", np).unwrap().speedup_vs(base);
            let s_blk = sweep.get("BLOCK", np).unwrap().speedup_vs(base);
            let s_mimo = sweep.get("MIMO", np).unwrap().speedup_vs(base);
            assert!(s_mimo > s_blk, "np={np}: MIMO best");
            assert!(s_blk >= s_def * 0.95, "np={np}: BLOCK >= DEFAULT");
        }
        // Monotone growth with np for MIMO.
        let s1 = sweep.get("MIMO", 1).unwrap().speedup_vs(base);
        let s64 = sweep.get("MIMO", 64).unwrap().speedup_vs(base);
        assert!(s64 > s1 * 10.0, "{s1} -> {s64}");
    }

    #[test]
    fn ablation_cyclic_beats_block_on_sorted_costs() {
        let cells =
            ablation_distribution(256, 8, Duration::from_millis(10), 42)
                .unwrap();
        let get = |pattern: &str, dist: crate::options::Distribution| {
            cells
                .iter()
                .find(|c| c.pattern == pattern && c.distribution == dist)
                .unwrap()
                .makespan
        };
        use crate::options::Distribution::{Block, Cyclic};
        // Uniform costs: both within a hair.
        let (bu, cu) = (get("uniform", Block), get("uniform", Cyclic));
        let ratio = bu.as_secs_f64() / cu.as_secs_f64();
        assert!((0.9..1.1).contains(&ratio), "uniform ratio {ratio}");
        // Sorted costs: cyclic clearly better (block gets the tail).
        let (bs, cs) = (get("sorted", Block), get("sorted", Cyclic));
        assert!(
            bs.as_secs_f64() > cs.as_secs_f64() * 1.2,
            "sorted: block {bs:?} should trail cyclic {cs:?}"
        );
    }

    #[test]
    fn spmd_virtual_amortization_is_exact_and_monotone() {
        // 64 items, 128ms startup, 10ms/item — integer-exact arithmetic.
        let pts = spmd_amortization_virtual(
            64,
            hint(128, 10),
            &[1, 4, 16, 64],
        )
        .unwrap();
        assert_eq!(pts.len(), 4);
        assert_eq!(pts[0].mode, "per-task");
        assert_eq!(pts[0].launches, 64);
        assert_eq!(
            pts[0].per_item_launch_overhead,
            Duration::from_millis(128)
        );
        assert_eq!(pts[3].mode, "ganged");
        assert_eq!(pts[3].launches, 1);
        assert_eq!(
            pts[3].per_item_launch_overhead,
            Duration::from_millis(2)
        );
        // Makespan = launches×startup + items×per_item exactly.
        assert_eq!(
            pts[0].makespan,
            Duration::from_millis(64 * 128 + 64 * 10)
        );
        assert_eq!(
            pts[3].makespan,
            Duration::from_millis(128 + 64 * 10)
        );
        // Overhead decreases monotonically as the gang grows.
        for w in pts.windows(2) {
            assert!(
                w[1].per_item_launch_overhead
                    < w[0].per_item_launch_overhead,
                "{:?} then {:?}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn spmd_bench_json_schema() {
        let h = hint(128, 10);
        let pts =
            spmd_amortization_virtual(64, h, &[1, 4, 16, 64]).unwrap();
        let doc = spmd_bench_json("sim-virtual", 64, h, &pts);
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("spmd-amortization"));
        assert_eq!(doc.get("items").unwrap().as_usize(), Some(64));
        assert_eq!(doc.get("startup_us").unwrap().as_usize(), Some(128_000));
        let points = doc.get("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 4);
        for p in points {
            assert!(p.get("mode").unwrap().as_str().is_some());
            assert!(p.get("items_per_task").unwrap().as_usize().is_some());
            assert!(
                p.get("per_item_launch_overhead_us")
                    .unwrap()
                    .as_usize()
                    .is_some()
            );
        }
        // The document round-trips through the parser.
        let text = doc.to_string_pretty();
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn micro_bench_json_schema() {
        let stats = vec![crate::bench::bench_fn("json/parse", 0, 3, || {})];
        let doc = micro_bench_json("cargo-bench-micro", &stats);
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("micro"));
        assert_eq!(
            doc.get("source").unwrap().as_str(),
            Some("cargo-bench-micro")
        );
        let points = doc.get("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 1);
        let p = &points[0];
        assert_eq!(p.get("name").unwrap().as_str(), Some("json/parse"));
        assert_eq!(p.get("iters").unwrap().as_usize(), Some(3));
        assert!(p.get("median_ns").unwrap().as_usize().is_some());
        assert!(p.get("mean_ns").unwrap().as_usize().is_some());
        assert!(p.get("p95_ns").unwrap().as_usize().is_some());
        let back =
            crate::util::json::Json::parse(&doc.to_string_pretty()).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn remote_bench_json_schema() {
        let pts = vec![RemotePoint {
            label: "local (4 slots)".into(),
            makespan: Duration::from_millis(120),
            ship_per_task: Duration::from_micros(300),
            compute_per_task: Duration::from_millis(4),
            speedup_vs_local: 1.0,
        }];
        let doc = remote_bench_json("cargo-bench-remote", &pts);
        assert_remote_doc_valid(&doc);
        let p = &doc.get("points").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("makespan_us").unwrap().as_usize(), Some(120_000));
        assert_eq!(p.get("ship_per_task_us").unwrap().as_usize(), Some(300));
        assert_eq!(
            p.get("compute_per_task_us").unwrap().as_usize(),
            Some(4_000)
        );
        let back =
            crate::util::json::Json::parse(&doc.to_string_pretty()).unwrap();
        assert_eq!(back, doc);
    }

    fn assert_remote_doc_valid(doc: &crate::util::json::Json) {
        assert_eq!(
            doc.get("bench").unwrap().as_str(),
            Some("remote-shipping")
        );
        assert!(doc.get("source").unwrap().as_str().is_some());
        let points = doc.get("points").unwrap().as_arr().unwrap();
        assert!(!points.is_empty());
        for p in points {
            assert!(p.get("label").unwrap().as_str().is_some());
            assert!(p.get("makespan_us").unwrap().as_usize().is_some());
            assert!(p.get("speedup_vs_local").unwrap().as_f64().is_some());
        }
    }

    /// The committed repo-root artifacts stay schema-compatible with
    /// the emitters (they are wall-clock measurements, so values are
    /// representative rather than byte-reproducible like BENCH_spmd).
    #[test]
    fn committed_bench_artifacts_validate() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap();
        let micro = root.join("BENCH_micro.json");
        if micro.is_file() {
            let text = std::fs::read_to_string(&micro).unwrap();
            let doc = crate::util::json::Json::parse(&text).unwrap();
            assert_eq!(doc.get("bench").unwrap().as_str(), Some("micro"));
            let points = doc.get("points").unwrap().as_arr().unwrap();
            // The crash-safety and observability taxes are tracked:
            // fsync'd journal appends, the journal-on/off pipeline
            // pair, the telemetry hot paths (histogram record, bus
            // fanout), the telemetry-on/off pipeline pair, and the
            // tracing costs (trace assembly, Chrome export, the
            // trace-on/off pipeline pair) must all be present.
            for needed in [
                "journal/record-fsync",
                "journal/record-no-fsync",
                "pipeline/journal-fsync",
                "pipeline/no-journal",
                "telemetry/histogram-record",
                "telemetry/event-fanout",
                "pipeline/telemetry-on",
                "pipeline/telemetry-off",
                "trace/assemble-256-tasks",
                "trace/chrome-export-256-tasks",
                "pipeline/trace-on",
                "pipeline/trace-off",
                "wire/json-encode-single",
                "wire/json-decode-single",
                "wire/bin-encode-single",
                "wire/bin-decode-single",
                "wire/json-encode-batch64",
                "wire/json-decode-batch64",
                "wire/bin-encode-batch64",
                "wire/bin-decode-batch64",
            ] {
                assert!(
                    points.iter().any(|p| p
                        .get("name")
                        .and_then(|n| n.as_str())
                        == Some(needed)),
                    "BENCH_micro.json must carry the '{needed}' row"
                );
            }
        }
        let remote = root.join("BENCH_remote.json");
        if remote.is_file() {
            let text = std::fs::read_to_string(&remote).unwrap();
            let doc = crate::util::json::Json::parse(&text).unwrap();
            assert_remote_doc_valid(&doc);
            // The small-task sweep is the PR-10 acceptance gate: the
            // batched-binary row must ship each task at least 2x
            // cheaper than the line-JSON frame-per-task row.
            let points = doc.get("points").unwrap().as_arr().unwrap();
            let ship = |label: &str| -> usize {
                points
                    .iter()
                    .find(|p| {
                        p.get("label").and_then(|l| l.as_str())
                            == Some(label)
                    })
                    .unwrap_or_else(|| {
                        panic!(
                            "BENCH_remote.json must carry the \
                             '{label}' sweep row"
                        )
                    })
                    .get("ship_per_task_us")
                    .unwrap()
                    .as_usize()
                    .unwrap()
            };
            let json = ship("sweep json frame-per-task (2 workers)");
            let bin = ship("sweep batched binary (2 workers)");
            assert!(
                bin * 2 <= json,
                "sweep: batched binary must ship >=2x cheaper \
                 (json={json}us binary={bin}us)"
            );
        }
    }

    #[test]
    fn default_and_block_similar_overhead() {
        // §IV: "both DEFAULT and BLOCK options show similar overhead,
        // although the BLOCK option shows slightly smaller cost".
        let sweep = fig18_19_sweep(
            256,
            &[4],
            hint(100, 10),
            Duration::from_millis(5),
        )
        .unwrap();
        let d = sweep.get("DEFAULT", 4).unwrap().overhead_per_task;
        let b = sweep.get("BLOCK", 4).unwrap().overhead_per_task;
        assert!(b < d, "BLOCK slightly smaller: {b:?} vs {d:?}");
        // But the same order of magnitude (both dominated by startup).
        assert!(d < b * 3, "similar overhead: {d:?} vs {b:?}");
    }
}
