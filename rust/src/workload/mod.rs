//! Synthetic workload generators.
//!
//! The paper's data is user data we don't have (sensor images, text
//! corpora, a 43,580-file image processing job).  These generators produce
//! deterministic synthetic equivalents that exercise the same code paths:
//! PPM images sized for the `image_convert` artifact, Zipf-distributed
//! text corpora for word counting, MATLIST matrix files for the §IV
//! scaling study, and the Table II trace parameters.

pub mod images;
pub mod matrices;
pub mod text;
pub mod trace;
