//! Synthetic PPM image generator (the §III-A image-conversion workload).

use std::path::{Path, PathBuf};

use crate::apps::image::{write_ppm, Image};
use crate::error::{IoContext, Result};
use crate::util::rng::Rng;

/// Generate `count` random RGB images of `height`×`width` as
/// `im_<i>.ppm` under `dir`.  Deterministic in `seed`.
pub fn generate_images(
    dir: &Path,
    count: usize,
    height: usize,
    width: usize,
    seed: u64,
) -> Result<Vec<PathBuf>> {
    std::fs::create_dir_all(dir).at(dir)?;
    let mut rng = Rng::new(seed);
    let mut paths = Vec::with_capacity(count);
    for i in 0..count {
        let mut r = rng.fork(i as u64);
        // Structured content (gradient + noise), not pure noise: grayscale
        // output then has visible structure, useful when eyeballing
        // example outputs.
        let mut rgb = Vec::with_capacity(height * width * 3);
        for y in 0..height {
            for x in 0..width {
                let gx = x as f32 / width.max(1) as f32;
                let gy = y as f32 / height.max(1) as f32;
                rgb.push((gx + 0.1 * r.next_f32()).clamp(0.0, 1.0));
                rgb.push((gy + 0.1 * r.next_f32()).clamp(0.0, 1.0));
                rgb.push((0.5 + 0.5 * r.next_f32()).clamp(0.0, 1.0));
            }
        }
        let img = Image {
            width,
            height,
            rgb,
        };
        let path = dir.join(format!("im_{i:04}.ppm"));
        write_ppm(&path, &img)?;
        paths.push(path);
    }
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::image::read_ppm;
    use std::fs;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-wimg-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn generates_readable_images() {
        let d = tmp("gen");
        let paths = generate_images(&d, 3, 8, 16, 42).unwrap();
        assert_eq!(paths.len(), 3);
        for p in &paths {
            let img = read_ppm(p).unwrap();
            assert_eq!((img.height, img.width), (8, 16));
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let d1 = tmp("det1");
        let d2 = tmp("det2");
        generate_images(&d1, 2, 4, 4, 7).unwrap();
        generate_images(&d2, 2, 4, 4, 7).unwrap();
        for i in 0..2 {
            let a = fs::read(d1.join(format!("im_{i:04}.ppm"))).unwrap();
            let b = fs::read(d2.join(format!("im_{i:04}.ppm"))).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn different_seed_differs() {
        let d1 = tmp("seed1");
        let d2 = tmp("seed2");
        generate_images(&d1, 1, 4, 4, 1).unwrap();
        generate_images(&d2, 1, 4, 4, 2).unwrap();
        assert_ne!(
            fs::read(d1.join("im_0000.ppm")).unwrap(),
            fs::read(d2.join("im_0000.ppm")).unwrap()
        );
    }
}
