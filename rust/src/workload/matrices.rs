//! Synthetic MATLIST generator (the §IV scaling-study workload: "512
//! input data files were created" of square-matrix lists).

use std::path::{Path, PathBuf};

use crate::apps::matmul::{write_matrix_list, MatrixList};
use crate::error::{IoContext, Result};
use crate::util::rng::Rng;

/// Generate `count` matrix-list files `mat_<i>.mat` under `dir`, each with
/// `chain_len` matrices of size `n`×`n`.  Values are scaled Gaussians so
/// chain products stay well inside f32 range.
pub fn generate_matrix_lists(
    dir: &Path,
    count: usize,
    chain_len: usize,
    n: usize,
    seed: u64,
) -> Result<Vec<PathBuf>> {
    std::fs::create_dir_all(dir).at(dir)?;
    let mut rng = Rng::new(seed);
    // Keep the spectral radius ~1: scale by 1/sqrt(n).
    let scale = 1.0 / (n as f64).sqrt();
    let mut paths = Vec::with_capacity(count);
    for i in 0..count {
        let mut r = rng.fork(i as u64);
        let data: Vec<f32> = (0..chain_len * n * n)
            .map(|_| (r.next_gaussian() * scale) as f32)
            .collect();
        let list = MatrixList { n, data };
        let path = dir.join(format!("mat_{i:04}.mat"));
        write_matrix_list(&path, &list)?;
        paths.push(path);
    }
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::matmul::{chain_product_ref, read_matrix_list};
    use std::fs;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-wmat-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn generates_readable_lists() {
        let d = tmp("gen");
        let paths = generate_matrix_lists(&d, 2, 3, 8, 5).unwrap();
        for p in &paths {
            let list = read_matrix_list(p).unwrap();
            assert_eq!(list.n, 8);
            assert_eq!(list.count(), 3);
        }
    }

    #[test]
    fn products_stay_finite() {
        let d = tmp("finite");
        let paths = generate_matrix_lists(&d, 1, 8, 16, 11).unwrap();
        let list = read_matrix_list(&paths[0]).unwrap();
        let prod = chain_product_ref(&list);
        assert!(prod.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn deterministic() {
        let d1 = tmp("d1");
        let d2 = tmp("d2");
        generate_matrix_lists(&d1, 1, 2, 4, 3).unwrap();
        generate_matrix_lists(&d2, 1, 2, 4, 3).unwrap();
        assert_eq!(
            fs::read(d1.join("mat_0000.mat")).unwrap(),
            fs::read(d2.join("mat_0000.mat")).unwrap()
        );
    }
}
