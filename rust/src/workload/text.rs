//! Synthetic text corpus generator (the §III-B word-counting workload:
//! "a Java application that counts the number of unique words in the
//! given text files" — 21 files in Table I).
//!
//! Words are drawn from a Zipf distribution over a synthetic vocabulary,
//! matching natural-language frequency shape so reducer merge costs are
//! realistic.

use std::path::{Path, PathBuf};

use crate::error::{IoContext, Result};
use crate::util::rng::Rng;

/// Deterministic synthetic vocabulary: `w<k>` tokens plus a stopword set
/// shared with the generated ignore file.
pub const STOPWORDS: [&str; 8] =
    ["the", "a", "an", "and", "of", "to", "in", "is"];

/// Generate `count` text files `doc_<i>.txt` under `dir`, each with
/// `words_per_file` words: Zipf-ranked vocabulary of `vocab` words mixed
/// with stopwords.  Also writes `textignore.txt` (the paper's reference
/// file) NEXT TO the corpus directory — like the paper, where the
/// reference file lives beside the application, not among the inputs —
/// and returns (doc paths, ignore path).
pub fn generate_corpus(
    dir: &Path,
    count: usize,
    words_per_file: usize,
    vocab: usize,
    seed: u64,
) -> Result<(Vec<PathBuf>, PathBuf)> {
    std::fs::create_dir_all(dir).at(dir)?;
    let mut rng = Rng::new(seed);

    // Zipf weights 1/rank over the vocabulary.
    let weights: Vec<f64> =
        (1..=vocab.max(1)).map(|r| 1.0 / r as f64).collect();

    let mut paths = Vec::with_capacity(count);
    for i in 0..count {
        let mut r = rng.fork(i as u64);
        let mut text = String::with_capacity(words_per_file * 6);
        for w in 0..words_per_file {
            if w > 0 {
                text.push(if w % 12 == 0 { '\n' } else { ' ' });
            }
            // 1-in-4 words is a stopword, like running English.
            if r.next_below(4) == 0 {
                text.push_str(
                    STOPWORDS[r.next_below(STOPWORDS.len() as u64) as usize],
                );
            } else {
                let rank = r.weighted(&weights);
                text.push_str(&format!("w{rank:05}"));
            }
        }
        text.push('\n');
        let path = dir.join(format!("doc_{i:04}.txt"));
        std::fs::write(&path, text).at(&path)?;
        paths.push(path);
    }

    let ignore = dir
        .parent()
        .unwrap_or(dir)
        .join("textignore.txt");
    std::fs::write(&ignore, STOPWORDS.join("\n")).at(&ignore)?;
    Ok((paths, ignore))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::fs;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-wtxt-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn generates_corpus_and_ignore_file() {
        let d = tmp("gen");
        let (docs, ignore) = generate_corpus(&d, 3, 100, 50, 1).unwrap();
        assert_eq!(docs.len(), 3);
        assert!(ignore.is_file());
        // The reference file must NOT be inside the input directory: the
        // scanner would otherwise feed it to the mapper as data.
        assert_ne!(ignore.parent(), Some(d.as_path()));
        for doc in &docs {
            let text = fs::read_to_string(doc).unwrap();
            assert!(text.split_whitespace().count() == 100);
        }
    }

    #[test]
    fn zipf_head_is_heavy() {
        let d = tmp("zipf");
        let (docs, _) = generate_corpus(&d, 1, 5000, 100, 2).unwrap();
        let text = fs::read_to_string(&docs[0]).unwrap();
        let head = text.matches("w00000").count();
        let tail = text.matches("w00099").count();
        assert!(head > tail * 3, "rank-1 ({head}) >> rank-100 ({tail})");
    }

    #[test]
    fn stopwords_present_and_listed() {
        let d = tmp("stop");
        let (docs, ignore) = generate_corpus(&d, 1, 2000, 20, 3).unwrap();
        let text = fs::read_to_string(&docs[0]).unwrap();
        let listed: HashSet<&str> = STOPWORDS.into_iter().collect();
        let found = text
            .split_whitespace()
            .filter(|w| listed.contains(w))
            .count();
        assert!(found > 200, "~25% stopwords, found {found}");
        let ign = fs::read_to_string(ignore).unwrap();
        for s in STOPWORDS {
            assert!(ign.contains(s));
        }
    }

    #[test]
    fn deterministic() {
        let d1 = tmp("det1");
        let d2 = tmp("det2");
        generate_corpus(&d1, 1, 100, 10, 9).unwrap();
        generate_corpus(&d2, 1, 100, 10, 9).unwrap();
        assert_eq!(
            fs::read(d1.join("doc_0000.txt")).unwrap(),
            fs::read(d2.join("doc_0000.txt")).unwrap()
        );
    }
}
