//! The Table II trace: the paper's "real user MATLAB application".
//!
//! "The MATLAB application does image processing, and the image files
//! were distributed to 256 array tasks.  The number of input data files
//! was 43,580 in this example. ... the map-reduce job was able to run
//! almost 12 times faster" (11.57×).
//!
//! We cannot rerun the user's MATLAB job, so this module captures its
//! *shape*: file count, task count, and a startup:compute ratio chosen so
//! the BLOCK-vs-MIMO arithmetic lands where the paper reports.  The bench
//! feeds these parameters to the discrete-event simulator.

use std::time::Duration;

use crate::options::AppType;
use crate::scheduler::{TaskSpec, TaskWork};

/// Parameters of the Table II workload.
#[derive(Debug, Clone, Copy)]
pub struct TraceParams {
    pub nfiles: usize,
    pub ntasks: usize,
    /// Per-launch application start-up (MATLAB boot, paper order ~10 s).
    pub startup: Duration,
    /// Per-file compute.
    pub per_item: Duration,
}

impl TraceParams {
    /// The paper's Table II shape.  The startup:per-item ratio is the one
    /// free parameter; 11.57× speed-up with ~170 files/task implies
    /// startup ≈ 11.4× per-item (see `scheduler::cost::Calibration::
    /// predicted_mimo_speedup`), matching MATLAB-boot vs seconds-of-image-
    /// processing. We use 11.4s / 1.0s.
    pub fn table2() -> TraceParams {
        TraceParams {
            nfiles: 43_580,
            ntasks: 256,
            startup: Duration::from_millis(11_400),
            per_item: Duration::from_millis(1_000),
        }
    }

    pub fn files_per_task(&self) -> usize {
        self.nfiles.div_ceil(self.ntasks)
    }

    /// Build the synthetic array-job tasks for one launch option.
    pub fn tasks(&self, apptype: AppType) -> Vec<TaskSpec> {
        let base = self.nfiles / self.ntasks;
        let rem = self.nfiles % self.ntasks;
        (0..self.ntasks)
            .map(|t| {
                let items = base + usize::from(t < rem);
                let launches = match apptype {
                    AppType::Siso => items,
                    AppType::Mimo | AppType::Spmd => {
                        usize::from(items > 0)
                    }
                };
                TaskSpec {
                    task_id: t + 1,
                    work: TaskWork::Synthetic {
                        startup: self.startup,
                        per_item: self.per_item,
                        items,
                        launches,
                    },
                }
            })
            .collect()
    }

    /// Closed-form ideal speed-up (no dispatch): what the simulator
    /// should approach.
    pub fn ideal_mimo_speedup(&self) -> f64 {
        let n = self.files_per_task() as f64;
        let s = self.startup.as_secs_f64();
        let p = self.per_item.as_secs_f64();
        (n * s + n * p) / (s + n * p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_shape() {
        let t = TraceParams::table2();
        assert_eq!(t.nfiles, 43_580);
        assert_eq!(t.ntasks, 256);
        assert_eq!(t.files_per_task(), 171);
    }

    #[test]
    fn table2_ideal_speedup_near_paper() {
        let t = TraceParams::table2();
        let s = t.ideal_mimo_speedup();
        assert!(
            (s - 11.57).abs() < 0.6,
            "ideal speed-up {s} should be near the paper's 11.57"
        );
    }

    #[test]
    fn tasks_cover_all_files() {
        let t = TraceParams::table2();
        for apptype in [AppType::Siso, AppType::Mimo] {
            let tasks = t.tasks(apptype);
            assert_eq!(tasks.len(), 256);
            let items: usize = tasks.iter().map(|ts| ts.work.items()).sum();
            assert_eq!(items, 43_580);
        }
    }

    #[test]
    fn launch_accounting_differs_by_mode() {
        let t = TraceParams::table2();
        let siso: usize = t
            .tasks(AppType::Siso)
            .iter()
            .map(|ts| ts.work.launches())
            .sum();
        let mimo: usize = t
            .tasks(AppType::Mimo)
            .iter()
            .map(|ts| ts.work.launches())
            .sum();
        assert_eq!(siso, 43_580);
        assert_eq!(mimo, 256);
    }
}
