//! Map and reduce applications.
//!
//! The paper's launcher is language-agnostic: "LLMapReduce can launch any
//! program in any language" (§I).  The API contract (§II):
//!
//! * a **map** application takes two arguments — input filename, output
//!   filename;
//! * a **reduce** application takes two arguments — the directory where
//!   the map results reside, and the reduce output filename;
//! * in MIMO mode the map application is started once and reads multiple
//!   lines of "input output" pairs from a generated file (Fig 11/17).
//!
//! The [`MapApp`] / [`MapInstance`] split makes the paper's central cost
//! explicit: **`startup()` is the expensive application launch** (MATLAB
//! interpreter boot in the paper; PJRT client + XLA compile here), and
//! `process()` is the cheap per-file work.  SISO pays `startup()` per
//! file; MIMO pays it once per array task.

pub mod command;
pub mod image;
pub mod matmul;
pub mod registry;
pub mod wordcount;

use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::error::Result;
use crate::options::AppType;

/// Cost hints for the discrete-event simulator, used when a study runs in
/// pure-timing mode (no real data).  Values come from calibration runs on
/// the local engine (`scheduler::cost::Calibration`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostHint {
    /// One application launch (the paper's "startup overhead").
    pub startup: Duration,
    /// Processing one input file after launch.
    pub per_item: Duration,
}

impl Default for CostHint {
    fn default() -> Self {
        // Conservative defaults in the ratio the paper reports for MATLAB
        // image processing (startup dominates short per-file work).
        CostHint {
            startup: Duration::from_millis(100),
            per_item: Duration::from_millis(10),
        }
    }
}

/// A map application factory.  One `MapApp` is shared by all array tasks;
/// each launch materializes a [`MapInstance`].
pub trait MapApp: Send + Sync {
    /// Application name (used as the scheduler job name, like
    /// `MatlabCmd.sh` in Fig 8).
    fn name(&self) -> &str;

    /// Launch the application — this is the expensive step whose repeated
    /// cost the MIMO option eliminates.  Implementations must do their
    /// real initialization here (load reference data, compile the XLA
    /// executable, ...), not lazily in `process`.
    fn startup(&self) -> Result<Box<dyn MapInstance>>;

    /// Cost hints for simulator-only studies.
    fn cost_hint(&self) -> CostHint {
        CostHint::default()
    }

    /// Wire identity for the remote engine: a spec string that
    /// [`crate::apps::registry::resolve_mapper`] on a worker daemon
    /// resolves back to an equivalent app.  Defaults to the plain name
    /// (correct for stateless built-ins); apps carrying construction
    /// state the resolver understands — an ignore file, an argv —
    /// override so that state survives the trip.  Apps that only exist
    /// in-process (test doubles) keep the default and simply fail to
    /// resolve worker-side, failing the job with a clear error.
    fn wire_spec(&self) -> String {
        self.name().to_string()
    }
}

/// A launched map application instance.
pub trait MapInstance {
    /// Process one (input, output) pair — the body of the paper's mapper.
    fn process(&mut self, input: &Path, output: &Path) -> Result<()>;

    /// Consume a whole packed batch through this one persistent instance
    /// — the SPMD morph's streaming entry point (`--spmd`).  The default
    /// simply drives [`MapInstance::process`] per pair, so every app is
    /// batch-capable for free and ganged execution is observationally
    /// identical to per-item execution.  Apps with a cheaper bulk path
    /// (a child process consuming an item stream on stdin, a shared
    /// decode buffer) override for true instance reuse; overrides must
    /// process pairs **in order** and fail the whole batch on the first
    /// error, exactly like the default, so retries and byte-identity
    /// guarantees hold on every engine.
    fn run_batch(
        &mut self,
        pairs: &[(PathBuf, PathBuf)],
    ) -> Result<()> {
        for (input, output) in pairs {
            self.process(input, output)?;
        }
        Ok(())
    }
}

/// A reduce application: merges the map output directory into one file
/// (Fig 1 steps 4–5).
pub trait ReduceApp: Send + Sync {
    fn name(&self) -> &str;

    /// Wire identity for the remote engine (see [`MapApp::wire_spec`]);
    /// resolved worker-side by
    /// [`crate::apps::registry::resolve_reducer`].
    fn wire_spec(&self) -> String {
        self.name().to_string()
    }

    /// Scan `map_output_dir` and write the merged result to `out_file`.
    fn reduce(&self, map_output_dir: &Path, out_file: &Path) -> Result<()>;

    /// Whether this reducer can fold partials (overlapped mode).
    /// **Opt-in**: the default is `false`, and the pipeline falls back
    /// to the Fig 1 barrier for reducers that never declared support —
    /// a reducer whose `reduce` depends on seeing the *real* mapper
    /// output files (boundaries, names, one-record formats) must not be
    /// silently fed concatenated partials.  Return `true` only after
    /// checking `reduce_partial` (the concat default or an override) is
    /// associative with your `reduce`.
    fn supports_partial(&self) -> bool {
        false
    }

    /// Fold one completed mapper task's output `files` into the partial
    /// file `out_file` — the overlapped pipeline's eager consumption step
    /// (`--overlap=true`, DESIGN.md §4).  The final [`ReduceApp::reduce`]
    /// pass later runs over the *directory of partial files*, so the
    /// partial output format must be readable by `reduce` and the fold
    /// must be associative: `reduce(partials) == reduce(mapper outputs)`.
    ///
    /// The default byte-concatenates the inputs, which is associative for
    /// line-oriented merges (concatenation, word-count files).  Reducers
    /// whose `reduce` reads one record per file must override — see
    /// `FrobeniusSumReducer` in [`crate::apps::matmul`].
    fn reduce_partial(
        &self,
        files: &[PathBuf],
        out_file: &Path,
    ) -> Result<()> {
        let mut merged = Vec::new();
        for f in files {
            merged.extend(
                std::fs::read(f)
                    .map_err(|e| crate::error::Error::io(f.clone(), e))?,
            );
        }
        std::fs::write(out_file, merged).map_err(|e| {
            crate::error::Error::io(out_file.to_path_buf(), e)
        })
    }
}

/// Blanket helper: run a full SISO, MIMO, or SPMD task over an
/// instance-producing app, returning (startup_total, compute_total,
/// launches).  Shared by the local engine, the executing simulator, and
/// the remote worker daemon.
pub fn run_map_task(
    app: &dyn MapApp,
    pairs: &[(std::path::PathBuf, std::path::PathBuf)],
    mode: AppType,
) -> Result<(Duration, Duration, usize)> {
    let mut startup_total = Duration::ZERO;
    let mut compute_total = Duration::ZERO;
    let mut launches = 0usize;

    match mode {
        AppType::Siso => {
            for (input, output) in pairs {
                let t0 = std::time::Instant::now();
                let mut inst = app.startup()?;
                startup_total += t0.elapsed();
                launches += 1;
                let t1 = std::time::Instant::now();
                inst.process(input, output)?;
                compute_total += t1.elapsed();
            }
        }
        AppType::Mimo => {
            if pairs.is_empty() {
                return Ok((Duration::ZERO, Duration::ZERO, 0));
            }
            let t0 = std::time::Instant::now();
            let mut inst = app.startup()?;
            startup_total += t0.elapsed();
            launches += 1;
            for (input, output) in pairs {
                let t1 = std::time::Instant::now();
                inst.process(input, output)?;
                compute_total += t1.elapsed();
            }
        }
        AppType::Spmd => {
            // One persistent instance consumes the whole batch through
            // the streaming entry point; the single `run_batch` call is
            // the task's compute span.
            if pairs.is_empty() {
                return Ok((Duration::ZERO, Duration::ZERO, 0));
            }
            let t0 = std::time::Instant::now();
            let mut inst = app.startup()?;
            startup_total += t0.elapsed();
            launches += 1;
            let t1 = std::time::Instant::now();
            inst.run_batch(pairs)?;
            compute_total += t1.elapsed();
        }
    }
    Ok((startup_total, compute_total, launches))
}

#[cfg(test)]
pub(crate) mod testutil {
    //! A trivially-instrumented app for engine and pipeline tests.
    use super::*;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    /// Counts startups and processed files; "processes" by copying the
    /// input file to the output path with a marker line appended.
    pub struct CountingApp {
        pub startups: Arc<AtomicUsize>,
        pub processed: Arc<AtomicUsize>,
        /// `run_batch` invocations (SPMD path instrumentation).
        pub batches: Arc<AtomicUsize>,
        /// Optional synthetic startup work to make timing visible.
        pub startup_spin: Duration,
        /// Fail processing of files whose name contains this marker.
        pub poison: Option<String>,
    }

    impl CountingApp {
        pub fn new() -> Self {
            CountingApp {
                startups: Arc::new(AtomicUsize::new(0)),
                processed: Arc::new(AtomicUsize::new(0)),
                batches: Arc::new(AtomicUsize::new(0)),
                startup_spin: Duration::ZERO,
                poison: None,
            }
        }
    }

    pub struct CountingInstance {
        processed: Arc<AtomicUsize>,
        batches: Arc<AtomicUsize>,
        poison: Option<String>,
    }

    impl MapApp for CountingApp {
        fn name(&self) -> &str {
            "counting-app"
        }

        fn startup(&self) -> Result<Box<dyn MapInstance>> {
            if !self.startup_spin.is_zero() {
                let t = std::time::Instant::now();
                while t.elapsed() < self.startup_spin {
                    std::hint::spin_loop();
                }
            }
            self.startups.fetch_add(1, Ordering::SeqCst);
            Ok(Box::new(CountingInstance {
                processed: self.processed.clone(),
                batches: self.batches.clone(),
                poison: self.poison.clone(),
            }))
        }
    }

    impl MapInstance for CountingInstance {
        fn process(&mut self, input: &Path, output: &Path) -> Result<()> {
            if let Some(p) = &self.poison {
                if input.to_string_lossy().contains(p.as_str()) {
                    return Err(crate::error::Error::App {
                        app: "counting-app".into(),
                        input: input.to_path_buf(),
                        reason: "poisoned input".into(),
                    });
                }
            }
            let data = std::fs::read_to_string(input).unwrap_or_default();
            std::fs::write(output, format!("{data}#mapped\n")).map_err(
                |e| crate::error::Error::io(output.to_path_buf(), e),
            )?;
            self.processed.fetch_add(1, Ordering::SeqCst);
            Ok(())
        }

        // Count batch entries, then defer to the per-item default — the
        // instrumentation proves the SPMD path was taken without
        // changing what gets written.
        fn run_batch(
            &mut self,
            pairs: &[(PathBuf, PathBuf)],
        ) -> Result<()> {
            self.batches.fetch_add(1, Ordering::SeqCst);
            for (input, output) in pairs {
                self.process(input, output)?;
            }
            Ok(())
        }
    }

    /// Reducer that concatenates all files in the directory (sorted).
    pub struct ConcatReducer;

    impl ReduceApp for ConcatReducer {
        fn name(&self) -> &str {
            "concat-reducer"
        }

        // Concatenation is associative with the default partial fold.
        fn supports_partial(&self) -> bool {
            true
        }

        fn reduce(&self, dir: &Path, out: &Path) -> Result<()> {
            let mut names: Vec<PathBuf> = std::fs::read_dir(dir)
                .map_err(|e| crate::error::Error::io(dir.to_path_buf(), e))?
                .filter_map(|e| e.ok())
                .map(|e| e.path())
                .filter(|p| p.is_file())
                .collect();
            names.sort();
            let mut merged = String::new();
            for n in names {
                merged.push_str(&std::fs::read_to_string(&n).unwrap_or_default());
            }
            std::fs::write(out, merged)
                .map_err(|e| crate::error::Error::io(out.to_path_buf(), e))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;
    use std::fs;
    use std::path::PathBuf;
    use std::sync::atomic::Ordering;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-apps-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn mk_pairs(dir: &PathBuf, n: usize) -> Vec<(PathBuf, PathBuf)> {
        (0..n)
            .map(|i| {
                let inp = dir.join(format!("f{i}.dat"));
                fs::write(&inp, format!("data{i}\n")).unwrap();
                (inp, dir.join(format!("f{i}.dat.out")))
            })
            .collect()
    }

    #[test]
    fn siso_starts_once_per_file() {
        let d = tmp("siso");
        let app = CountingApp::new();
        let pairs = mk_pairs(&d, 5);
        let (_s, _c, launches) = run_map_task(&app, &pairs, AppType::Siso).unwrap();
        assert_eq!(launches, 5);
        assert_eq!(app.startups.load(Ordering::SeqCst), 5);
        assert_eq!(app.processed.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn mimo_starts_once_per_task() {
        let d = tmp("mimo");
        let app = CountingApp::new();
        let pairs = mk_pairs(&d, 5);
        let (_s, _c, launches) = run_map_task(&app, &pairs, AppType::Mimo).unwrap();
        assert_eq!(launches, 1);
        assert_eq!(app.startups.load(Ordering::SeqCst), 1);
        assert_eq!(app.processed.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn spmd_starts_once_and_takes_the_batch_path() {
        let d = tmp("spmd");
        let app = CountingApp::new();
        let pairs = mk_pairs(&d, 5);
        let (_s, _c, launches) =
            run_map_task(&app, &pairs, AppType::Spmd).unwrap();
        assert_eq!(launches, 1);
        assert_eq!(app.startups.load(Ordering::SeqCst), 1);
        assert_eq!(app.processed.load(Ordering::SeqCst), 5);
        assert_eq!(
            app.batches.load(Ordering::SeqCst),
            1,
            "spmd mode must go through run_batch"
        );
        for (_, out) in &pairs {
            assert!(fs::read_to_string(out).unwrap().ends_with("#mapped\n"));
        }
    }

    #[test]
    fn spmd_empty_task_never_launches() {
        let app = CountingApp::new();
        let (_s, _c, launches) =
            run_map_task(&app, &[], AppType::Spmd).unwrap();
        assert_eq!(launches, 0);
        assert_eq!(app.startups.load(Ordering::SeqCst), 0);
        assert_eq!(app.batches.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn default_run_batch_matches_per_item_path() {
        // An instance that never overrides run_batch still processes the
        // whole batch, in order, via the default.
        struct Plain(Vec<String>);
        impl MapInstance for Plain {
            fn process(&mut self, input: &Path, output: &Path) -> Result<()> {
                self.0.push(input.display().to_string());
                std::fs::write(output, b"x").map_err(|e| {
                    crate::error::Error::io(output.to_path_buf(), e)
                })
            }
        }
        let d = tmp("default-batch");
        let pairs: Vec<(PathBuf, PathBuf)> = (0..4)
            .map(|i| {
                let inp = d.join(format!("in{i}"));
                fs::write(&inp, "d").unwrap();
                (inp, d.join(format!("out{i}")))
            })
            .collect();
        let mut inst = Plain(Vec::new());
        inst.run_batch(&pairs).unwrap();
        let order: Vec<String> =
            pairs.iter().map(|(i, _)| i.display().to_string()).collect();
        assert_eq!(inst.0, order, "default preserves item order");
        for (_, out) in &pairs {
            assert!(out.exists());
        }
    }

    #[test]
    fn mimo_empty_task_never_launches() {
        let app = CountingApp::new();
        let (_s, _c, launches) = run_map_task(&app, &[], AppType::Mimo).unwrap();
        assert_eq!(launches, 0);
        assert_eq!(app.startups.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn outputs_written() {
        let d = tmp("outputs");
        let app = CountingApp::new();
        let pairs = mk_pairs(&d, 3);
        run_map_task(&app, &pairs, AppType::Mimo).unwrap();
        for (_, out) in &pairs {
            let text = fs::read_to_string(out).unwrap();
            assert!(text.ends_with("#mapped\n"));
        }
    }

    #[test]
    fn startup_cost_amortized_in_mimo() {
        let d = tmp("amortize");
        let mut app = CountingApp::new();
        app.startup_spin = Duration::from_millis(3);
        let pairs = mk_pairs(&d, 4);
        let (siso_startup, _, _) = run_map_task(&app, &pairs, AppType::Siso).unwrap();
        let (mimo_startup, _, _) = run_map_task(&app, &pairs, AppType::Mimo).unwrap();
        // 4 launches vs 1: SISO startup must be several times larger.
        assert!(
            siso_startup > mimo_startup * 2,
            "siso={siso_startup:?} mimo={mimo_startup:?}"
        );
    }

    #[test]
    fn failing_process_propagates() {
        let d = tmp("poison");
        let mut app = CountingApp::new();
        app.poison = Some("f1".into());
        let pairs = mk_pairs(&d, 3);
        let err = run_map_task(&app, &pairs, AppType::Siso).unwrap_err();
        assert!(err.to_string().contains("poisoned"));
    }

    #[test]
    fn reducer_merges_sorted() {
        let d = tmp("reduce");
        fs::write(d.join("b.out"), "B\n").unwrap();
        fs::write(d.join("a.out"), "A\n").unwrap();
        let out = d.join("merged");
        ConcatReducer.reduce(&d, &out).unwrap();
        assert_eq!(fs::read_to_string(out).unwrap(), "A\nB\n");
    }

    #[test]
    fn default_reduce_partial_concatenates_then_reduces_associatively() {
        let d = tmp("partial");
        fs::write(d.join("a.out"), "A\n").unwrap();
        fs::write(d.join("b.out"), "B\n").unwrap();
        fs::write(d.join("c.out"), "C\n").unwrap();
        // Overlapped shape: two partials over task-grouped outputs...
        let pdir = d.join("partials");
        fs::create_dir_all(&pdir).unwrap();
        ConcatReducer
            .reduce_partial(
                &[d.join("a.out"), d.join("b.out")],
                &pdir.join("part_1"),
            )
            .unwrap();
        ConcatReducer
            .reduce_partial(&[d.join("c.out")], &pdir.join("part_2"))
            .unwrap();
        // ...then the final pass over the partials directory must equal
        // a direct reduce over all three mapper outputs.
        let overlapped = d.join("overlapped");
        ConcatReducer.reduce(&pdir, &overlapped).unwrap();
        assert_eq!(
            fs::read_to_string(&overlapped).unwrap(),
            "A\nB\nC\n",
            "partial fold is associative for concat"
        );
    }
}
