//! Image conversion map application (§III-A).
//!
//! The Rust + XLA analogue of the paper's MATLAB `imageConvert()`:
//! read an RGB image, convert to gray scale, write the result.  Images are
//! PPM (P6) in, PGM (P5) out — simple formats a synthetic workload
//! generator can produce byte-exactly.
//!
//! The compute is the AOT-compiled `image_convert` artifact (L2 JAX graph
//! over the L1 Pallas grayscale kernel).  `startup()` compiles the
//! artifact — the expensive launch the MIMO option amortizes, standing in
//! for MATLAB's interpreter boot (DESIGN.md §3).

use std::path::Path;
use std::sync::Arc;

use crate::apps::{CostHint, MapApp, MapInstance};
use crate::error::{Error, IoContext, Result};
use crate::runtime::{ArtifactEntry, Manifest, XlaExecutable};

/// A decoded RGB image, f32 planes in [0, 1].
#[derive(Debug, Clone, PartialEq)]
pub struct Image {
    pub width: usize,
    pub height: usize,
    /// HWC interleaved, length = height*width*3.
    pub rgb: Vec<f32>,
}

/// Read a binary PPM (P6, maxval 255).
pub fn read_ppm(path: &Path) -> Result<Image> {
    let data = std::fs::read(path).at(path)?;
    let mut p = HeaderParser { data: &data, pos: 0 };
    let magic = p.token(path)?;
    if magic != b"P6" {
        return Err(Error::Format {
            kind: "ppm",
            path: path.to_path_buf(),
            reason: format!("bad magic {:?}", String::from_utf8_lossy(magic)),
        });
    }
    let width = p.number(path)?;
    let height = p.number(path)?;
    let maxval = p.number(path)?;
    if maxval != 255 {
        return Err(Error::Format {
            kind: "ppm",
            path: path.to_path_buf(),
            reason: format!("unsupported maxval {maxval}"),
        });
    }
    p.single_whitespace();
    let need = width * height * 3;
    let pixels = &p.data[p.pos..];
    if pixels.len() < need {
        return Err(Error::Format {
            kind: "ppm",
            path: path.to_path_buf(),
            reason: format!("short pixel data: {} < {need}", pixels.len()),
        });
    }
    let rgb = pixels[..need].iter().map(|&b| b as f32 / 255.0).collect();
    Ok(Image { width, height, rgb })
}

/// Write a binary PPM (P6, maxval 255) from f32 [0, 1] HWC data.
pub fn write_ppm(path: &Path, img: &Image) -> Result<()> {
    let mut out =
        format!("P6\n{} {}\n255\n", img.width, img.height).into_bytes();
    out.extend(img.rgb.iter().map(|&v| quantize(v)));
    std::fs::write(path, out).at(path)
}

/// Write a binary PGM (P5, maxval 255) from f32 [0, 1] gray data.
pub fn write_pgm(
    path: &Path,
    width: usize,
    height: usize,
    gray: &[f32],
) -> Result<()> {
    debug_assert_eq!(gray.len(), width * height);
    let mut out = format!("P5\n{width} {height}\n255\n").into_bytes();
    out.extend(gray.iter().map(|&v| quantize(v)));
    std::fs::write(path, out).at(path)
}

/// Read a binary PGM (P5, maxval 255) into f32 [0, 1].
pub fn read_pgm(path: &Path) -> Result<(usize, usize, Vec<f32>)> {
    let data = std::fs::read(path).at(path)?;
    let mut p = HeaderParser { data: &data, pos: 0 };
    let magic = p.token(path)?;
    if magic != b"P5" {
        return Err(Error::Format {
            kind: "pgm",
            path: path.to_path_buf(),
            reason: "bad magic".into(),
        });
    }
    let width = p.number(path)?;
    let height = p.number(path)?;
    let _maxval = p.number(path)?;
    p.single_whitespace();
    let need = width * height;
    let gray = p.data[p.pos..p.pos + need]
        .iter()
        .map(|&b| b as f32 / 255.0)
        .collect();
    Ok((width, height, gray))
}

fn quantize(v: f32) -> u8 {
    (v.clamp(0.0, 1.0) * 255.0).round() as u8
}

struct HeaderParser<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> HeaderParser<'a> {
    fn skip_ws_and_comments(&mut self) {
        loop {
            while self
                .data
                .get(self.pos)
                .is_some_and(|b| b.is_ascii_whitespace())
            {
                self.pos += 1;
            }
            if self.data.get(self.pos) == Some(&b'#') {
                while self.data.get(self.pos).is_some_and(|&b| b != b'\n') {
                    self.pos += 1;
                }
            } else {
                break;
            }
        }
    }

    fn token(&mut self, path: &Path) -> Result<&'a [u8]> {
        self.skip_ws_and_comments();
        let start = self.pos;
        while self
            .data
            .get(self.pos)
            .is_some_and(|b| !b.is_ascii_whitespace())
        {
            self.pos += 1;
        }
        if start == self.pos {
            return Err(Error::Format {
                kind: "pnm",
                path: path.to_path_buf(),
                reason: "truncated header".into(),
            });
        }
        Ok(&self.data[start..self.pos])
    }

    fn number(&mut self, path: &Path) -> Result<usize> {
        let tok = self.token(path)?;
        std::str::from_utf8(tok)
            .ok()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Format {
                kind: "pnm",
                path: path.to_path_buf(),
                reason: "bad number in header".into(),
            })
    }

    /// Exactly one whitespace byte separates header and pixels.
    fn single_whitespace(&mut self) {
        if self
            .data
            .get(self.pos)
            .is_some_and(|b| b.is_ascii_whitespace())
        {
            self.pos += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// The map application
// ---------------------------------------------------------------------------

/// `imageConvert` as an LLMapReduce map application.
///
/// Generic over the bound artifact: `new` binds the plain grayscale
/// `image_convert`; [`ImageConvertApp::pipeline`] binds the richer
/// `image_pipeline` (grayscale + 3x3 blur — the Table II-style
/// multi-stage image processing).  Both share the (H, W, 3) -> (H, W)
/// contract.
pub struct ImageConvertApp {
    entry: ArtifactEntry,
    name: &'static str,
    /// Expected image shape from the artifact manifest (H, W).
    height: usize,
    width: usize,
}

impl ImageConvertApp {
    /// Bind to the `image_convert` artifact in `manifest`.
    pub fn new(manifest: &Manifest) -> Result<Arc<Self>> {
        Self::bind(manifest, "image_convert", "imageconvert")
    }

    /// Bind to the `image_pipeline` artifact (grayscale + box blur).
    pub fn pipeline(manifest: &Manifest) -> Result<Arc<Self>> {
        Self::bind(manifest, "image_pipeline", "imagepipeline")
    }

    fn bind(
        manifest: &Manifest,
        artifact: &str,
        name: &'static str,
    ) -> Result<Arc<Self>> {
        let entry = manifest.entry(artifact)?.clone();
        let shape = &entry.inputs[0].shape;
        if shape.len() != 3 || shape[2] != 3 {
            return Err(Error::Artifact {
                name: artifact.into(),
                reason: format!("unexpected shape {shape:?}"),
            });
        }
        Ok(Arc::new(ImageConvertApp {
            height: shape[0],
            width: shape[1],
            name,
            entry,
        }))
    }

    pub fn image_shape(&self) -> (usize, usize) {
        (self.height, self.width)
    }
}

impl MapApp for ImageConvertApp {
    fn name(&self) -> &str {
        self.name
    }

    fn startup(&self) -> Result<Box<dyn MapInstance>> {
        // The expensive launch: XLA-compile the artifact.
        let exe = XlaExecutable::from_entry(&self.entry)?;
        Ok(Box::new(ImageConvertInstance {
            exe,
            height: self.height,
            width: self.width,
        }))
    }

    fn cost_hint(&self) -> CostHint {
        // Refined by calibration at bench time; these are ballpark values
        // measured on this container (compile ~15ms, convert ~1ms).
        CostHint {
            startup: std::time::Duration::from_millis(15),
            per_item: std::time::Duration::from_millis(1),
        }
    }
}

struct ImageConvertInstance {
    exe: XlaExecutable,
    height: usize,
    width: usize,
}

impl MapInstance for ImageConvertInstance {
    fn process(&mut self, input: &Path, output: &Path) -> Result<()> {
        let img = read_ppm(input)?;
        if (img.height, img.width) != (self.height, self.width) {
            return Err(Error::App {
                app: "imageconvert".into(),
                input: input.to_path_buf(),
                reason: format!(
                    "image is {}x{}, artifact wants {}x{}",
                    img.height, img.width, self.height, self.width
                ),
            });
        }
        let gray = self.exe.run_f32(&[&img.rgb])?;
        write_pgm(output, img.width, img.height, &gray)
    }
}

/// Pure-Rust reference conversion (BT.601), used by tests to validate the
/// XLA path end-to-end.
pub fn grayscale_ref(img: &Image) -> Vec<f32> {
    const WR: f32 = 0.298936021293775;
    const WG: f32 = 0.587043074451121;
    const WB: f32 = 0.114020904255103;
    img.rgb
        .chunks_exact(3)
        .map(|px| WR * px[0] + WG * px[1] + WB * px[2])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::fs;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-img-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn random_image(h: usize, w: usize, seed: u64) -> Image {
        let mut rng = Rng::new(seed);
        Image {
            width: w,
            height: h,
            rgb: (0..h * w * 3).map(|_| rng.next_f32()).collect(),
        }
    }

    #[test]
    fn ppm_roundtrip() {
        let d = tmp("roundtrip");
        let img = random_image(16, 24, 1);
        let p = d.join("x.ppm");
        write_ppm(&p, &img).unwrap();
        let back = read_ppm(&p).unwrap();
        assert_eq!(back.width, 24);
        assert_eq!(back.height, 16);
        // Quantization error at most 1/255 per channel (plus rounding).
        for (a, b) in img.rgb.iter().zip(&back.rgb) {
            assert!((a - b).abs() <= 0.5 / 255.0 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn ppm_with_comments() {
        let d = tmp("comments");
        let p = d.join("c.ppm");
        let mut bytes = b"P6\n# a comment\n2 1\n255\n".to_vec();
        bytes.extend([255, 0, 0, 0, 255, 0]);
        fs::write(&p, bytes).unwrap();
        let img = read_ppm(&p).unwrap();
        assert_eq!((img.width, img.height), (2, 1));
        assert!((img.rgb[0] - 1.0).abs() < 1e-6);
        assert!((img.rgb[4] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn ppm_rejects_bad_magic_and_truncation() {
        let d = tmp("bad");
        let p = d.join("bad.ppm");
        fs::write(&p, b"P5\n1 1\n255\nxxx").unwrap();
        assert!(read_ppm(&p).is_err());
        fs::write(&p, b"P6\n4 4\n255\nxx").unwrap();
        let err = read_ppm(&p).unwrap_err().to_string();
        assert!(err.contains("short pixel data"), "{err}");
    }

    #[test]
    fn pgm_roundtrip() {
        let d = tmp("pgm");
        let p = d.join("g.pgm");
        let gray: Vec<f32> = (0..12).map(|i| i as f32 / 11.0).collect();
        write_pgm(&p, 4, 3, &gray).unwrap();
        let (w, h, back) = read_pgm(&p).unwrap();
        assert_eq!((w, h), (4, 3));
        for (a, b) in gray.iter().zip(&back) {
            assert!((a - b).abs() <= 0.5 / 255.0 + 1e-6);
        }
    }

    #[test]
    fn grayscale_ref_weights() {
        let img = Image {
            width: 1,
            height: 1,
            rgb: vec![1.0, 1.0, 1.0],
        };
        let g = grayscale_ref(&img);
        assert!((g[0] - 1.0).abs() < 1e-6, "white stays white");
    }

    // -- XLA-backed tests (skip silently when artifacts absent) ------------

    #[test]
    fn image_convert_app_matches_ref() {
        let Ok(m) = Manifest::discover() else { return };
        let app = ImageConvertApp::new(&m).unwrap();
        let (h, w) = app.image_shape();
        let d = tmp("app");
        let img = random_image(h, w, 42);
        let inp = d.join("in.ppm");
        let out = d.join("in.ppm.out");
        write_ppm(&inp, &img).unwrap();

        let mut inst = app.startup().unwrap();
        inst.process(&inp, &out).unwrap();

        let (ow, oh, gray) = read_pgm(&out).unwrap();
        assert_eq!((ow, oh), (w, h));
        // Compare against the pure-Rust reference on the *quantized* input.
        let quantized = read_ppm(&inp).unwrap();
        let expect = grayscale_ref(&quantized);
        for (a, b) in gray.iter().zip(&expect) {
            assert!((a - b).abs() <= 1.0 / 255.0 + 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn image_pipeline_app_blurs() {
        let Ok(m) = Manifest::discover() else { return };
        let Ok(app) = ImageConvertApp::pipeline(&m) else { return };
        let (h, w) = app.image_shape();
        let d = tmp("pipeline");
        // A white image stays ~white inside; borders darken (zero pad).
        let img = Image {
            width: w,
            height: h,
            rgb: vec![1.0; h * w * 3],
        };
        let inp = d.join("white.ppm");
        let out = d.join("white.ppm.out");
        write_ppm(&inp, &img).unwrap();
        let mut inst = app.startup().unwrap();
        inst.process(&inp, &out).unwrap();
        let (_, _, gray) = read_pgm(&out).unwrap();
        let center = gray[(h / 2) * w + w / 2];
        let corner = gray[0];
        assert!((center - 1.0).abs() < 2.0 / 255.0, "center {center}");
        assert!(corner < center, "borders darkened by zero padding");
    }

    #[test]
    fn image_convert_rejects_wrong_size() {
        let Ok(m) = Manifest::discover() else { return };
        let app = ImageConvertApp::new(&m).unwrap();
        let d = tmp("wrongsize");
        let img = random_image(8, 8, 1);
        let inp = d.join("small.ppm");
        write_ppm(&inp, &img).unwrap();
        let mut inst = app.startup().unwrap();
        let err = inst
            .process(&inp, &d.join("small.out"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("artifact wants"), "{err}");
    }
}
