//! Word-frequency counting — the paper's Java use case (§III-B).
//!
//! The Rust analogue of Swartz's `WordFrequencyCmd` [42]: the mapper
//! counts word frequencies in one text file, ignoring words listed in a
//! reference file (`textignore.txt`); the reducer scans the map output
//! directory and merges the counts into a single file.
//!
//! `startup()` loads and indexes the ignore list — the per-launch cost a
//! JVM boot carries in the paper.  An optional deterministic spin can be
//! added to model heavier interpreters for overhead studies.

use std::collections::BTreeMap;
use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use crate::apps::{CostHint, MapApp, MapInstance, ReduceApp};
use crate::error::{Error, IoContext, Result};

/// Case-folded word iterator: alphanumeric runs, lowercased.
/// (Matching the common word-count convention; apostrophes split.)
fn words(text: &str) -> impl Iterator<Item = String> + '_ {
    text.split(|c: char| !c.is_alphanumeric())
        .filter(|w| !w.is_empty())
        .map(|w| w.to_lowercase())
}

/// Count words in `text`, skipping `ignore`.
pub fn count_words(
    text: &str,
    ignore: &HashSet<String>,
) -> BTreeMap<String, u64> {
    let mut counts = BTreeMap::new();
    for w in words(text) {
        if !ignore.contains(&w) {
            *counts.entry(w).or_insert(0) += 1;
        }
    }
    counts
}

/// Serialize counts: `<word> <count>` per line, words sorted.
pub fn write_counts(
    path: &Path,
    counts: &BTreeMap<String, u64>,
) -> Result<()> {
    use std::fmt::Write as _;
    let mut out = String::new();
    for (w, c) in counts {
        let _ = writeln!(out, "{w} {c}");
    }
    std::fs::write(path, out).at(path)
}

/// Parse a counts file back.
pub fn read_counts(path: &Path) -> Result<BTreeMap<String, u64>> {
    let text = std::fs::read_to_string(path).at(path)?;
    let mut counts = BTreeMap::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(w), Some(c)) = (it.next(), it.next()) else {
            return Err(Error::Format {
                kind: "wordcount",
                path: path.to_path_buf(),
                reason: format!("line {}: bad entry", lineno + 1),
            });
        };
        let c: u64 = c.parse().map_err(|_| Error::Format {
            kind: "wordcount",
            path: path.to_path_buf(),
            reason: format!("line {}: bad count", lineno + 1),
        })?;
        *counts.entry(w.to_string()).or_insert(0) += c;
    }
    Ok(counts)
}

/// The word-frequency mapper (`WordFreqCmd.sh` analogue).
pub struct WordCountApp {
    /// Path of the ignore-list reference file (the third argument of the
    /// paper's Java command, bound at construction like the wrapper
    /// script binds `textignore.txt`).
    ignore_file: Option<PathBuf>,
    /// Synthetic extra startup (models a heavy interpreter for overhead
    /// studies; zero by default).
    pub startup_spin: Duration,
}

impl WordCountApp {
    pub fn new(ignore_file: Option<PathBuf>) -> Arc<Self> {
        Arc::new(WordCountApp {
            ignore_file,
            startup_spin: Duration::ZERO,
        })
    }

    pub fn with_startup_spin(
        ignore_file: Option<PathBuf>,
        spin: Duration,
    ) -> Arc<Self> {
        Arc::new(WordCountApp {
            ignore_file,
            startup_spin: spin,
        })
    }
}

impl MapApp for WordCountApp {
    fn name(&self) -> &str {
        "wordcount"
    }

    /// Remote workers must re-bind the same ignore file, so the wire
    /// spec carries it (`wordcount:<path>` — the CLI's spelling, which
    /// [`crate::apps::registry::resolve_mapper`] parses back).  A
    /// relative path is absolutized against this process's working
    /// directory first: workers share the filesystem, not the cwd.
    fn wire_spec(&self) -> String {
        match &self.ignore_file {
            Some(p) => format!(
                "wordcount:{}",
                crate::util::absolutize(p).display()
            ),
            None => "wordcount".to_string(),
        }
    }

    fn startup(&self) -> Result<Box<dyn MapInstance>> {
        if !self.startup_spin.is_zero() {
            let t = std::time::Instant::now();
            while t.elapsed() < self.startup_spin {
                std::hint::spin_loop();
            }
        }
        // Real launch work: load + index the reference file.
        let ignore = match &self.ignore_file {
            Some(p) => std::fs::read_to_string(p)
                .at(p)?
                .split_whitespace()
                .map(|w| w.to_lowercase())
                .collect(),
            None => HashSet::new(),
        };
        Ok(Box::new(WordCountInstance {
            ignore,
            buf: String::new(),
        }))
    }

    fn cost_hint(&self) -> CostHint {
        CostHint {
            startup: self.startup_spin.max(Duration::from_micros(200)),
            per_item: Duration::from_micros(500),
        }
    }
}

struct WordCountInstance {
    ignore: HashSet<String>,
    /// Read buffer reused across a batch (SPMD instance reuse: the
    /// ignore index is loaded once at startup and the I/O buffer is
    /// recycled item to item).
    buf: String,
}

impl WordCountInstance {
    fn count_one(&mut self, input: &Path, output: &Path) -> Result<()> {
        use std::io::Read as _;
        self.buf.clear();
        std::fs::File::open(input)
            .and_then(|mut f| f.read_to_string(&mut self.buf))
            .at(input)?;
        let counts = count_words(&self.buf, &self.ignore);
        write_counts(output, &counts)
    }
}

impl MapInstance for WordCountInstance {
    fn process(&mut self, input: &Path, output: &Path) -> Result<()> {
        self.count_one(input, output)
    }

    /// SPMD entry point: one persistent instance takes the whole batch.
    /// Identical arithmetic to per-item processing — counts are computed
    /// file by file against the startup-loaded ignore index — so ganged
    /// output is byte-identical to the per-task path.
    fn run_batch(&mut self, pairs: &[(PathBuf, PathBuf)]) -> Result<()> {
        for (input, output) in pairs {
            self.count_one(input, output)?;
        }
        Ok(())
    }
}

/// The merging reducer (`ReduceWordFrequencyCmd` analogue): scans the map
/// output directory and merges all counts into one file.
pub struct WordCountReducer;

impl ReduceApp for WordCountReducer {
    fn name(&self) -> &str {
        "wordcount-reducer"
    }

    fn reduce(&self, dir: &Path, out: &Path) -> Result<()> {
        let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
            .at(dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.is_file()
                    && *p != *out
                    && p.file_name()
                        .and_then(|n| n.to_str())
                        .is_some_and(|n| !n.starts_with('.'))
            })
            .collect();
        files.sort();
        write_counts(out, &merge_count_files(&files)?)
    }

    /// Overlapped mode: pre-merge one mapper task's count files into a
    /// single counts file.  Count merging is associative, so the final
    /// `reduce` over the partials directory yields exactly the barriered
    /// totals — with fewer, smaller files to scan at the end.
    fn reduce_partial(&self, files: &[PathBuf], out: &Path) -> Result<()> {
        write_counts(out, &merge_count_files(files)?)
    }

    fn supports_partial(&self) -> bool {
        true
    }
}

/// The one fold both reduce paths share: merge count files into totals.
fn merge_count_files(files: &[PathBuf]) -> Result<BTreeMap<String, u64>> {
    let mut merged: BTreeMap<String, u64> = BTreeMap::new();
    for f in files {
        for (w, c) in read_counts(f)? {
            *merged.entry(w).or_insert(0) += c;
        }
    }
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-wc-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn counts_basic() {
        let c = count_words("the cat and the hat", &HashSet::new());
        assert_eq!(c["the"], 2);
        assert_eq!(c["cat"], 1);
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn counts_case_folded_and_punctuation() {
        let c = count_words("The THE the, tHe. (the)", &HashSet::new());
        assert_eq!(c["the"], 5);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn ignore_list_respected() {
        let ignore: HashSet<String> =
            ["the", "and"].iter().map(|s| s.to_string()).collect();
        let c = count_words("the cat and the hat", &ignore);
        assert!(!c.contains_key("the"));
        assert!(!c.contains_key("and"));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn counts_file_roundtrip() {
        let d = tmp("roundtrip");
        let p = d.join("c.out");
        let mut counts = BTreeMap::new();
        counts.insert("apple".to_string(), 3u64);
        counts.insert("zebra".to_string(), 1u64);
        write_counts(&p, &counts).unwrap();
        assert_eq!(read_counts(&p).unwrap(), counts);
    }

    #[test]
    fn mapper_end_to_end_with_ignore_file() {
        let d = tmp("mapper");
        let ignore = d.join("textignore.txt");
        fs::write(&ignore, "a an the\n").unwrap();
        let inp = d.join("doc.txt");
        fs::write(&inp, "The quick brown fox jumps over a lazy dog the end")
            .unwrap();
        let out = d.join("doc.txt.out");
        let app = WordCountApp::new(Some(ignore));
        let mut inst = app.startup().unwrap();
        inst.process(&inp, &out).unwrap();
        let counts = read_counts(&out).unwrap();
        assert!(!counts.contains_key("the"));
        assert_eq!(counts["quick"], 1);
    }

    #[test]
    fn reducer_merges_across_files() {
        let d = tmp("reduce");
        fs::write(d.join("a.out"), "apple 2\nbanana 1\n").unwrap();
        fs::write(d.join("b.out"), "apple 3\ncherry 4\n").unwrap();
        let out = d.join("llmapreduce.out");
        WordCountReducer.reduce(&d, &out).unwrap();
        let merged = read_counts(&out).unwrap();
        assert_eq!(merged["apple"], 5);
        assert_eq!(merged["banana"], 1);
        assert_eq!(merged["cherry"], 4);
    }

    #[test]
    fn reducer_skips_hidden_and_self() {
        let d = tmp("skip");
        fs::write(d.join("a.out"), "x 1\n").unwrap();
        fs::write(d.join(".hidden"), "garbage not counts\n").unwrap();
        let out = d.join("llmapreduce.out");
        // Pre-existing output from an earlier run must not self-merge.
        fs::write(&out, "x 100\n").unwrap();
        WordCountReducer.reduce(&d, &out).unwrap();
        let merged = read_counts(&out).unwrap();
        assert_eq!(merged["x"], 1);
    }

    #[test]
    fn missing_ignore_file_fails_at_startup() {
        let app = WordCountApp::new(Some(PathBuf::from("/nonexistent/ign")));
        assert!(app.startup().is_err(), "startup loads the reference file");
    }

    #[test]
    fn batch_path_matches_per_item_output_bytes() {
        let d = tmp("batch");
        let ignore = d.join("textignore.txt");
        fs::write(&ignore, "the a\n").unwrap();
        let texts = ["the cat sat", "a dog ran the mile", "plain words"];
        let mut pairs = Vec::new();
        for (i, t) in texts.iter().enumerate() {
            let inp = d.join(format!("doc{i}.txt"));
            fs::write(&inp, t).unwrap();
            pairs.push((inp, d.join(format!("doc{i}.batch.out"))));
        }
        let app = WordCountApp::new(Some(ignore));
        // Ganged: one instance, one run_batch over all items.
        let mut inst = app.startup().unwrap();
        inst.run_batch(&pairs).unwrap();
        // Per-item: fresh instance per file.
        for (i, (inp, _)) in pairs.iter().enumerate() {
            let out = d.join(format!("doc{i}.solo.out"));
            app.startup().unwrap().process(inp, &out).unwrap();
            let batch = fs::read(d.join(format!("doc{i}.batch.out"))).unwrap();
            assert_eq!(fs::read(&out).unwrap(), batch, "file {i} differs");
        }
    }

    #[test]
    fn mimo_semantics_one_scan_of_ignore_list() {
        // MIMO reuses one instance: same results as fresh instances.
        let d = tmp("mimo");
        let inp1 = d.join("x.txt");
        let inp2 = d.join("y.txt");
        fs::write(&inp1, "alpha beta").unwrap();
        fs::write(&inp2, "beta gamma").unwrap();
        let app = WordCountApp::new(None);
        let mut inst = app.startup().unwrap();
        inst.process(&inp1, &d.join("x.out")).unwrap();
        inst.process(&inp2, &d.join("y.out")).unwrap();
        assert_eq!(read_counts(&d.join("x.out")).unwrap()["alpha"], 1);
        assert_eq!(read_counts(&d.join("y.out")).unwrap()["gamma"], 1);
    }
}
