//! Matrix chain-multiply map application — the §IV scalability workload.
//!
//! "a MATLAB code that reads in a list of square matrices and multiplies
//! the matrices.  512 input data files were created..."
//!
//! File format (`.mat` text, self-describing so generators and tests can
//! produce it):
//!
//! ```text
//! MATLIST <count> <n>
//! <n*n f32 values, whitespace separated>   x count
//! ```
//!
//! The map application chain-multiplies the matrices (via the AOT
//! `matmul_chain` artifact when the file matches its static (L, N) shape,
//! element-streaming through `matmul_pair` otherwise) and writes the
//! product plus its Frobenius norm to the output file.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::apps::{CostHint, MapApp, MapInstance};
use crate::error::{Error, IoContext, Result};
use crate::runtime::{ArtifactEntry, Manifest, XlaExecutable};

/// A list of square matrices from one input file.
#[derive(Debug, Clone, PartialEq)]
pub struct MatrixList {
    pub n: usize,
    /// `count` matrices, each n*n f32, concatenated.
    pub data: Vec<f32>,
}

impl MatrixList {
    pub fn count(&self) -> usize {
        self.data.len() / (self.n * self.n)
    }

    pub fn matrix(&self, i: usize) -> &[f32] {
        let sz = self.n * self.n;
        &self.data[i * sz..(i + 1) * sz]
    }
}

/// Read a MATLIST file.
pub fn read_matrix_list(path: &Path) -> Result<MatrixList> {
    let text = std::fs::read_to_string(path).at(path)?;
    let mut tokens = text.split_ascii_whitespace();
    let bad = |reason: String| Error::Format {
        kind: "matlist",
        path: path.to_path_buf(),
        reason,
    };
    if tokens.next() != Some("MATLIST") {
        return Err(bad("missing MATLIST magic".into()));
    }
    let count: usize = tokens
        .next()
        .and_then(|t| t.parse().ok())
        .ok_or_else(|| bad("bad count".into()))?;
    let n: usize = tokens
        .next()
        .and_then(|t| t.parse().ok())
        .ok_or_else(|| bad("bad n".into()))?;
    let need = count * n * n;
    let mut data = Vec::with_capacity(need);
    for tok in tokens.by_ref().take(need) {
        data.push(
            tok.parse::<f32>()
                .map_err(|_| bad(format!("bad value '{tok}'")))?,
        );
    }
    if data.len() != need {
        return Err(bad(format!("expected {need} values, got {}", data.len())));
    }
    Ok(MatrixList { n, data })
}

/// Write a MATLIST file.
pub fn write_matrix_list(path: &Path, list: &MatrixList) -> Result<()> {
    use std::fmt::Write as _;
    let mut out = format!("MATLIST {} {}\n", list.count(), list.n);
    for m in 0..list.count() {
        let mat = list.matrix(m);
        for row in mat.chunks(list.n) {
            for (i, v) in row.iter().enumerate() {
                if i > 0 {
                    out.push(' ');
                }
                let _ = write!(out, "{v}");
            }
            out.push('\n');
        }
    }
    std::fs::write(path, out).at(path)
}

/// Pure-Rust chain product for validation (row-major, f32).
pub fn chain_product_ref(list: &MatrixList) -> Vec<f32> {
    let n = list.n;
    let mut acc = list.matrix(0).to_vec();
    let mut next = vec![0f32; n * n];
    for m in 1..list.count() {
        let b = list.matrix(m);
        for i in 0..n {
            for k in 0..n {
                let a = acc[i * n + k];
                if a == 0.0 {
                    continue;
                }
                let brow = &b[k * n..(k + 1) * n];
                let orow = &mut next[i * n..(i + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += a * bv;
                }
            }
        }
        std::mem::swap(&mut acc, &mut next);
        next.iter_mut().for_each(|v| *v = 0.0);
    }
    acc
}

/// Frobenius norm.
pub fn frobenius(m: &[f32]) -> f32 {
    m.iter().map(|v| v * v).sum::<f32>().sqrt()
}

/// Output format written by the app: `MATRESULT <n>` header, the product
/// matrix, then `FROBENIUS <value>`.
pub fn write_result(path: &Path, n: usize, product: &[f32]) -> Result<()> {
    use std::fmt::Write as _;
    let mut out = format!("MATRESULT {n}\n");
    for row in product.chunks(n) {
        for (i, v) in row.iter().enumerate() {
            if i > 0 {
                out.push(' ');
            }
            let _ = write!(out, "{v}");
        }
        out.push('\n');
    }
    let _ = writeln!(out, "FROBENIUS {}", frobenius(product));
    std::fs::write(path, out).at(path)
}

/// Parse the Frobenius line back from a result file (used by the reducer).
pub fn read_result_frobenius(path: &Path) -> Result<f32> {
    let text = std::fs::read_to_string(path).at(path)?;
    for line in text.lines().rev() {
        if let Some(v) = line.strip_prefix("FROBENIUS ") {
            return v.trim().parse().map_err(|_| Error::Format {
                kind: "matresult",
                path: path.to_path_buf(),
                reason: "bad FROBENIUS value".into(),
            });
        }
    }
    Err(Error::Format {
        kind: "matresult",
        path: path.to_path_buf(),
        reason: "no FROBENIUS line".into(),
    })
}

// ---------------------------------------------------------------------------
// The map application
// ---------------------------------------------------------------------------

/// The matrix chain-multiply mapper over the AOT artifacts.
pub struct MatmulChainApp {
    chain_entry: ArtifactEntry,
    pair_entry: ArtifactEntry,
    /// Static (L, N) of the `matmul_chain` artifact.
    chain_len: usize,
    n: usize,
}

impl MatmulChainApp {
    pub fn new(manifest: &Manifest) -> Result<Arc<Self>> {
        let chain_entry = manifest.entry("matmul_chain")?.clone();
        let pair_entry = manifest.entry("matmul_pair")?.clone();
        let shape = &chain_entry.inputs[0].shape; // (L, N, N)
        if shape.len() != 3 || shape[1] != shape[2] {
            return Err(Error::Artifact {
                name: "matmul_chain".into(),
                reason: format!("unexpected shape {shape:?}"),
            });
        }
        Ok(Arc::new(MatmulChainApp {
            chain_len: shape[0],
            n: shape[1],
            chain_entry,
            pair_entry,
        }))
    }

    /// The (chain length, matrix size) the fast path accepts.
    pub fn static_shape(&self) -> (usize, usize) {
        (self.chain_len, self.n)
    }
}

impl MapApp for MatmulChainApp {
    fn name(&self) -> &str {
        "matmulchain"
    }

    fn startup(&self) -> Result<Box<dyn MapInstance>> {
        // The expensive launch: compile BOTH artifacts (the paper's MATLAB
        // boot loads the whole toolbox, not one function).
        let chain = XlaExecutable::from_entry(&self.chain_entry)?;
        let pair = XlaExecutable::from_entry(&self.pair_entry)?;
        Ok(Box::new(MatmulChainInstance {
            chain,
            pair,
            chain_len: self.chain_len,
            n: self.n,
        }))
    }

    fn cost_hint(&self) -> CostHint {
        CostHint {
            startup: std::time::Duration::from_millis(30),
            per_item: std::time::Duration::from_millis(3),
        }
    }
}

struct MatmulChainInstance {
    chain: XlaExecutable,
    pair: XlaExecutable,
    chain_len: usize,
    n: usize,
}

impl MapInstance for MatmulChainInstance {
    fn process(&mut self, input: &Path, output: &Path) -> Result<()> {
        let list = read_matrix_list(input)?;
        if list.n != self.n {
            return Err(Error::App {
                app: "matmulchain".into(),
                input: input.to_path_buf(),
                reason: format!(
                    "matrix size {} != artifact size {}",
                    list.n, self.n
                ),
            });
        }
        let product = if list.count() == self.chain_len {
            // Fast path: single fused chain executable.
            self.chain.run_f32(&[&list.data])?
        } else {
            // General path: fold through the pair executable.
            let mut acc = list.matrix(0).to_vec();
            for m in 1..list.count() {
                acc = self.pair.run_f32(&[&acc, list.matrix(m)])?;
            }
            acc
        };
        write_result(output, self.n, &product)
    }
}

/// The reducer for the matmul pipeline: sums Frobenius norms across all
/// mapper outputs — a one-number summary like the paper's reduce step.
pub struct FrobeniusSumReducer;

impl crate::apps::ReduceApp for FrobeniusSumReducer {
    fn name(&self) -> &str {
        "frobsum-reducer"
    }

    fn reduce(&self, dir: &Path, out: &Path) -> Result<()> {
        let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
            .at(dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.is_file()
                    && p.file_name()
                        .and_then(|n| n.to_str())
                        .is_some_and(|n| !n.starts_with('.'))
                    && *p != *out
            })
            .collect();
        files.sort();
        let (count, total) = sum_results(&files)?;
        std::fs::write(
            out,
            format!("FILES {count}\nFROBENIUS_SUM {total}\n"),
        )
        .at(out)
    }

    /// Overlapped mode: `read_result_frobenius` reads ONE value per file,
    /// so the default byte-concatenation would drop all but one matrix
    /// result per partial.  Instead sum the task's values and emit a
    /// `FILES <n>` line plus a single `FROBENIUS <sum>` line; the final
    /// `reduce` pass sums both across partials, so the overlapped output
    /// matches the barriered one (same f64 parsing on both paths; exact
    /// up to floating-point summation order).
    fn reduce_partial(&self, files: &[PathBuf], out: &Path) -> Result<()> {
        let (count, total) = sum_results(files)?;
        std::fs::write(
            out,
            format!("FILES {count}\nFROBENIUS {total}\n"),
        )
        .at(out)
    }

    fn supports_partial(&self) -> bool {
        true
    }
}

/// The one fold both reduce paths share: total file count and Frobenius
/// sum over result files (mapper outputs or partials).
fn sum_results(files: &[PathBuf]) -> Result<(usize, f64)> {
    let mut total = 0f64;
    let mut count = 0usize;
    for f in files {
        let (nfiles, frob) = read_result_or_partial(f)?;
        total += frob;
        count += nfiles;
    }
    Ok((count, total))
}

/// Read either a mapper output (one matrix result, counts as 1 file) or
/// an overlapped partial (`FILES <n>` + `FROBENIUS <sum>`): returns the
/// file count it represents and its Frobenius contribution.  One read;
/// the FROBENIUS value is parsed as f64 on every path (barriered reduce,
/// partial fold, final merge) so the two modes agree.
fn read_result_or_partial(path: &Path) -> Result<(usize, f64)> {
    let text = std::fs::read_to_string(path).at(path)?;
    let bad = |reason: &str| Error::Format {
        kind: "matresult",
        path: path.to_path_buf(),
        reason: reason.into(),
    };
    let mut nfiles = 1usize;
    let mut frob: Option<f64> = None;
    for line in text.lines() {
        if let Some(v) = line.strip_prefix("FILES ") {
            nfiles = v
                .trim()
                .parse()
                .map_err(|_| bad("bad FILES value"))?;
        } else if let Some(v) = line.strip_prefix("FROBENIUS ") {
            frob = Some(
                v.trim()
                    .parse()
                    .map_err(|_| bad("bad FROBENIUS value"))?,
            );
        }
    }
    frob.map(|f| (nfiles, f))
        .ok_or_else(|| bad("no FROBENIUS line"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::fs;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-mat-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn random_list(count: usize, n: usize, seed: u64) -> MatrixList {
        let mut rng = Rng::new(seed);
        // Scale down so chain products stay in f32 range.
        MatrixList {
            n,
            data: (0..count * n * n)
                .map(|_| (rng.next_f32() - 0.5) * 0.2)
                .collect(),
        }
    }

    #[test]
    fn matlist_roundtrip() {
        let d = tmp("roundtrip");
        let list = random_list(3, 4, 1);
        let p = d.join("m.mat");
        write_matrix_list(&p, &list).unwrap();
        let back = read_matrix_list(&p).unwrap();
        assert_eq!(back.n, 4);
        assert_eq!(back.count(), 3);
        for (a, b) in list.data.iter().zip(&back.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn matlist_rejects_malformed() {
        let d = tmp("badmat");
        let p = d.join("bad.mat");
        fs::write(&p, "NOTMAT 1 2\n").unwrap();
        assert!(read_matrix_list(&p).is_err());
        fs::write(&p, "MATLIST 2 2\n1 2 3\n").unwrap();
        let err = read_matrix_list(&p).unwrap_err().to_string();
        assert!(err.contains("expected 8 values"), "{err}");
    }

    #[test]
    fn chain_ref_identity() {
        // I * A = A
        let n = 3;
        let mut data = vec![0f32; n * n];
        for i in 0..n {
            data[i * n + i] = 1.0;
        }
        let a: Vec<f32> = (0..9).map(|i| i as f32).collect();
        data.extend(&a);
        let list = MatrixList { n, data };
        assert_eq!(chain_product_ref(&list), a);
    }

    #[test]
    fn result_file_roundtrip() {
        let d = tmp("result");
        let p = d.join("r.out");
        let product = vec![3.0, 0.0, 0.0, 4.0];
        write_result(&p, 2, &product).unwrap();
        let f = read_result_frobenius(&p).unwrap();
        assert!((f - 5.0).abs() < 1e-6);
    }

    #[test]
    fn overlapped_partials_match_barriered_reduce() {
        use crate::apps::ReduceApp;
        let d = tmp("frobpart");
        write_result(&d.join("a.out"), 1, &[3.0]).unwrap();
        write_result(&d.join("b.out"), 1, &[4.0]).unwrap();
        write_result(&d.join("c.out"), 1, &[5.0]).unwrap();
        // Overlapped: two partials (task-grouped), then a final merge.
        let pdir = d.join("partials");
        fs::create_dir_all(&pdir).unwrap();
        FrobeniusSumReducer
            .reduce_partial(
                &[d.join("a.out"), d.join("b.out")],
                &pdir.join("part_1"),
            )
            .unwrap();
        FrobeniusSumReducer
            .reduce_partial(&[d.join("c.out")], &pdir.join("part_2"))
            .unwrap();
        let overlapped = pdir.join(".final");
        FrobeniusSumReducer.reduce(&pdir, &overlapped).unwrap();
        let text = fs::read_to_string(&overlapped).unwrap();
        // FILES counts matrices (3), not partials (2); sum is 3+4+5.
        assert!(text.contains("FILES 3"), "{text}");
        assert!(text.contains("FROBENIUS_SUM 12"), "{text}");
    }

    #[test]
    fn frobsum_reducer_sums() {
        let d = tmp("frobsum");
        write_result(&d.join("a.out"), 2, &[3.0, 0.0, 0.0, 4.0]).unwrap();
        write_result(&d.join("b.out"), 2, &[6.0, 0.0, 0.0, 8.0]).unwrap();
        let out = d.join("llmapreduce.out");
        crate::apps::ReduceApp::reduce(&FrobeniusSumReducer, &d, &out)
            .unwrap();
        let text = fs::read_to_string(&out).unwrap();
        assert!(text.contains("FILES 2"));
        assert!(text.contains("FROBENIUS_SUM 15"), "{text}");
    }

    // -- XLA-backed (skip when artifacts absent) ----------------------------

    #[test]
    fn app_matches_reference_on_static_shape() {
        let Ok(m) = Manifest::discover() else { return };
        let app = MatmulChainApp::new(&m).unwrap();
        let (l, n) = app.static_shape();
        let d = tmp("app");
        let list = random_list(l, n, 7);
        let inp = d.join("in.mat");
        write_matrix_list(&inp, &list).unwrap();
        let out = d.join("in.mat.out");
        let mut inst = app.startup().unwrap();
        inst.process(&inp, &out).unwrap();

        let quantized = read_matrix_list(&inp).unwrap();
        let expect = chain_product_ref(&quantized);
        let f_expect = frobenius(&expect);
        let f_got = read_result_frobenius(&out).unwrap();
        assert!(
            (f_got - f_expect).abs() / f_expect.max(1e-6) < 1e-3,
            "{f_got} vs {f_expect}"
        );
    }

    #[test]
    fn app_general_path_other_lengths() {
        let Ok(m) = Manifest::discover() else { return };
        let app = MatmulChainApp::new(&m).unwrap();
        let (_, n) = app.static_shape();
        let d = tmp("general");
        let list = random_list(2, n, 9); // != static chain length
        let inp = d.join("in2.mat");
        write_matrix_list(&inp, &list).unwrap();
        let out = d.join("in2.mat.out");
        let mut inst = app.startup().unwrap();
        inst.process(&inp, &out).unwrap();
        let expect = frobenius(&chain_product_ref(&read_matrix_list(&inp).unwrap()));
        let got = read_result_frobenius(&out).unwrap();
        assert!((got - expect).abs() / expect.max(1e-6) < 1e-3);
    }
}
