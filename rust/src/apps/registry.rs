//! Name → application resolution, shared by the CLI and the remote
//! worker daemon.
//!
//! The paper resolves mapper/reducer names to executables on disk; this
//! registry resolves them to the built-in apps first and falls back to
//! external commands ("any program in any language", §I).  The remote
//! engine leans on the same mapping for its wire protocol: the
//! coordinator ships [`crate::apps::MapApp::wire_spec`] strings, and the
//! worker daemon resolves them here — so a spec that round-trips through
//! the CLI (`--mapper=wordcount:ignore.txt`) round-trips over the wire
//! identically.

use std::path::PathBuf;
use std::sync::Arc;

use crate::apps::command::{
    CommandApp, CommandMimoApp, CommandReducer, CommandStreamApp,
};
use crate::apps::image::ImageConvertApp;
use crate::apps::matmul::{FrobeniusSumReducer, MatmulChainApp};
use crate::apps::wordcount::{WordCountApp, WordCountReducer};
use crate::apps::{MapApp, ReduceApp};
use crate::error::Result;
use crate::runtime::Manifest;

/// Resolve a mapper spec: built-ins first, external command otherwise.
///
/// Built-ins: `imageconvert`, `imagepipeline`, `matmulchain`,
/// `wordcount[:ignorefile]`.  Batched command protocols carry an
/// explicit prefix so they survive the wire round-trip: `stream:<argv>`
/// resolves to the stdin item-stream app and `mimo:<argv>` to the
/// list-file app (the worker supplies a local list directory).  Anything
/// else is split on whitespace and launched as an external command per
/// file.
pub fn resolve_mapper(spec: &str) -> Result<Arc<dyn MapApp>> {
    if let Some(rest) = spec.strip_prefix("stream:") {
        return Ok(CommandStreamApp::new(
            rest.split_whitespace().map(str::to_string).collect(),
        )? as Arc<dyn MapApp>);
    }
    if let Some(rest) = spec.strip_prefix("mimo:") {
        let list_dir = std::env::temp_dir()
            .join(format!("llmr-mimo-lists-{}", std::process::id()));
        return Ok(CommandMimoApp::new(
            rest.split_whitespace().map(str::to_string).collect(),
            list_dir,
        )? as Arc<dyn MapApp>);
    }
    if spec == "imageconvert" {
        let m = Manifest::discover()?;
        return Ok(ImageConvertApp::new(&m)? as Arc<dyn MapApp>);
    }
    if spec == "imagepipeline" {
        let m = Manifest::discover()?;
        return Ok(ImageConvertApp::pipeline(&m)? as Arc<dyn MapApp>);
    }
    if spec == "matmulchain" {
        let m = Manifest::discover()?;
        return Ok(MatmulChainApp::new(&m)? as Arc<dyn MapApp>);
    }
    if let Some(rest) = spec.strip_prefix("wordcount") {
        if rest.is_empty() || rest.starts_with(':') {
            let ignore = rest
                .strip_prefix(':')
                .map(PathBuf::from)
                .filter(|p| !p.as_os_str().is_empty());
            return Ok(WordCountApp::new(ignore) as Arc<dyn MapApp>);
        }
    }
    Ok(CommandApp::new(
        spec.split_whitespace().map(str::to_string).collect(),
    )? as Arc<dyn MapApp>)
}

/// Resolve a reducer spec: `wordcount-reducer`, `frobsum-reducer`, or an
/// external command.
pub fn resolve_reducer(spec: &str) -> Result<Arc<dyn ReduceApp>> {
    match spec {
        "wordcount-reducer" => Ok(Arc::new(WordCountReducer)),
        "frobsum-reducer" => Ok(Arc::new(FrobeniusSumReducer)),
        other => Ok(CommandReducer::new(
            other.split_whitespace().map(str::to_string).collect(),
        )? as Arc<dyn ReduceApp>),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wordcount_specs_resolve_to_builtin() {
        assert_eq!(resolve_mapper("wordcount").unwrap().name(), "wordcount");
        let with_ignore = resolve_mapper("wordcount:/tmp/ign.txt").unwrap();
        assert_eq!(with_ignore.name(), "wordcount");
        // The ignore path survives in the wire spec.
        assert_eq!(with_ignore.wire_spec(), "wordcount:/tmp/ign.txt");
    }

    #[test]
    fn wordcount_prefixed_command_is_not_the_builtin() {
        // "wordcounter" must not silently become the wordcount built-in.
        let app = resolve_mapper("wordcounter").unwrap();
        assert_eq!(app.name(), "wordcounter");
        assert_eq!(app.wire_spec(), "wordcounter");
    }

    #[test]
    fn builtin_reducers_resolve() {
        assert_eq!(
            resolve_reducer("wordcount-reducer").unwrap().name(),
            "wordcount-reducer"
        );
        assert_eq!(
            resolve_reducer("frobsum-reducer").unwrap().name(),
            "frobsum-reducer"
        );
    }

    #[test]
    fn external_command_spec_roundtrips() {
        let app = resolve_mapper("./mapper.sh ref.txt").unwrap();
        assert_eq!(app.wire_spec(), "./mapper.sh ref.txt");
        let red = resolve_reducer("./reduce.sh --merge").unwrap();
        assert_eq!(red.wire_spec(), "./reduce.sh --merge");
    }

    #[test]
    fn empty_spec_rejected() {
        assert!(resolve_mapper("").is_err());
        assert!(resolve_reducer("").is_err());
    }

    #[test]
    fn builtin_wire_specs_resolve_back_to_equivalent_apps() {
        // The contract the remote engine relies on: resolving a spec and
        // re-resolving its wire_spec lands on the same app identity.
        for spec in ["wordcount", "wordcount:ign.txt", "cat"] {
            let app = resolve_mapper(spec).unwrap();
            let again = resolve_mapper(&app.wire_spec()).unwrap();
            assert_eq!(app.wire_spec(), again.wire_spec(), "{spec}");
        }
    }

    #[test]
    fn batched_wire_specs_resolve_back_to_equivalent_apps() {
        // SPMD ganging ships `stream:`/`mimo:` specs; the worker must
        // land on the same protocol with the argv (incl. bound reference
        // files) intact.
        for spec in ["stream:./mapper.sh ref.txt", "mimo:cat"] {
            let app = resolve_mapper(spec).unwrap();
            assert_eq!(app.wire_spec(), spec, "argv survives in the spec");
            let again = resolve_mapper(&app.wire_spec()).unwrap();
            assert_eq!(app.wire_spec(), again.wire_spec(), "{spec}");
        }
    }

    #[test]
    fn stream_prefixed_command_name_is_not_misparsed() {
        // A program literally named "streamer" stays a plain per-item
        // command; only the "stream:" protocol prefix opts in.
        let app = resolve_mapper("streamer").unwrap();
        assert_eq!(app.wire_spec(), "streamer");
    }
}
