//! External-command applications — the paper's generality claim.
//!
//! "LLMapReduce can launch any program in any language on any
//! supercomputers with a standard scheduler" (§I).  This app wraps an
//! arbitrary executable honouring the LLMapReduce API contract:
//!
//! * SISO mapper: `prog <input> <output>` per file (Fig 6's wrapper);
//! * MIMO mapper: the engine still calls `process` per pair, but the
//!   process is spawned once per *instance* in server mode when
//!   `--mimo-server` style programs are used — here we model the paper's
//!   simpler contract: the MIMO pair list is written by the launcher and
//!   handed to the program once (`prog <pairlist>`, Fig 11/17).  Use
//!   [`CommandMimoApp`] for that shape.
//! * reducer: `prog <map_output_dir> <redout>` (Fig 14).

use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::Arc;

use crate::apps::{MapApp, MapInstance, ReduceApp};
use crate::error::{Error, Result};

fn run_command(argv: &[String]) -> Result<()> {
    let (prog, args) = argv.split_first().ok_or_else(|| {
        Error::App {
            app: "command".into(),
            input: PathBuf::new(),
            reason: "empty argv".into(),
        }
    })?;
    let status = Command::new(prog).args(args).status().map_err(|e| {
        Error::App {
            app: prog.clone(),
            input: PathBuf::new(),
            reason: format!("spawn failed: {e}"),
        }
    })?;
    if !status.success() {
        return Err(Error::App {
            app: prog.clone(),
            input: PathBuf::new(),
            reason: format!("exit status {status}"),
        });
    }
    Ok(())
}

/// SISO external mapper: spawns `prog input output` per file.  The
/// process spawn *is* the startup cost — exactly the overhead the paper
/// measures for wrapper-script mappers.
pub struct CommandApp {
    argv: Vec<String>,
}

impl CommandApp {
    /// `argv`: program + fixed leading arguments (the wrapper script and
    /// its bound reference files, like Fig 13's `textignore.txt`).
    pub fn new(argv: Vec<String>) -> Result<Arc<Self>> {
        if argv.is_empty() {
            return Err(Error::opt("command app needs a program"));
        }
        Ok(Arc::new(CommandApp { argv }))
    }
}

impl MapApp for CommandApp {
    fn name(&self) -> &str {
        &self.argv[0]
    }

    /// The full argv, whitespace-joined — what the registry's external
    /// fallback splits back.  Shipped verbatim: tokens may be `$PATH`
    /// programs, so they cannot be safely absolutized — use absolute
    /// paths in the argv when workers run from a different directory.
    /// (Arguments containing spaces do not round-trip; the CLI surface
    /// has the same limitation.)
    fn wire_spec(&self) -> String {
        self.argv.join(" ")
    }

    fn startup(&self) -> Result<Box<dyn MapInstance>> {
        Ok(Box::new(CommandInstance {
            argv: self.argv.clone(),
        }))
    }
}

struct CommandInstance {
    argv: Vec<String>,
}

impl MapInstance for CommandInstance {
    fn process(&mut self, input: &Path, output: &Path) -> Result<()> {
        let mut argv = self.argv.clone();
        argv.push(input.display().to_string());
        argv.push(output.display().to_string());
        run_command(&argv)
    }
}

/// MIMO external mapper: the program is spawned **once per task** with a
/// pair-list file (Fig 12's `run_llmap_x` calling `MatlabCmdMulti.sh
/// input_x`).  The launcher writes the list; the program loops over it.
pub struct CommandMimoApp {
    argv: Vec<String>,
    /// Directory for generated pair lists.
    list_dir: PathBuf,
}

impl CommandMimoApp {
    pub fn new(argv: Vec<String>, list_dir: PathBuf) -> Result<Arc<Self>> {
        if argv.is_empty() {
            return Err(Error::opt("command app needs a program"));
        }
        std::fs::create_dir_all(&list_dir)
            .map_err(|e| Error::io(list_dir.clone(), e))?;
        Ok(Arc::new(CommandMimoApp { argv, list_dir }))
    }
}

impl MapApp for CommandMimoApp {
    fn name(&self) -> &str {
        &self.argv[0]
    }

    fn startup(&self) -> Result<Box<dyn MapInstance>> {
        Ok(Box::new(CommandMimoInstance {
            argv: self.argv.clone(),
            list_dir: self.list_dir.clone(),
            pending: Vec::new(),
        }))
    }
}

/// Accumulates pairs, flushes the external program once on drop (the
/// instance lives for exactly one MIMO task).
struct CommandMimoInstance {
    argv: Vec<String>,
    list_dir: PathBuf,
    pending: Vec<(PathBuf, PathBuf)>,
}

impl CommandMimoInstance {
    fn flush(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        // Unique per flush: concurrent array tasks must not collide on
        // the list path (fixed after a real race in the any_language
        // example).
        static SEQ: std::sync::atomic::AtomicU64 =
            std::sync::atomic::AtomicU64::new(0);
        let seq = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let list = self.list_dir.join(format!(
            "pairs-{}-{seq}.list",
            std::process::id(),
        ));
        let body = crate::workdir::scripts::mimo_input_list(&self.pending);
        std::fs::write(&list, body)
            .map_err(|e| Error::io(list.clone(), e))?;
        let mut argv = self.argv.clone();
        argv.push(list.display().to_string());
        let result = run_command(&argv);
        let _ = std::fs::remove_file(&list);
        self.pending.clear();
        result
    }
}

impl MapInstance for CommandMimoInstance {
    fn process(&mut self, input: &Path, output: &Path) -> Result<()> {
        // Batch; the run_map_task driver calls process per pair, and the
        // batch flushes when the instance drops at end of task.
        self.pending.push((input.to_path_buf(), output.to_path_buf()));
        // Flush opportunistically at a batch bound so errors surface
        // before drop (drop cannot return Result).
        if self.pending.len() >= 4096 {
            self.flush()?;
        }
        Ok(())
    }
}

impl Drop for CommandMimoInstance {
    fn drop(&mut self) {
        if let Err(e) = self.flush() {
            eprintln!("command mimo flush failed: {e}");
        }
    }
}

/// External reducer: `prog <map_output_dir> <redout>`.
pub struct CommandReducer {
    argv: Vec<String>,
}

impl CommandReducer {
    pub fn new(argv: Vec<String>) -> Result<Arc<Self>> {
        if argv.is_empty() {
            return Err(Error::opt("command reducer needs a program"));
        }
        Ok(Arc::new(CommandReducer { argv }))
    }
}

impl ReduceApp for CommandReducer {
    fn name(&self) -> &str {
        &self.argv[0]
    }

    /// See [`CommandApp::wire_spec`] (same argv round-trip).
    fn wire_spec(&self) -> String {
        self.argv.join(" ")
    }

    fn reduce(&self, dir: &Path, out: &Path) -> Result<()> {
        let mut argv = self.argv.clone();
        argv.push(dir.display().to_string());
        argv.push(out.display().to_string());
        run_command(&argv)
    }

    // `supports_partial` stays at the opt-in default (false): an
    // external program's reduce contract is "a directory of real mapper
    // outputs", and we cannot know whether concatenated partials would
    // misparse, so the overlapped pipeline barriers for command reducers.
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-cmd-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    /// A tiny shell mapper: copies input to output, uppercased.
    fn write_mapper_script(dir: &Path) -> PathBuf {
        let p = dir.join("mapper.sh");
        fs::write(
            &p,
            "#!/bin/sh\ntr '[:lower:]' '[:upper:]' < \"$1\" > \"$2\"\n",
        )
        .unwrap();
        make_exec(&p);
        p
    }

    fn make_exec(p: &Path) {
        use std::os::unix::fs::PermissionsExt;
        let mut perm = fs::metadata(p).unwrap().permissions();
        perm.set_mode(0o755);
        fs::set_permissions(p, perm).unwrap();
    }

    #[test]
    fn siso_command_runs_per_file() {
        let d = tmp("siso");
        let script = write_mapper_script(&d);
        let inp = d.join("x.txt");
        fs::write(&inp, "hello").unwrap();
        let out = d.join("x.txt.out");
        let app =
            CommandApp::new(vec![script.display().to_string()]).unwrap();
        let mut inst = app.startup().unwrap();
        inst.process(&inp, &out).unwrap();
        assert_eq!(fs::read_to_string(&out).unwrap(), "HELLO");
    }

    #[test]
    fn failing_command_reports_status() {
        let d = tmp("fail");
        let p = d.join("bad.sh");
        fs::write(&p, "#!/bin/sh\nexit 3\n").unwrap();
        make_exec(&p);
        let app = CommandApp::new(vec![p.display().to_string()]).unwrap();
        let mut inst = app.startup().unwrap();
        let err = inst
            .process(Path::new("a"), Path::new("b"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("exit status"), "{err}");
    }

    #[test]
    fn mimo_command_gets_pair_list_once() {
        let d = tmp("mimo");
        // Mapper that logs its invocation then processes the pair list.
        let p = d.join("multi.sh");
        fs::write(
            &p,
            format!(
                "#!/bin/sh\necho run >> {}/invocations\n\
                 while read -r i o; do cp \"$i\" \"$o\"; done < \"$1\"\n",
                d.display()
            ),
        )
        .unwrap();
        make_exec(&p);
        let app = CommandMimoApp::new(
            vec![p.display().to_string()],
            d.join("lists"),
        )
        .unwrap();
        let pairs: Vec<_> = (0..3)
            .map(|i| {
                let inp = d.join(format!("f{i}.txt"));
                fs::write(&inp, format!("{i}")).unwrap();
                (inp, d.join(format!("f{i}.txt.out")))
            })
            .collect();
        {
            let mut inst = app.startup().unwrap();
            for (i, o) in &pairs {
                inst.process(i, o).unwrap();
            }
        } // drop flushes
        for (i, o) in &pairs {
            assert_eq!(
                fs::read_to_string(o).unwrap(),
                fs::read_to_string(i).unwrap()
            );
        }
        // Spawned exactly once.
        let inv = fs::read_to_string(d.join("invocations")).unwrap();
        assert_eq!(inv.lines().count(), 1);
    }

    #[test]
    fn command_reducer_contract() {
        let d = tmp("reduce");
        fs::write(d.join("a.out"), "1\n").unwrap();
        fs::write(d.join("b.out"), "2\n").unwrap();
        let p = d.join("red.sh");
        fs::write(&p, "#!/bin/sh\ncat \"$1\"/*.out > \"$2\"\n").unwrap();
        make_exec(&p);
        let red = CommandReducer::new(vec![p.display().to_string()]).unwrap();
        let out = d.join("merged");
        red.reduce(&d, &out).unwrap();
        assert_eq!(fs::read_to_string(&out).unwrap(), "1\n2\n");
        // External reducers can't fold partials: --overlap must fall
        // back to the barrier for them.
        assert!(!red.supports_partial());
    }

    #[test]
    fn empty_argv_rejected() {
        assert!(CommandApp::new(vec![]).is_err());
        assert!(CommandReducer::new(vec![]).is_err());
    }
}
