//! External-command applications — the paper's generality claim.
//!
//! "LLMapReduce can launch any program in any language on any
//! supercomputers with a standard scheduler" (§I).  This app wraps an
//! arbitrary executable honouring the LLMapReduce API contract:
//!
//! * SISO mapper: `prog <input> <output>` per file (Fig 6's wrapper);
//! * MIMO mapper: the engine still calls `process` per pair, but the
//!   process is spawned once per *instance* in server mode when
//!   `--mimo-server` style programs are used — here we model the paper's
//!   simpler contract: the MIMO pair list is written by the launcher and
//!   handed to the program once (`prog <pairlist>`, Fig 11/17).  Use
//!   [`CommandMimoApp`] for that shape.
//! * reducer: `prog <map_output_dir> <redout>` (Fig 14).

use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::Arc;

use crate::apps::{MapApp, MapInstance, ReduceApp};
use crate::error::{Error, Result};

fn run_command(argv: &[String]) -> Result<()> {
    let (prog, args) = argv.split_first().ok_or_else(|| {
        Error::App {
            app: "command".into(),
            input: PathBuf::new(),
            reason: "empty argv".into(),
        }
    })?;
    let status = Command::new(prog).args(args).status().map_err(|e| {
        Error::App {
            app: prog.clone(),
            input: PathBuf::new(),
            reason: format!("spawn failed: {e}"),
        }
    })?;
    if !status.success() {
        return Err(Error::App {
            app: prog.clone(),
            input: PathBuf::new(),
            reason: format!("exit status {status}"),
        });
    }
    Ok(())
}

/// SISO external mapper: spawns `prog input output` per file.  The
/// process spawn *is* the startup cost — exactly the overhead the paper
/// measures for wrapper-script mappers.
pub struct CommandApp {
    argv: Vec<String>,
}

impl CommandApp {
    /// `argv`: program + fixed leading arguments (the wrapper script and
    /// its bound reference files, like Fig 13's `textignore.txt`).
    pub fn new(argv: Vec<String>) -> Result<Arc<Self>> {
        if argv.is_empty() {
            return Err(Error::opt("command app needs a program"));
        }
        Ok(Arc::new(CommandApp { argv }))
    }
}

impl MapApp for CommandApp {
    fn name(&self) -> &str {
        &self.argv[0]
    }

    /// The full argv, whitespace-joined — what the registry's external
    /// fallback splits back.  Shipped verbatim: tokens may be `$PATH`
    /// programs, so they cannot be safely absolutized — use absolute
    /// paths in the argv when workers run from a different directory.
    /// (Arguments containing spaces do not round-trip; the CLI surface
    /// has the same limitation.)
    fn wire_spec(&self) -> String {
        self.argv.join(" ")
    }

    fn startup(&self) -> Result<Box<dyn MapInstance>> {
        Ok(Box::new(CommandInstance {
            argv: self.argv.clone(),
        }))
    }
}

struct CommandInstance {
    argv: Vec<String>,
}

impl MapInstance for CommandInstance {
    fn process(&mut self, input: &Path, output: &Path) -> Result<()> {
        let mut argv = self.argv.clone();
        argv.push(input.display().to_string());
        argv.push(output.display().to_string());
        run_command(&argv)
    }
}

/// MIMO external mapper: the program is spawned **once per task** with a
/// pair-list file (Fig 12's `run_llmap_x` calling `MatlabCmdMulti.sh
/// input_x`).  The launcher writes the list; the program loops over it.
pub struct CommandMimoApp {
    argv: Vec<String>,
    /// Directory for generated pair lists.
    list_dir: PathBuf,
}

impl CommandMimoApp {
    pub fn new(argv: Vec<String>, list_dir: PathBuf) -> Result<Arc<Self>> {
        if argv.is_empty() {
            return Err(Error::opt("command app needs a program"));
        }
        std::fs::create_dir_all(&list_dir)
            .map_err(|e| Error::io(list_dir.clone(), e))?;
        Ok(Arc::new(CommandMimoApp { argv, list_dir }))
    }
}

impl MapApp for CommandMimoApp {
    fn name(&self) -> &str {
        &self.argv[0]
    }

    /// `mimo:`-prefixed argv (cf. [`CommandStreamApp::wire_spec`]): the
    /// default would be the bare program name, which a worker daemon
    /// resolves to a per-item [`CommandApp`] — wrong launch protocol
    /// *and* dropped arguments.  The registry resolves the prefix back
    /// to a `CommandMimoApp` with a worker-local list directory.
    fn wire_spec(&self) -> String {
        format!("mimo:{}", self.argv.join(" "))
    }

    fn startup(&self) -> Result<Box<dyn MapInstance>> {
        Ok(Box::new(CommandMimoInstance {
            argv: self.argv.clone(),
            list_dir: self.list_dir.clone(),
            pending: Vec::new(),
        }))
    }
}

/// Accumulates pairs, flushes the external program once on drop (the
/// instance lives for exactly one MIMO task).
struct CommandMimoInstance {
    argv: Vec<String>,
    list_dir: PathBuf,
    pending: Vec<(PathBuf, PathBuf)>,
}

impl CommandMimoInstance {
    fn flush(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        // Unique per flush: concurrent array tasks must not collide on
        // the list path (fixed after a real race in the any_language
        // example).
        static SEQ: std::sync::atomic::AtomicU64 =
            std::sync::atomic::AtomicU64::new(0);
        let seq = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let list = self.list_dir.join(format!(
            "pairs-{}-{seq}.list",
            std::process::id(),
        ));
        let body = crate::workdir::scripts::mimo_input_list(&self.pending);
        std::fs::write(&list, body)
            .map_err(|e| Error::io(list.clone(), e))?;
        let mut argv = self.argv.clone();
        argv.push(list.display().to_string());
        let result = run_command(&argv);
        let _ = std::fs::remove_file(&list);
        self.pending.clear();
        result
    }
}

impl MapInstance for CommandMimoInstance {
    fn process(&mut self, input: &Path, output: &Path) -> Result<()> {
        // Batch; the run_map_task driver calls process per pair, and the
        // batch flushes when the instance drops at end of task.
        self.pending.push((input.to_path_buf(), output.to_path_buf()));
        // Flush opportunistically at a batch bound so errors surface
        // before drop (drop cannot return Result).
        if self.pending.len() >= 4096 {
            self.flush()?;
        }
        Ok(())
    }
}

impl Drop for CommandMimoInstance {
    fn drop(&mut self) {
        if let Err(e) = self.flush() {
            eprintln!("command mimo flush failed: {e}");
        }
    }
}

/// SPMD external mapper: the program is spawned **once per batch** and
/// consumes tab-separated `input<TAB>output` lines on **stdin** until
/// EOF — the item-stream protocol (`--spmd`, DESIGN.md §7).  The spawn
/// happens in [`MapApp::startup`] so the launch cost lands where every
/// engine measures it, and the persistent child then eats the whole
/// batch in one [`MapInstance::run_batch`] call.  Exit status 0 means
/// every item succeeded; anything else fails the batch (and the task),
/// which is exactly the per-item path's failure granularity after
/// reassignment re-runs the batch.
pub struct CommandStreamApp {
    argv: Vec<String>,
}

impl CommandStreamApp {
    /// `argv`: program + fixed leading arguments.  The program must loop
    /// `while read -r input output; do ...; done` over stdin (or the
    /// equivalent), exiting non-zero on the first failed item.
    pub fn new(argv: Vec<String>) -> Result<Arc<Self>> {
        if argv.is_empty() {
            return Err(Error::opt("command app needs a program"));
        }
        Ok(Arc::new(CommandStreamApp { argv }))
    }
}

fn spawn_stream(argv: &[String]) -> Result<std::process::Child> {
    let (prog, args) = argv.split_first().ok_or_else(|| Error::App {
        app: "command-stream".into(),
        input: PathBuf::new(),
        reason: "empty argv".into(),
    })?;
    Command::new(prog)
        .args(args)
        .stdin(std::process::Stdio::piped())
        .spawn()
        .map_err(|e| Error::App {
            app: prog.clone(),
            input: PathBuf::new(),
            reason: format!("spawn failed: {e}"),
        })
}

impl MapApp for CommandStreamApp {
    fn name(&self) -> &str {
        &self.argv[0]
    }

    /// `stream:`-prefixed argv so a worker daemon resolves the *same*
    /// launch protocol: a bare argv would round-trip to a per-item
    /// [`CommandApp`] and silently change the app identity of a ganged
    /// remote job (see [`crate::apps::registry::resolve_mapper`]).
    fn wire_spec(&self) -> String {
        format!("stream:{}", self.argv.join(" "))
    }

    fn startup(&self) -> Result<Box<dyn MapInstance>> {
        // Spawn here: the child process launch is the startup cost the
        // SPMD morph amortizes, so it must be timed as startup.
        Ok(Box::new(CommandStreamInstance {
            argv: self.argv.clone(),
            child: Some(spawn_stream(&self.argv)?),
        }))
    }
}

/// One spawned stream consumer.  The pre-spawned child serves the first
/// batch (or first per-item call); later calls spawn fresh — instances
/// normally live for exactly one batch, so the respawn path only runs
/// when a caller drives the instance beyond the task contract.
struct CommandStreamInstance {
    argv: Vec<String>,
    child: Option<std::process::Child>,
}

impl CommandStreamInstance {
    fn stream(&mut self, pairs: &[(PathBuf, PathBuf)]) -> Result<()> {
        if pairs.is_empty() {
            return Ok(());
        }
        let mut child = match self.child.take() {
            Some(c) => c,
            None => spawn_stream(&self.argv)?,
        };
        let prog = self.argv[0].clone();
        let write_items = |child: &mut std::process::Child| -> Result<()> {
            use std::io::Write;
            let mut stdin =
                std::io::BufWriter::new(child.stdin.take().ok_or_else(
                    || Error::App {
                        app: prog.clone(),
                        input: PathBuf::new(),
                        reason: "child stdin unavailable".into(),
                    },
                )?);
            for (input, output) in pairs {
                writeln!(
                    stdin,
                    "{}\t{}",
                    input.display(),
                    output.display()
                )
                .map_err(|e| Error::App {
                    app: prog.clone(),
                    input: input.clone(),
                    reason: format!("item stream write: {e}"),
                })?;
            }
            stdin.flush().map_err(|e| Error::App {
                app: prog.clone(),
                input: PathBuf::new(),
                reason: format!("item stream flush: {e}"),
            })?;
            Ok(())
        };
        let written = write_items(&mut child);
        // stdin dropped above => EOF => a well-behaved consumer exits.
        let status = child.wait().map_err(|e| Error::App {
            app: prog.clone(),
            input: PathBuf::new(),
            reason: format!("wait failed: {e}"),
        })?;
        // A failing child both exits non-zero *and* breaks the pipe the
        // writer is still filling; the exit status is the root cause, so
        // report it ahead of any (broken-pipe) write error.
        if !status.success() {
            return Err(Error::App {
                app: prog,
                input: PathBuf::new(),
                reason: format!("exit status {status}"),
            });
        }
        written
    }
}

impl MapInstance for CommandStreamInstance {
    /// Per-item fallback: stream a one-item batch.  Unmodified per-item
    /// binaries should use [`CommandApp`] instead; this keeps a
    /// stream-protocol program correct even when something drives the
    /// instance item by item.
    fn process(&mut self, input: &Path, output: &Path) -> Result<()> {
        self.stream(&[(input.to_path_buf(), output.to_path_buf())])
    }

    fn run_batch(&mut self, pairs: &[(PathBuf, PathBuf)]) -> Result<()> {
        self.stream(pairs)
    }
}

impl Drop for CommandStreamInstance {
    fn drop(&mut self) {
        // A pre-spawned child that never saw a batch: close its stdin
        // (EOF) and reap it so nothing leaks.
        if let Some(mut child) = self.child.take() {
            drop(child.stdin.take());
            let _ = child.wait();
        }
    }
}

/// External reducer: `prog <map_output_dir> <redout>`.
pub struct CommandReducer {
    argv: Vec<String>,
}

impl CommandReducer {
    pub fn new(argv: Vec<String>) -> Result<Arc<Self>> {
        if argv.is_empty() {
            return Err(Error::opt("command reducer needs a program"));
        }
        Ok(Arc::new(CommandReducer { argv }))
    }
}

impl ReduceApp for CommandReducer {
    fn name(&self) -> &str {
        &self.argv[0]
    }

    /// See [`CommandApp::wire_spec`] (same argv round-trip).
    fn wire_spec(&self) -> String {
        self.argv.join(" ")
    }

    fn reduce(&self, dir: &Path, out: &Path) -> Result<()> {
        let mut argv = self.argv.clone();
        argv.push(dir.display().to_string());
        argv.push(out.display().to_string());
        run_command(&argv)
    }

    // `supports_partial` stays at the opt-in default (false): an
    // external program's reduce contract is "a directory of real mapper
    // outputs", and we cannot know whether concatenated partials would
    // misparse, so the overlapped pipeline barriers for command reducers.
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-cmd-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    /// A tiny shell mapper: copies input to output, uppercased.
    fn write_mapper_script(dir: &Path) -> PathBuf {
        let p = dir.join("mapper.sh");
        fs::write(
            &p,
            "#!/bin/sh\ntr '[:lower:]' '[:upper:]' < \"$1\" > \"$2\"\n",
        )
        .unwrap();
        make_exec(&p);
        p
    }

    fn make_exec(p: &Path) {
        use std::os::unix::fs::PermissionsExt;
        let mut perm = fs::metadata(p).unwrap().permissions();
        perm.set_mode(0o755);
        fs::set_permissions(p, perm).unwrap();
    }

    #[test]
    fn siso_command_runs_per_file() {
        let d = tmp("siso");
        let script = write_mapper_script(&d);
        let inp = d.join("x.txt");
        fs::write(&inp, "hello").unwrap();
        let out = d.join("x.txt.out");
        let app =
            CommandApp::new(vec![script.display().to_string()]).unwrap();
        let mut inst = app.startup().unwrap();
        inst.process(&inp, &out).unwrap();
        assert_eq!(fs::read_to_string(&out).unwrap(), "HELLO");
    }

    #[test]
    fn failing_command_reports_status() {
        let d = tmp("fail");
        let p = d.join("bad.sh");
        fs::write(&p, "#!/bin/sh\nexit 3\n").unwrap();
        make_exec(&p);
        let app = CommandApp::new(vec![p.display().to_string()]).unwrap();
        let mut inst = app.startup().unwrap();
        let err = inst
            .process(Path::new("a"), Path::new("b"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("exit status"), "{err}");
    }

    #[test]
    fn mimo_command_gets_pair_list_once() {
        let d = tmp("mimo");
        // Mapper that logs its invocation then processes the pair list.
        let p = d.join("multi.sh");
        fs::write(
            &p,
            format!(
                "#!/bin/sh\necho run >> {}/invocations\n\
                 while read -r i o; do cp \"$i\" \"$o\"; done < \"$1\"\n",
                d.display()
            ),
        )
        .unwrap();
        make_exec(&p);
        let app = CommandMimoApp::new(
            vec![p.display().to_string()],
            d.join("lists"),
        )
        .unwrap();
        let pairs: Vec<_> = (0..3)
            .map(|i| {
                let inp = d.join(format!("f{i}.txt"));
                fs::write(&inp, format!("{i}")).unwrap();
                (inp, d.join(format!("f{i}.txt.out")))
            })
            .collect();
        {
            let mut inst = app.startup().unwrap();
            for (i, o) in &pairs {
                inst.process(i, o).unwrap();
            }
        } // drop flushes
        for (i, o) in &pairs {
            assert_eq!(
                fs::read_to_string(o).unwrap(),
                fs::read_to_string(i).unwrap()
            );
        }
        // Spawned exactly once.
        let inv = fs::read_to_string(d.join("invocations")).unwrap();
        assert_eq!(inv.lines().count(), 1);
    }

    /// A stream mapper honouring the stdin item-stream protocol: one
    /// `input<TAB>output` line per item, EOF ends the batch.  Logs every
    /// spawn so tests can count launches.
    fn write_stream_script(dir: &Path) -> PathBuf {
        let p = dir.join("stream.sh");
        fs::write(
            &p,
            format!(
                "#!/bin/sh\necho run >> {}/stream-invocations\n\
                 while read -r i o; do\n\
                 cp \"$i\" \"$o\" || exit 1\n\
                 done\n",
                dir.display()
            ),
        )
        .unwrap();
        make_exec(&p);
        p
    }

    #[test]
    fn stream_command_consumes_batch_in_one_spawn() {
        let d = tmp("stream");
        let script = write_stream_script(&d);
        let app =
            CommandStreamApp::new(vec![script.display().to_string()])
                .unwrap();
        let pairs: Vec<_> = (0..4)
            .map(|i| {
                let inp = d.join(format!("s{i}.txt"));
                fs::write(&inp, format!("item-{i}")).unwrap();
                (inp, d.join(format!("s{i}.txt.out")))
            })
            .collect();
        let mut inst = app.startup().unwrap();
        inst.run_batch(&pairs).unwrap();
        for (i, o) in &pairs {
            assert_eq!(
                fs::read_to_string(o).unwrap(),
                fs::read_to_string(i).unwrap()
            );
        }
        let inv =
            fs::read_to_string(d.join("stream-invocations")).unwrap();
        assert_eq!(inv.lines().count(), 1, "one spawn for the batch");
    }

    #[test]
    fn stream_command_per_item_fallback_still_works() {
        let d = tmp("stream-item");
        let script = write_stream_script(&d);
        let app =
            CommandStreamApp::new(vec![script.display().to_string()])
                .unwrap();
        let inp = d.join("one.txt");
        fs::write(&inp, "solo").unwrap();
        let out = d.join("one.txt.out");
        let mut inst = app.startup().unwrap();
        inst.process(&inp, &out).unwrap();
        assert_eq!(fs::read_to_string(&out).unwrap(), "solo");
        // A second per-item call respawns (the instance outlived its
        // batch contract) and still works.
        let inp2 = d.join("two.txt");
        fs::write(&inp2, "again").unwrap();
        let out2 = d.join("two.txt.out");
        inst.process(&inp2, &out2).unwrap();
        assert_eq!(fs::read_to_string(&out2).unwrap(), "again");
    }

    #[test]
    fn stream_command_failure_fails_the_batch() {
        let d = tmp("stream-fail");
        let p = d.join("failing.sh");
        fs::write(&p, "#!/bin/sh\nread -r line\nexit 7\n").unwrap();
        make_exec(&p);
        let app =
            CommandStreamApp::new(vec![p.display().to_string()]).unwrap();
        let pairs = vec![
            (d.join("a"), d.join("a.out")),
            (d.join("b"), d.join("b.out")),
        ];
        let mut inst = app.startup().unwrap();
        let err = inst.run_batch(&pairs).unwrap_err().to_string();
        assert!(err.contains("exit status"), "{err}");
    }

    #[test]
    fn stream_empty_batch_is_noop_and_drop_reaps_child() {
        let d = tmp("stream-empty");
        let script = write_stream_script(&d);
        let app =
            CommandStreamApp::new(vec![script.display().to_string()])
                .unwrap();
        {
            let mut inst = app.startup().unwrap();
            inst.run_batch(&[]).unwrap();
        } // drop closes stdin; child exits on EOF and is reaped
        assert!(
            fs::read_to_string(d.join("stream-invocations"))
                .unwrap()
                .lines()
                .count()
                == 1
        );
    }

    #[test]
    fn batched_wire_specs_carry_protocol_prefix() {
        let s = CommandStreamApp::new(vec![
            "prog".into(),
            "ref.txt".into(),
        ])
        .unwrap();
        assert_eq!(s.wire_spec(), "stream:prog ref.txt");
        let m = CommandMimoApp::new(
            vec!["prog".into(), "ref.txt".into()],
            tmp("wire-lists"),
        )
        .unwrap();
        assert_eq!(m.wire_spec(), "mimo:prog ref.txt");
    }

    #[test]
    fn command_reducer_contract() {
        let d = tmp("reduce");
        fs::write(d.join("a.out"), "1\n").unwrap();
        fs::write(d.join("b.out"), "2\n").unwrap();
        let p = d.join("red.sh");
        fs::write(&p, "#!/bin/sh\ncat \"$1\"/*.out > \"$2\"\n").unwrap();
        make_exec(&p);
        let red = CommandReducer::new(vec![p.display().to_string()]).unwrap();
        let out = d.join("merged");
        red.reduce(&d, &out).unwrap();
        assert_eq!(fs::read_to_string(&out).unwrap(), "1\n2\n");
        // External reducers can't fold partials: --overlap must fall
        // back to the barrier for them.
        assert!(!red.supports_partial());
    }

    #[test]
    fn empty_argv_rejected() {
        assert!(CommandApp::new(vec![]).is_err());
        assert!(CommandReducer::new(vec![]).is_err());
    }
}
