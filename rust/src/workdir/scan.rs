//! Input discovery — step 1 of Fig 1.
//!
//! "LLMapReduce identifies the input files to be processed by scanning a
//! given input directory or reading a list from a given input file."
//! With `--subdir=true` the scan recurses (§II-A) and the relative
//! directory structure is preserved so the output tree can be replicated.

use std::fs;
use std::path::{Path, PathBuf};

use crate::error::{Error, IoContext, Result};

/// One discovered input file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InputFile {
    /// Absolute (or input-rooted) path to the file.
    pub path: PathBuf,
    /// Path relative to the scan root — drives output-tree replication.
    pub relative: PathBuf,
}

impl InputFile {
    /// File name component as utf-8 (input files are named by generators
    /// and users; non-utf8 names are rejected at scan time).
    pub fn file_name(&self) -> &str {
        self.path
            .file_name()
            .and_then(|n| n.to_str())
            .expect("scan guarantees utf-8 names")
    }
}

/// Scan an input *source*: a directory (flat or recursive) or a list file.
///
/// Results are sorted by relative path so planning is deterministic — the
/// scheduler's task numbering in the paper is stable for a given input
/// directory, and tests rely on the same property.
pub fn scan_input(input: &Path, recursive: bool) -> Result<Vec<InputFile>> {
    let meta = fs::metadata(input).map_err(|e| Error::InputScan {
        path: input.to_path_buf(),
        reason: e.to_string(),
    })?;
    let mut files = if meta.is_dir() {
        scan_dir(input, recursive)?
    } else {
        read_list_file(input)?
    };
    files.sort_by(|a, b| a.relative.cmp(&b.relative));
    if files.is_empty() {
        return Err(Error::EmptyInput(input.to_path_buf()));
    }
    Ok(files)
}

fn scan_dir(root: &Path, recursive: bool) -> Result<Vec<InputFile>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let entries = fs::read_dir(&dir).at(&dir)?;
        for entry in entries {
            let entry = entry.at(&dir)?;
            let path = entry.path();
            let ftype = entry.file_type().at(&path)?;
            if ftype.is_dir() {
                if recursive && !is_hidden(&path) {
                    stack.push(path);
                }
                continue;
            }
            if !ftype.is_file() {
                continue; // sockets, fifos — not data
            }
            if is_hidden(&path) {
                continue; // .MAPRED.* and dotfiles are never inputs
            }
            let relative = path
                .strip_prefix(root)
                .expect("entry under root")
                .to_path_buf();
            check_utf8(&path)?;
            out.push(InputFile { path, relative });
        }
    }
    Ok(out)
}

/// Read an explicit list file: one input path per line, `#` comments and
/// blank lines skipped.  Relative paths resolve against the list file's
/// parent directory.
fn read_list_file(list: &Path) -> Result<Vec<InputFile>> {
    let text = fs::read_to_string(list).at(list)?;
    let base = list.parent().unwrap_or_else(|| Path::new("."));
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let path = if Path::new(line).is_absolute() {
            PathBuf::from(line)
        } else {
            base.join(line)
        };
        if !path.is_file() {
            return Err(Error::InputScan {
                path: list.to_path_buf(),
                reason: format!(
                    "line {}: '{}' is not a file",
                    lineno + 1,
                    line
                ),
            });
        }
        let relative = PathBuf::from(
            path.file_name().expect("file path has a name"),
        );
        check_utf8(&path)?;
        out.push(InputFile { path, relative });
    }
    Ok(out)
}

fn is_hidden(path: &Path) -> bool {
    path.file_name()
        .and_then(|n| n.to_str())
        .map(|n| n.starts_with('.'))
        .unwrap_or(false)
}

fn check_utf8(path: &Path) -> Result<()> {
    if path.file_name().and_then(|n| n.to_str()).is_none() {
        return Err(Error::InputScan {
            path: path.to_path_buf(),
            reason: "non-utf8 file name".into(),
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs::File;
    use std::io::Write;

    fn mkdirs(root: &Path, files: &[&str]) {
        for f in files {
            let p = root.join(f);
            fs::create_dir_all(p.parent().unwrap()).unwrap();
            File::create(&p).unwrap();
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "llmr-scan-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn flat_scan_sorted_and_complete() {
        let d = tmpdir("flat");
        mkdirs(&d, &["b.dat", "a.dat", "c.dat"]);
        let files = scan_input(&d, false).unwrap();
        let names: Vec<_> = files.iter().map(|f| f.file_name()).collect();
        assert_eq!(names, vec!["a.dat", "b.dat", "c.dat"]);
    }

    #[test]
    fn flat_scan_skips_subdirs() {
        let d = tmpdir("skipsub");
        mkdirs(&d, &["a.dat", "sub/b.dat"]);
        let files = scan_input(&d, false).unwrap();
        assert_eq!(files.len(), 1);
        assert_eq!(files[0].file_name(), "a.dat");
    }

    #[test]
    fn recursive_scan_preserves_relative_paths() {
        let d = tmpdir("rec");
        mkdirs(&d, &["x/1.dat", "x/y/2.dat", "3.dat"]);
        let files = scan_input(&d, true).unwrap();
        let rels: Vec<_> = files
            .iter()
            .map(|f| f.relative.to_str().unwrap().to_string())
            .collect();
        assert_eq!(rels, vec!["3.dat", "x/1.dat", "x/y/2.dat"]);
    }

    #[test]
    fn hidden_files_excluded() {
        let d = tmpdir("hidden");
        mkdirs(&d, &["a.dat", ".hidden", ".MAPRED.123/run_llmap_1"]);
        let files = scan_input(&d, true).unwrap();
        assert_eq!(files.len(), 1);
    }

    #[test]
    fn empty_dir_is_error() {
        let d = tmpdir("empty");
        assert!(matches!(
            scan_input(&d, false),
            Err(Error::EmptyInput(_))
        ));
    }

    #[test]
    fn missing_input_is_error() {
        let d = tmpdir("gone").join("nope");
        assert!(matches!(
            scan_input(&d, false),
            Err(Error::InputScan { .. })
        ));
    }

    #[test]
    fn list_file_with_comments() {
        let d = tmpdir("list");
        mkdirs(&d, &["a.dat", "b.dat"]);
        let list = d.join("inputs.list");
        let mut f = File::create(&list).unwrap();
        writeln!(f, "# comment\n\na.dat\nb.dat").unwrap();
        let files = scan_input(&list, false).unwrap();
        assert_eq!(files.len(), 2);
        assert!(files[0].path.is_file());
    }

    #[test]
    fn list_file_bad_entry_is_error() {
        let d = tmpdir("badlist");
        let list = d.join("inputs.list");
        let mut f = File::create(&list).unwrap();
        writeln!(f, "missing.dat").unwrap();
        let err = scan_input(&list, false).unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn list_file_absolute_paths() {
        let d = tmpdir("abslist");
        mkdirs(&d, &["a.dat"]);
        let list = d.join("inputs.list");
        let mut f = File::create(&list).unwrap();
        writeln!(f, "{}", d.join("a.dat").display()).unwrap();
        let files = scan_input(&list, false).unwrap();
        assert_eq!(files.len(), 1);
    }
}
