//! Generated script artifacts (Figs 8, 9, 12).
//!
//! LLMapReduce's observable products on a real cluster are *text files*:
//! one submission script and one run script per array task (plus, in MIMO
//! mode, one `input_<N>` pair-list per task).  We generate the same files
//! with the same names and shapes, so the `.MAPRED.PID` directory of this
//! reproduction is recognizable next to the paper's figures, and golden
//! tests can pin the formats.

use crate::error::Result;
use crate::mapreduce::planner::Plan;
use crate::options::{AppType, Options};
use crate::scheduler::dialect::{Dialect, SubmitRequest};
use crate::workdir::MapRedDir;

/// Render the run script for one SISO task (Fig 9): the wrapper is
/// invoked once per (input, output) pair.
pub fn siso_run_script(
    mapper: &str,
    pairs: &[(std::path::PathBuf, std::path::PathBuf)],
) -> String {
    let mut s = String::new();
    s.push_str("#!/bin/bash\n");
    s.push_str("export PATH=${PATH}:.\n");
    for (input, output) in pairs {
        s.push_str(&format!(
            "{mapper} {} {}\n",
            input.display(),
            output.display()
        ));
    }
    s
}

/// Render the run script for one MIMO task (Fig 12): the wrapper is
/// invoked once with the generated pair-list file.
pub fn mimo_run_script(mapper: &str, input_list: &std::path::Path) -> String {
    format!(
        "#!/bin/bash\nexport PATH=${{PATH}}:.\n{mapper} {}\n",
        input_list.display()
    )
}

/// Render the MIMO pair list (`input_<N>`): one "input output" line per
/// file, the format Fig 11's wrapper reads with `strsplit`.
pub fn mimo_input_list(
    pairs: &[(std::path::PathBuf, std::path::PathBuf)],
) -> String {
    let mut s = String::new();
    for (input, output) in pairs {
        s.push_str(&format!("{} {}\n", input.display(), output.display()));
    }
    s
}

/// Render the run script for one SPMD task (`--spmd`): the persistent
/// wrapper is launched once and consumes the tab-separated pair list on
/// **stdin** — the item-stream protocol that lets unmodified per-item
/// binaries gang via the generated wrapper while stream-aware apps read
/// items until EOF.
pub fn spmd_run_script(mapper: &str, input_list: &std::path::Path) -> String {
    format!(
        "#!/bin/bash\nexport PATH=${{PATH}}:.\n{mapper} < {}\n",
        input_list.display()
    )
}

/// Render the SPMD item stream (`input_<N>`): one `input<TAB>output`
/// line per item, the frame a stream-capable app reads off stdin until
/// EOF (tab-separated so paths containing spaces stay unambiguous).
pub fn spmd_input_list(
    pairs: &[(std::path::PathBuf, std::path::PathBuf)],
) -> String {
    let mut s = String::new();
    for (input, output) in pairs {
        s.push_str(&format!("{}\t{}\n", input.display(), output.display()));
    }
    s
}

/// Render the run script for the reduce task: reducer gets the map output
/// directory and the reduce output filename (§II).
pub fn reduce_run_script(
    reducer: &str,
    map_output_dir: &std::path::Path,
    redout: &std::path::Path,
) -> String {
    format!(
        "#!/bin/bash\nexport PATH=${{PATH}}:.\n{reducer} {} {}\n",
        map_output_dir.display(),
        redout.display()
    )
}

/// Everything written for one submission.
#[derive(Debug)]
pub struct GeneratedScripts {
    pub submit_script: std::path::PathBuf,
    pub run_scripts: Vec<std::path::PathBuf>,
    pub mimo_inputs: Vec<std::path::PathBuf>,
}

/// Write submission + run scripts (+ MIMO pair lists) for a plan into the
/// `.MAPRED.PID` directory — the file set Figs 8/9/12 show.
pub fn write_all(
    wd: &MapRedDir,
    plan: &Plan,
    opts: &Options,
    dialect: &dyn Dialect,
) -> Result<GeneratedScripts> {
    let mapred_name = wd
        .path()
        .file_name()
        .and_then(|n| n.to_str())
        .unwrap_or(".MAPRED.0")
        .to_string();

    let mut run_scripts = Vec::with_capacity(plan.tasks.len());
    let mut mimo_inputs = Vec::new();

    for task in &plan.tasks {
        // The plan's apptype (not the raw option) decides the script
        // shape: under --spmd the planner switched the mode itself.
        let script = match plan.apptype {
            AppType::Siso => siso_run_script(&opts.mapper, &task.pairs),
            AppType::Mimo => {
                let list_path = wd.mimo_input(task.task_id);
                let list_name = format!("input_{}", task.task_id);
                wd.write(&list_name, &mimo_input_list(&task.pairs))?;
                mimo_inputs.push(list_path.clone());
                mimo_run_script(&opts.mapper, &list_path)
            }
            AppType::Spmd => {
                let list_path = wd.mimo_input(task.task_id);
                let list_name = format!("input_{}", task.task_id);
                wd.write(&list_name, &spmd_input_list(&task.pairs))?;
                mimo_inputs.push(list_path.clone());
                spmd_run_script(&opts.mapper, &list_path)
            }
        };
        let name = format!("run_llmap_{}", task.task_id);
        run_scripts.push(wd.write(&name, &script)?);
    }

    let req = SubmitRequest {
        job_name: &opts.mapper,
        tasks: plan.tasks.len(),
        mapred_dir: &mapred_name,
        exclusive: opts.exclusive,
        depends_on: None,
        extra_options: &opts.scheduler_options,
    };
    let submit = wd.write("submit.sh", &dialect.submission_script(&req))?;

    Ok(GeneratedScripts {
        submit_script: submit,
        run_scripts,
        mimo_inputs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::planner::plan;
    use crate::options::SchedulerKind;
    use crate::scheduler::dialect::dialect_for;
    use crate::workdir::scan::InputFile;
    use std::fs;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-scripts-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn fake_files(n: usize) -> Vec<InputFile> {
        (0..n)
            .map(|i| InputFile {
                path: PathBuf::from(format!("input/im{i}.ppm")),
                relative: PathBuf::from(format!("im{i}.ppm")),
            })
            .collect()
    }

    #[test]
    fn siso_run_script_matches_fig9_shape() {
        let pairs = vec![(
            PathBuf::from("input/im1.ppm"),
            PathBuf::from("output/im1.ppm.out"),
        )];
        let s = siso_run_script("MatlabCmd.sh", &pairs);
        assert_eq!(
            s,
            "#!/bin/bash\nexport PATH=${PATH}:.\n\
             MatlabCmd.sh input/im1.ppm output/im1.ppm.out\n"
        );
    }

    #[test]
    fn mimo_run_script_matches_fig12_shape() {
        let s = mimo_run_script(
            "MatlabCmdMulti.sh",
            std::path::Path::new("./.MAPRED.2188/input_1"),
        );
        assert_eq!(
            s,
            "#!/bin/bash\nexport PATH=${PATH}:.\n\
             MatlabCmdMulti.sh ./.MAPRED.2188/input_1\n"
        );
    }

    #[test]
    fn mimo_input_list_is_pair_lines() {
        let pairs = vec![
            (PathBuf::from("a.ppm"), PathBuf::from("a.ppm.gray")),
            (PathBuf::from("b.ppm"), PathBuf::from("b.ppm.gray")),
        ];
        assert_eq!(
            mimo_input_list(&pairs),
            "a.ppm a.ppm.gray\nb.ppm b.ppm.gray\n"
        );
    }

    #[test]
    fn write_all_siso_layout() {
        let base = tmp("siso");
        let wd = MapRedDir::create(&base, 1120, true).unwrap();
        let opts = Options::new("input", "output", "MatlabCmd.sh")
            .np(2)
            .pid(1120);
        let d = dialect_for(SchedulerKind::GridEngine);
        let p = plan(&fake_files(6), &opts, d.as_ref()).unwrap();
        let gen = write_all(&wd, &p, &opts, d.as_ref()).unwrap();
        assert_eq!(gen.run_scripts.len(), 2);
        assert!(gen.mimo_inputs.is_empty());
        // Submission script exists and references the run scripts.
        let submit = fs::read_to_string(&gen.submit_script).unwrap();
        assert!(submit.contains("-t 1-2"));
        assert!(submit.contains("run_llmap_$SGE_TASK_ID"));
        // Run script 1 processes its block of 3 files, one exec per file.
        let run1 = fs::read_to_string(&gen.run_scripts[0]).unwrap();
        assert_eq!(run1.matches("MatlabCmd.sh ").count(), 3);
    }

    #[test]
    fn write_all_mimo_layout() {
        let base = tmp("mimo");
        let wd = MapRedDir::create(&base, 2188, true).unwrap();
        let opts = Options::new("input", "output", "MatlabCmdMulti.sh")
            .np(2)
            .apptype(AppType::Mimo)
            .pid(2188);
        let d = dialect_for(SchedulerKind::GridEngine);
        let p = plan(&fake_files(6), &opts, d.as_ref()).unwrap();
        let gen = write_all(&wd, &p, &opts, d.as_ref()).unwrap();
        assert_eq!(gen.mimo_inputs.len(), 2);
        // Each run script launches the wrapper exactly once (Fig 12).
        for (i, rs) in gen.run_scripts.iter().enumerate() {
            let text = fs::read_to_string(rs).unwrap();
            assert_eq!(text.matches("MatlabCmdMulti.sh").count(), 1);
            assert!(text.contains(&format!("input_{}", i + 1)));
        }
        // Pair lists cover all 6 files.
        let total_lines: usize = gen
            .mimo_inputs
            .iter()
            .map(|p| fs::read_to_string(p).unwrap().lines().count())
            .sum();
        assert_eq!(total_lines, 6);
    }

    #[test]
    fn write_all_spmd_layout() {
        let base = tmp("spmd");
        let wd = MapRedDir::create(&base, 3001, true).unwrap();
        let opts = Options::new("input", "output", "StreamCmd.sh")
            .items_per_task(4)
            .pid(3001);
        let d = dialect_for(SchedulerKind::GridEngine);
        let p = plan(&fake_files(6), &opts, d.as_ref()).unwrap();
        let gen = write_all(&wd, &p, &opts, d.as_ref()).unwrap();
        assert_eq!(gen.mimo_inputs.len(), 2, "ceil(6/4) batches");
        // Each run script launches the wrapper once, fed on stdin.
        for rs in &gen.run_scripts {
            let text = fs::read_to_string(rs).unwrap();
            assert_eq!(text.matches("StreamCmd.sh").count(), 1);
            assert!(text.contains("StreamCmd.sh < "), "stdin protocol");
        }
        // Item streams are tab-separated and cover all 6 files.
        let mut total_lines = 0;
        for list in &gen.mimo_inputs {
            let text = fs::read_to_string(list).unwrap();
            for line in text.lines() {
                assert_eq!(line.matches('\t').count(), 1, "{line}");
                total_lines += 1;
            }
        }
        assert_eq!(total_lines, 6);
    }

    #[test]
    fn spmd_scripts_shape() {
        let s = spmd_run_script(
            "WordFreqStream.sh",
            std::path::Path::new("./.MAPRED.3001/input_1"),
        );
        assert_eq!(
            s,
            "#!/bin/bash\nexport PATH=${PATH}:.\n\
             WordFreqStream.sh < ./.MAPRED.3001/input_1\n"
        );
        let pairs = vec![
            (PathBuf::from("a b.ppm"), PathBuf::from("a b.ppm.out")),
        ];
        assert_eq!(spmd_input_list(&pairs), "a b.ppm\ta b.ppm.out\n");
    }

    #[test]
    fn reduce_script_contract() {
        let s = reduce_run_script(
            "ReduceWordFreqCmd.sh",
            std::path::Path::new("output"),
            std::path::Path::new("llmapreduce.out"),
        );
        assert!(s.contains("ReduceWordFreqCmd.sh output llmapreduce.out"));
    }
}
