//! The `.MAPRED.<PID>` temporary working directory (§II).
//!
//! "LLMapReduce generates all the necessary temporary files under the
//! directory, .MAPRED.PID, where the PID is the process identification
//! number.  [...] By default, LLMapReduce will delete the .MAPRED.PID
//! directory after the job is completed.  However, users can keep the
//! temporary directory for debugging purpose with the --keep=true option."

pub mod scan;
pub mod scripts;

use std::fs;
use std::path::{Path, PathBuf};

use crate::error::{IoContext, Result};

/// Handle to a live `.MAPRED.<PID>` directory.  Dropping it deletes the
/// directory unless `keep` was requested (or `persist()` was called).
#[derive(Debug)]
pub struct MapRedDir {
    path: PathBuf,
    keep: bool,
}

impl MapRedDir {
    /// Create `.MAPRED.<pid>` under `base` (the job's working directory).
    pub fn create(base: &Path, pid: u32, keep: bool) -> Result<MapRedDir> {
        let path = base.join(format!(".MAPRED.{pid}"));
        fs::create_dir_all(&path).at(&path)?;
        Ok(MapRedDir { path, keep })
    }

    /// Adopt an *existing* `.MAPRED.<pid>` directory left behind by a
    /// crashed run (used by `llmapreduce resume`): same drop semantics
    /// as [`MapRedDir::create`], but the directory must already exist —
    /// a resumed invocation re-uses the crashed run's artifacts and
    /// journal rather than regenerating them.
    pub fn adopt(path: &Path, keep: bool) -> Result<MapRedDir> {
        fs::metadata(path).at(path)?;
        Ok(MapRedDir {
            path: path.to_path_buf(),
            keep,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn keep(&self) -> bool {
        self.keep
    }

    /// Path of the per-task run script (Fig 9 / Fig 12: `run_llmap_<N>`,
    /// 1-based like the scheduler's task ids).
    pub fn run_script(&self, task_id: usize) -> PathBuf {
        self.path.join(format!("run_llmap_{task_id}"))
    }

    /// Path of the per-task MIMO pair-list file (Fig 12: `input_<N>`).
    pub fn mimo_input(&self, task_id: usize) -> PathBuf {
        self.path.join(format!("input_{task_id}"))
    }

    /// Path of the generated submission script.
    pub fn submit_script(&self) -> PathBuf {
        self.path.join("submit.sh")
    }

    /// Path of the per-task log file (Fig 8 names them
    /// `llmap.log-$JOB_ID-$TASK_ID`; job id is known at submit time).
    pub fn log_file(&self, job_id: u64, task_id: usize) -> PathBuf {
        self.path.join(format!("llmap.log-{job_id}-{task_id}"))
    }

    /// Write a file inside the directory.
    pub fn write(&self, name: &str, contents: &str) -> Result<PathBuf> {
        let p = self.path.join(name);
        fs::write(&p, contents).at(&p)?;
        Ok(p)
    }

    /// Keep the directory alive past drop (used when handing ownership to
    /// a running job).
    pub fn persist(mut self) -> PathBuf {
        self.keep = true;
        self.path.clone()
    }
}

impl Drop for MapRedDir {
    fn drop(&mut self) {
        if !self.keep {
            let _ = fs::remove_dir_all(&self.path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-wd-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn creates_mapred_pid_dir() {
        let base = tmp("create");
        let wd = MapRedDir::create(&base, 1120, false).unwrap();
        assert!(wd.path().ends_with(".MAPRED.1120"));
        assert!(wd.path().is_dir());
    }

    #[test]
    fn default_drop_deletes() {
        let base = tmp("drop");
        let path;
        {
            let wd = MapRedDir::create(&base, 7, false).unwrap();
            path = wd.path().to_path_buf();
            wd.write("x", "y").unwrap();
        }
        assert!(!path.exists(), "deleted on drop without --keep");
    }

    #[test]
    fn keep_preserves() {
        let base = tmp("keep");
        let path;
        {
            let wd = MapRedDir::create(&base, 8, true).unwrap();
            path = wd.path().to_path_buf();
        }
        assert!(path.exists(), "--keep=true preserves the directory");
    }

    #[test]
    fn adopt_requires_existing_dir_and_cleans_up() {
        let base = tmp("adopt");
        let wd = MapRedDir::create(&base, 11, true).unwrap();
        let path = wd.path().to_path_buf();
        drop(wd);
        assert!(path.exists());
        {
            let adopted = MapRedDir::adopt(&path, false).unwrap();
            assert_eq!(adopted.path(), path.as_path());
        }
        assert!(!path.exists(), "adopted dir removed on drop");
        assert!(MapRedDir::adopt(&path, false).is_err());
    }

    #[test]
    fn persist_overrides_cleanup() {
        let base = tmp("persist");
        let wd = MapRedDir::create(&base, 9, false).unwrap();
        let path = wd.persist();
        assert!(path.exists());
    }

    #[test]
    fn file_name_conventions_match_paper() {
        let base = tmp("names");
        let wd = MapRedDir::create(&base, 2188, false).unwrap();
        // Fig 12: .MAPRED.2188/run_llmap_1 and .MAPRED.2188/input_1
        assert!(wd.run_script(1).ends_with(".MAPRED.2188/run_llmap_1"));
        assert!(wd.mimo_input(1).ends_with(".MAPRED.2188/input_1"));
        // Fig 8: llmap.log-$JOB_ID-$TASK_ID
        assert!(wd.log_file(42, 3).ends_with(".MAPRED.2188/llmap.log-42-3"));
    }
}
