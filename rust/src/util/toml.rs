//! Minimal TOML subset parser (the `toml` crate is unavailable offline).
//!
//! Supports the subset the config system uses:
//!
//! * `[section]` and `[section.subsection]` headers;
//! * `key = value` with string (`"..."`), integer, float, boolean and
//!   string-array (`["a", "b"]`) values;
//! * `#` comments and blank lines.
//!
//! Everything is stored flattened as `section.key` -> [`TomlValue`].

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed TOML scalar or string array.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    StrArray(Vec<String>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_int().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str_array(&self) -> Option<&[String]> {
        match self {
            TomlValue::StrArray(a) => Some(a),
            _ => None,
        }
    }
}

/// A flattened TOML document: `section.key` -> value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    /// Parse a document.
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| {
                Error::Config(format!("line {}: {msg}", lineno + 1))
            };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated section header"))?
                    .trim();
                if name.is_empty() {
                    return Err(err("empty section name"));
                }
                section = name.to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| err("expected key = value"))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let value = parse_value(value.trim())
                .ok_or_else(|| err(&format!("bad value '{}'", value.trim())))?;
            if entries.insert(full_key.clone(), value).is_some() {
                return Err(err(&format!("duplicate key '{full_key}'")));
            }
        }
        Ok(TomlDoc { entries })
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    /// All keys under a `section.` prefix.
    pub fn section_keys(&self, section: &str) -> Vec<&str> {
        let prefix = format!("{section}.");
        self.entries
            .keys()
            .filter(|k| k.starts_with(&prefix))
            .map(String::as_str)
            .collect()
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<TomlValue> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"')?;
        // No escape support beyond \" and \\ — config strings are paths
        // and option strings.
        return Some(TomlValue::Str(
            inner.replace("\\\"", "\"").replace("\\\\", "\\"),
        ));
    }
    if s == "true" {
        return Some(TomlValue::Bool(true));
    }
    if s == "false" {
        return Some(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']')?.trim();
        if inner.is_empty() {
            return Some(TomlValue::StrArray(vec![]));
        }
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            let item = part.strip_prefix('"')?.strip_suffix('"')?;
            items.push(item.to_string());
        }
        return Some(TomlValue::StrArray(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Some(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Some(TomlValue::Float(f));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let doc = TomlDoc::parse(
            r#"
# llmapreduce cluster profile
[cluster]
nodes = 16
slots_per_node = 16        # cores
dispatch_latency_ms = 50
jitter = 0.05
name = "supercloud"

[job]
np = 256
apptype = "mimo"
options = ["-l mem=8G", "-q long"]
exclusive = false
"#,
        )
        .unwrap();
        assert_eq!(doc.get("cluster.nodes").unwrap().as_int(), Some(16));
        assert_eq!(doc.get("cluster.jitter").unwrap().as_float(), Some(0.05));
        assert_eq!(
            doc.get("cluster.name").unwrap().as_str(),
            Some("supercloud")
        );
        assert_eq!(doc.get("job.apptype").unwrap().as_str(), Some("mimo"));
        assert_eq!(
            doc.get("job.options").unwrap().as_str_array().unwrap(),
            &["-l mem=8G".to_string(), "-q long".to_string()]
        );
        assert_eq!(doc.get("job.exclusive").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn keys_without_section() {
        let doc = TomlDoc::parse("engine = \"sim\"\n").unwrap();
        assert_eq!(doc.get("engine").unwrap().as_str(), Some("sim"));
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = TomlDoc::parse("key = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("key").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unterminated\n").is_err());
        assert!(TomlDoc::parse("key value\n").is_err());
        assert!(TomlDoc::parse("k = \n").is_err());
        assert!(TomlDoc::parse("k = 1\nk = 2\n").is_err());
        assert!(TomlDoc::parse("[]\nk = 1\n").is_err());
    }

    #[test]
    fn section_keys_listing() {
        let doc =
            TomlDoc::parse("[a]\nx = 1\ny = 2\n[b]\nz = 3\n").unwrap();
        assert_eq!(doc.section_keys("a"), vec!["a.x", "a.y"]);
        assert_eq!(doc.section_keys("b"), vec!["b.z"]);
    }

    #[test]
    fn negative_and_float_values() {
        let doc = TomlDoc::parse("a = -3\nb = 2.5\nc = -0.25\n").unwrap();
        assert_eq!(doc.get("a").unwrap().as_int(), Some(-3));
        assert_eq!(doc.get("b").unwrap().as_float(), Some(2.5));
        assert_eq!(doc.get("c").unwrap().as_float(), Some(-0.25));
    }

    #[test]
    fn empty_array() {
        let doc = TomlDoc::parse("a = []\n").unwrap();
        assert_eq!(doc.get("a").unwrap().as_str_array().unwrap().len(), 0);
    }
}
