//! Deterministic pseudo-random number generation.
//!
//! The crates.io `rand` family is not available in this offline build, so
//! the workload generators and the simulator's jitter model use this
//! self-contained implementation: SplitMix64 for seeding and
//! xoshiro256** for the stream (public-domain algorithms by Blackman &
//! Vigna).  Determinism matters more than statistical perfection here —
//! every experiment in EXPERIMENTS.md must replay bit-identically from its
//! seed.

/// SplitMix64 step: used to expand a single u64 seed into stream state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** deterministic RNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator.  Identical seeds give identical streams on all
    /// platforms.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-file generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> mantissa.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).  Uses Lemire rejection for lack of bias.
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.next_below((hi - lo + 1) as u64) as usize
    }

    /// Standard normal via Box–Muller (adequate for synthetic matrices).
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from an (unnormalized) weight table.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.next_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues reachable");
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let x = r.range(5, 8);
            assert!((5..=8).contains(&x));
            lo_seen |= x == 5;
            hi_seen |= x == 8;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffled order changed");
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(21);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
