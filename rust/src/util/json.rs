//! Minimal JSON parser/writer.
//!
//! `serde` / `serde_json` are not available in this offline build, so the
//! artifact manifest (written by `python/compile/aot.py`) and the metrics
//! reports use this hand-rolled implementation.  It supports the full JSON
//! grammar except for exotic number forms beyond f64, which is all the
//! manifest needs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.  Objects use a BTreeMap so output is canonical.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Json(format!(
                "trailing garbage at byte {}",
                p.pos
            )));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building reports.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build a `Json::Obj` from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            let combined = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8: back up and take the char.
                    let start = self.pos - 1;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let ch = text.chars().next().unwrap();
                    self.pos = start + ch.len_utf8();
                    let _ = c;
                    s.push(ch);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("short \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"hi\\nthere\"").unwrap(),
            Json::Str("hi\nthere".into())
        );
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": "c"}], "d": {"e": null}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d").unwrap().get("e"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn parse_real_utf8() {
        assert_eq!(
            Json::parse("\"héllo\"").unwrap(),
            Json::Str("héllo".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let doc = r#"{"arr":[1,2.5,true,null],"s":"x\"y"}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = obj(vec![
            ("name", "table1".into()),
            ("speedup", 2.41.into()),
            ("rows", vec![1usize, 2, 3].into()),
        ]);
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_aot_manifest_shape() {
        let doc = r#"{
          "format": "hlo-text",
          "entries": {
            "matmul_pair": {
              "file": "matmul_pair.hlo.txt",
              "inputs": [{"shape": [128, 128], "dtype": "float32"}],
              "outputs": "tuple"
            }
          }
        }"#;
        let v = Json::parse(doc).unwrap();
        let entry = v.get("entries").unwrap().get("matmul_pair").unwrap();
        assert_eq!(entry.get("file").unwrap().as_str(), Some("matmul_pair.hlo.txt"));
        let shape = entry.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap();
        let dims: Vec<usize> =
            shape.as_arr().unwrap().iter().map(|d| d.as_usize().unwrap()).collect();
        assert_eq!(dims, vec![128, 128]);
    }

    #[test]
    fn integers_keep_integer_form() {
        assert_eq!(Json::Num(75000.0).to_string_compact(), "75000");
        assert_eq!(Json::Num(2.41).to_string_compact(), "2.41");
    }
}
