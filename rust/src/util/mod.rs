//! Small self-contained substrates that offline builds can't pull from
//! crates.io: deterministic RNG, JSON, and human-readable formatting.

pub mod json;
pub mod rng;
pub mod toml;

/// Absolutize a relative path against `base` (no-op for absolute
/// paths; `base = None` leaves relative paths untouched).  Used by the
/// remote engine, which ships paths to workers that share the
/// filesystem but not necessarily the working directory.
pub fn absolutize_in(
    base: Option<&std::path::Path>,
    path: &std::path::Path,
) -> std::path::PathBuf {
    if path.is_absolute() {
        return path.to_path_buf();
    }
    match base {
        Some(b) => b.join(path),
        None => path.to_path_buf(),
    }
}

/// [`absolutize_in`] against the current working directory.
pub fn absolutize(path: &std::path::Path) -> std::path::PathBuf {
    let cwd = std::env::current_dir().ok();
    absolutize_in(cwd.as_deref(), path)
}

/// Format a duration in engineering units (ns/µs/ms/s).
pub fn fmt_duration(d: std::time::Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

/// Format a count with thousands separators (43,580 files).
pub fn fmt_count(n: usize) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn durations() {
        assert_eq!(fmt_duration(Duration::from_nanos(12)), "12ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50ms");
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
    }

    #[test]
    fn counts() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(43580), "43,580");
        assert_eq!(fmt_count(1_000_000), "1,000,000");
    }
}
