//! Crate-wide error type.
//!
//! Every fallible public API in the crate returns [`Result`].  The variants
//! mirror the subsystems: option parsing, input scanning, scheduling,
//! runtime (PJRT), and app execution.

use std::path::PathBuf;

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// All the ways an LLMapReduce job can fail.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Bad or inconsistent command-line / API options (Fig 2 surface).
    #[error("invalid option: {0}")]
    InvalidOption(String),

    /// Input discovery failed (missing directory, unreadable list file...).
    #[error("input scan failed at {path}: {reason}")]
    InputScan { path: PathBuf, reason: String },

    /// No input files matched — the paper's model has nothing to map over.
    #[error("no input files found under {0}")]
    EmptyInput(PathBuf),

    /// Scheduler rejected or lost a job.
    #[error("scheduler error: {0}")]
    Scheduler(String),

    /// A job exceeded the dialect's array-task limit and --np/--ndata
    /// could not be reconciled.
    #[error("array job of {requested} tasks exceeds {dialect} limit of {limit}")]
    ArrayLimit {
        requested: usize,
        limit: usize,
        dialect: String,
    },

    /// PJRT / XLA runtime failure (artifact load, compile, execute).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Artifact missing or failed manifest validation.
    #[error("artifact error for '{name}': {reason}")]
    Artifact { name: String, reason: String },

    /// A mapper or reducer application failed on a concrete input.
    #[error("app '{app}' failed on {input}: {reason}")]
    App {
        app: String,
        input: PathBuf,
        reason: String,
    },

    /// Malformed data file (PPM image, matrix list, manifest JSON ...).
    #[error("malformed {kind} file {path}: {reason}")]
    Format {
        kind: &'static str,
        path: PathBuf,
        reason: String,
    },

    /// JSON parse error (hand-rolled parser in util::json).
    #[error("json error: {0}")]
    Json(String),

    /// Configuration file problem.
    #[error("config error: {0}")]
    Config(String),

    /// Plain I/O, with context attached where it happened.
    #[error("io error at {path}: {source}")]
    Io {
        path: PathBuf,
        #[source]
        source: std::io::Error,
    },
}

impl Error {
    /// Attach a path to a raw `io::Error`.
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> Self {
        Error::Io {
            path: path.into(),
            source,
        }
    }

    /// Shorthand for an option-validation failure.
    pub fn opt(msg: impl Into<String>) -> Self {
        Error::InvalidOption(msg.into())
    }
}

/// Extension to add path context to `io::Result` in one call.
pub trait IoContext<T> {
    fn at(self, path: impl Into<PathBuf>) -> Result<T>;
}

impl<T> IoContext<T> for std::io::Result<T> {
    fn at(self, path: impl Into<PathBuf>) -> Result<T> {
        self.map_err(|e| Error::io(path, e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_messages_are_informative() {
        let e = Error::ArrayLimit {
            requested: 100_000,
            limit: 75_000,
            dialect: "gridengine".into(),
        };
        let msg = e.to_string();
        assert!(msg.contains("100000"));
        assert!(msg.contains("75000"));
        assert!(msg.contains("gridengine"));
    }

    #[test]
    fn io_context_attaches_path() {
        let r: std::io::Result<()> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.at("/some/path").unwrap_err();
        assert!(e.to_string().contains("/some/path"));
    }

    #[test]
    fn opt_shorthand() {
        assert!(Error::opt("--np must be > 0")
            .to_string()
            .contains("--np must be > 0"));
    }
}
