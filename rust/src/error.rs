//! Crate-wide error type.
//!
//! Every fallible public API in the crate returns [`Result`].  The variants
//! mirror the subsystems: option parsing, input scanning, scheduling,
//! runtime (PJRT), and app execution.

use std::path::PathBuf;

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// All the ways an LLMapReduce job can fail.
///
/// `Display` and `std::error::Error` are implemented by hand (rather
/// than derived via `thiserror`) so the crate's default build has zero
/// external dependencies — it compiles offline with a bare toolchain.
#[derive(Debug)]
pub enum Error {
    /// Bad or inconsistent command-line / API options (Fig 2 surface).
    InvalidOption(String),

    /// Input discovery failed (missing directory, unreadable list file...).
    InputScan { path: PathBuf, reason: String },

    /// No input files matched — the paper's model has nothing to map over.
    EmptyInput(PathBuf),

    /// Scheduler rejected or lost a job.
    Scheduler(String),

    /// A job exceeded the dialect's array-task limit and --np/--ndata
    /// could not be reconciled.
    ArrayLimit {
        requested: usize,
        limit: usize,
        dialect: String,
    },

    /// PJRT / XLA runtime failure (artifact load, compile, execute).
    Runtime(String),

    /// Artifact missing or failed manifest validation.
    Artifact { name: String, reason: String },

    /// A mapper or reducer application failed on a concrete input.
    App {
        app: String,
        input: PathBuf,
        reason: String,
    },

    /// Malformed data file (PPM image, matrix list, manifest JSON ...).
    Format {
        kind: &'static str,
        path: PathBuf,
        reason: String,
    },

    /// JSON parse error (hand-rolled parser in util::json).
    Json(String),

    /// Configuration file problem.
    Config(String),

    /// Plain I/O, with context attached where it happened.
    Io {
        path: PathBuf,
        source: std::io::Error,
    },
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::InvalidOption(msg) => write!(f, "invalid option: {msg}"),
            Error::InputScan { path, reason } => write!(
                f,
                "input scan failed at {}: {reason}",
                path.display()
            ),
            Error::EmptyInput(path) => {
                write!(f, "no input files found under {}", path.display())
            }
            Error::Scheduler(msg) => write!(f, "scheduler error: {msg}"),
            Error::ArrayLimit {
                requested,
                limit,
                dialect,
            } => write!(
                f,
                "array job of {requested} tasks exceeds {dialect} limit \
                 of {limit}"
            ),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Artifact { name, reason } => {
                write!(f, "artifact error for '{name}': {reason}")
            }
            Error::App { app, input, reason } => write!(
                f,
                "app '{app}' failed on {}: {reason}",
                input.display()
            ),
            Error::Format { kind, path, reason } => write!(
                f,
                "malformed {kind} file {}: {reason}",
                path.display()
            ),
            Error::Json(msg) => write!(f, "json error: {msg}"),
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Io { path, source } => {
                write!(f, "io error at {}: {source}", path.display())
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl Error {
    /// Attach a path to a raw `io::Error`.
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> Self {
        Error::Io {
            path: path.into(),
            source,
        }
    }

    /// Shorthand for an option-validation failure.
    pub fn opt(msg: impl Into<String>) -> Self {
        Error::InvalidOption(msg.into())
    }
}

/// Extension to add path context to `io::Result` in one call.
pub trait IoContext<T> {
    fn at(self, path: impl Into<PathBuf>) -> Result<T>;
}

impl<T> IoContext<T> for std::io::Result<T> {
    fn at(self, path: impl Into<PathBuf>) -> Result<T> {
        self.map_err(|e| Error::io(path, e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_messages_are_informative() {
        let e = Error::ArrayLimit {
            requested: 100_000,
            limit: 75_000,
            dialect: "gridengine".into(),
        };
        let msg = e.to_string();
        assert!(msg.contains("100000"));
        assert!(msg.contains("75000"));
        assert!(msg.contains("gridengine"));
    }

    #[test]
    fn io_context_attaches_path() {
        let r: std::io::Result<()> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.at("/some/path").unwrap_err();
        assert!(e.to_string().contains("/some/path"));
    }

    #[test]
    fn opt_shorthand() {
        assert!(Error::opt("--np must be > 0")
            .to_string()
            .contains("--np must be > 0"));
    }
}
