//! The LLMapReduce option surface — Fig 2 of the paper, verbatim.
//!
//! ```text
//! LLMapReduce --np=number_of_tasks \
//!  --input=input_dir --output=output_dir \
//!  --mapper=myMapper --reducer=myReducer --redout=output_filename \
//!  --ndata=NdataPerTask --distribution=block|cyclic \
//!  --subdir=true|false --ext=myExt --delimeter=myExtDelimiter \
//!  --exclusive=true|false --keep=true|false --apptype=mimo|siso \
//!  --options=<scheduler_options_to_add>
//! ```
//!
//! Both `--delimeter` (the paper's spelling, Fig 2) and `--delimiter`
//! (the prose spelling, §II) are accepted.  Values may be given as
//! `--key=value` or `--key value`, matching the paper's own usage (Fig 7
//! uses `=`; Fig 15 uses spaces).

use std::path::PathBuf;

use crate::error::{Error, Result};
use crate::scheduler::journal::OnError;
use crate::util::json::{obj, Json};

/// How input files are spread over array tasks (§II, `--distribution`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Distribution {
    /// Contiguous runs of files per task (the paper's default).
    #[default]
    Block,
    /// Round-robin: file *i* goes to task *i mod np* (Fig 15).
    Cyclic,
}

impl Distribution {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "block" => Ok(Distribution::Block),
            "cyclic" => Ok(Distribution::Cyclic),
            other => Err(Error::opt(format!(
                "--distribution must be block|cyclic, got '{other}'"
            ))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Distribution::Block => "block",
            Distribution::Cyclic => "cyclic",
        }
    }
}

/// Application launch protocol (§II-B, `--apptype`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AppType {
    /// Single-input-single-output: one application launch per input file
    /// (repeated start-up cost).  The paper's default.
    #[default]
    Siso,
    /// Multiple-input-multiple-output: one launch per array task, fed a
    /// generated list of input/output pairs (Fig 11/17).
    Mimo,
    /// The SPMD morph that gives the paper its 10x headline: one
    /// *persistent* application instance per task consumes a packed
    /// batch of items through [`crate::apps::MapInstance::run_batch`]
    /// (command apps stream `input\toutput` lines over stdin), so the
    /// launch cost is paid once per batch instead of once per item.
    /// Selected by `--spmd` / `--items-per-task` rather than
    /// `--apptype` — Fig 2's surface stays verbatim.
    Spmd,
}

impl AppType {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "siso" => Ok(AppType::Siso),
            "mimo" => Ok(AppType::Mimo),
            "spmd" => Ok(AppType::Spmd),
            other => Err(Error::opt(format!(
                "--apptype must be mimo|siso|spmd, got '{other}'"
            ))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            AppType::Siso => "siso",
            AppType::Mimo => "mimo",
            AppType::Spmd => "spmd",
        }
    }
}

/// Which scheduler dialect generates the submission scripts.
/// (The paper supports "several schedulers such as SLURM, Grid Engine and
/// LSF" — §I; the dialect is orthogonal to the execution engine.)
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedulerKind {
    /// Open-source Grid Engine (the dialect shown in Fig 8).
    #[default]
    GridEngine,
    Slurm,
    Lsf,
}

impl SchedulerKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "gridengine" | "sge" | "ge" => Ok(SchedulerKind::GridEngine),
            "slurm" => Ok(SchedulerKind::Slurm),
            "lsf" => Ok(SchedulerKind::Lsf),
            other => Err(Error::opt(format!(
                "--scheduler must be gridengine|slurm|lsf, got '{other}'"
            ))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SchedulerKind::GridEngine => "gridengine",
            SchedulerKind::Slurm => "slurm",
            SchedulerKind::Lsf => "lsf",
        }
    }
}

/// The full Fig 2 option set, plus the engine/scheduler selectors this
/// reproduction adds (they do not exist in the paper because the paper had
/// a real cluster; see DESIGN.md §3 substitutions).
#[derive(Debug, Clone)]
pub struct Options {
    /// `--np`: number of array tasks.  `None` = one task per input file
    /// (the paper's DEFAULT mode).
    pub np: Option<usize>,
    /// `--ndata`: input files per task; overrides `--np` (§II).
    pub ndata: Option<usize>,
    /// `--input`: input directory or list file.
    pub input: PathBuf,
    /// `--output`: output directory.
    pub output: PathBuf,
    /// `--mapper`: map application (built-in name or executable path).
    pub mapper: String,
    /// `--reducer`: optional reduce application.
    pub reducer: Option<String>,
    /// `--redout`: reducer output file name (default `llmapreduce.out`).
    pub redout: String,
    /// `--distribution`: block|cyclic.
    pub distribution: Distribution,
    /// `--subdir`: recurse into the input tree and replicate it on output.
    pub subdir: bool,
    /// `--ext`: output extension (default "out").
    pub ext: String,
    /// `--delimeter`/`--delimiter`: extension delimiter (default ".").
    pub delimiter: String,
    /// `--exclusive`: whole-node allocation.
    pub exclusive: bool,
    /// `--keep`: keep the .MAPRED.PID directory for debugging.
    pub keep: bool,
    /// `--apptype`: siso|mimo.
    pub apptype: AppType,
    /// `--overlap`: overlapped map→reduce (reproduction extra, not in
    /// Fig 2).  When true and a reducer is given, reducer consumption
    /// starts per-mapper-task-completion via task-granularity scheduler
    /// dependencies instead of barriering on the whole map array job
    /// (DESIGN.md §4).  Ignored without a reducer, and falls back to the
    /// barrier under `--subdir` (the classic reducer scans only the top
    /// level of the output dir; overlap must not change the reduced file
    /// set).
    pub overlap: bool,
    /// `--spmd`: gang items into persistent app instances (reproduction
    /// extra; the SPMD morph of §II-B).  Overrides `--apptype` for
    /// execution: tasks run in [`AppType::Spmd`] mode, paying launch
    /// cost once per batch of [`Options::effective_items_per_task`]
    /// items instead of once per item.
    pub spmd: bool,
    /// `--items-per-task`: batch size for the SPMD morph.  Setting it
    /// implies `--spmd`; `--spmd` without it defaults to 16 items per
    /// batch.
    pub items_per_task: Option<usize>,
    /// `--options`: extra raw scheduler directives, passed through into the
    /// generated submission script.
    pub scheduler_options: Vec<String>,
    /// `--scheduler`: which dialect writes the scripts.
    pub scheduler: SchedulerKind,
    /// Process id used for the `.MAPRED.<PID>` name; defaults to the real
    /// pid, overridable for reproducible tests.
    pub pid: Option<u32>,
    /// Where `.MAPRED.<PID>` is created; defaults to the current working
    /// directory (the paper's behaviour).
    pub workdir: Option<PathBuf>,
    /// `--on-error`: what a task's terminal execution error does to the
    /// map job — `stop` (fail the job, historic default), `retry`
    /// (re-queue then dead-letter), `dlq` (dead-letter immediately),
    /// `skip` (drop silently).  `None` = stop.
    pub on_error: Option<OnError>,
    /// `--failure-threshold`: circuit breaker — halt the job once more
    /// than this fraction of its tasks have terminally errored.  `None`
    /// = 1.0 (breaker off).
    pub failure_threshold: Option<f64>,
    /// Write the crash journal under the `.MAPRED.<PID>` workdir
    /// (builder-only; on by default — benches flip it off to measure the
    /// fsync cost).
    pub journal: bool,
    /// `--telemetry`: publish job transitions to the telemetry bus and
    /// keep `status.json` live in the `.MAPRED.<PID>` workdir (on by
    /// default; `--telemetry=false` opts out, like `--journal` in the
    /// builder API).  See [`crate::telemetry`].
    pub telemetry: bool,
    /// `--trace`: persist per-task span timings on the journal's done
    /// records so `llmapreduce trace <workdir>` can rebuild the job
    /// timeline offline (on by default; `--trace=false` trims the
    /// journal back to the pre-trace shape).  No effect when the
    /// journal is off.  See [`crate::telemetry::trace`].
    pub trace: bool,
}

impl Default for Options {
    fn default() -> Self {
        Options {
            np: None,
            ndata: None,
            input: PathBuf::new(),
            output: PathBuf::new(),
            mapper: String::new(),
            reducer: None,
            redout: "llmapreduce.out".to_string(),
            distribution: Distribution::Block,
            subdir: false,
            ext: "out".to_string(),
            delimiter: ".".to_string(),
            exclusive: false,
            keep: false,
            apptype: AppType::Siso,
            overlap: false,
            spmd: false,
            items_per_task: None,
            scheduler_options: Vec::new(),
            scheduler: SchedulerKind::GridEngine,
            pid: None,
            workdir: None,
            on_error: None,
            failure_threshold: None,
            journal: true,
            telemetry: true,
            trace: true,
        }
    }
}

impl Options {
    /// Start building options for an input/output/mapper triple (the three
    /// mandatory arguments of every example in the paper).
    pub fn new(
        input: impl Into<PathBuf>,
        output: impl Into<PathBuf>,
        mapper: impl Into<String>,
    ) -> Self {
        Options {
            input: input.into(),
            output: output.into(),
            mapper: mapper.into(),
            ..Default::default()
        }
    }

    // -- builder-style setters (used by examples and tests) -----------------

    pub fn np(mut self, np: usize) -> Self {
        self.np = Some(np);
        self
    }
    pub fn ndata(mut self, ndata: usize) -> Self {
        self.ndata = Some(ndata);
        self
    }
    pub fn reducer(mut self, r: impl Into<String>) -> Self {
        self.reducer = Some(r.into());
        self
    }
    pub fn redout(mut self, r: impl Into<String>) -> Self {
        self.redout = r.into();
        self
    }
    pub fn distribution(mut self, d: Distribution) -> Self {
        self.distribution = d;
        self
    }
    pub fn subdir(mut self, on: bool) -> Self {
        self.subdir = on;
        self
    }
    pub fn ext(mut self, e: impl Into<String>) -> Self {
        self.ext = e.into();
        self
    }
    pub fn delimiter(mut self, d: impl Into<String>) -> Self {
        self.delimiter = d.into();
        self
    }
    pub fn exclusive(mut self, on: bool) -> Self {
        self.exclusive = on;
        self
    }
    pub fn keep(mut self, on: bool) -> Self {
        self.keep = on;
        self
    }
    pub fn apptype(mut self, t: AppType) -> Self {
        self.apptype = t;
        self
    }
    pub fn overlap(mut self, on: bool) -> Self {
        self.overlap = on;
        self
    }
    pub fn spmd(mut self, on: bool) -> Self {
        self.spmd = on;
        self
    }
    pub fn items_per_task(mut self, n: usize) -> Self {
        self.items_per_task = Some(n);
        self
    }
    pub fn scheduler(mut self, s: SchedulerKind) -> Self {
        self.scheduler = s;
        self
    }
    pub fn scheduler_option(mut self, o: impl Into<String>) -> Self {
        self.scheduler_options.push(o.into());
        self
    }
    pub fn pid(mut self, pid: u32) -> Self {
        self.pid = Some(pid);
        self
    }
    pub fn workdir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.workdir = Some(dir.into());
        self
    }
    pub fn on_error(mut self, p: OnError) -> Self {
        self.on_error = Some(p);
        self
    }
    pub fn failure_threshold(mut self, t: f64) -> Self {
        self.failure_threshold = Some(t);
        self
    }
    pub fn journal(mut self, on: bool) -> Self {
        self.journal = on;
        self
    }
    pub fn telemetry(mut self, on: bool) -> Self {
        self.telemetry = on;
        self
    }
    pub fn trace(mut self, on: bool) -> Self {
        self.trace = on;
        self
    }

    /// Parse from a command-line style argument vector (everything after
    /// the program name).  Accepts `--key=value` and `--key value`.
    pub fn parse_args<I, S>(args: I) -> Result<Options>
    where
        I: IntoIterator<Item = S>,
        S: AsRef<str>,
    {
        let mut opts = Options::default();
        let argv: Vec<String> =
            args.into_iter().map(|s| s.as_ref().to_string()).collect();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            let (key, inline_val) = match arg.split_once('=') {
                Some((k, v)) => (k.to_string(), Some(v.to_string())),
                None => (arg.clone(), None),
            };
            if !key.starts_with("--") {
                return Err(Error::opt(format!("unexpected argument '{arg}'")));
            }
            let mut take = || -> Result<String> {
                if let Some(v) = inline_val.clone() {
                    Ok(v)
                } else {
                    i += 1;
                    argv.get(i).cloned().ok_or_else(|| {
                        Error::opt(format!("{key} requires a value"))
                    })
                }
            };
            match key.as_str() {
                "--np" => opts.np = Some(parse_count(&key, &take()?)?),
                "--ndata" => opts.ndata = Some(parse_count(&key, &take()?)?),
                "--input" => opts.input = PathBuf::from(take()?),
                "--output" => opts.output = PathBuf::from(take()?),
                "--mapper" => opts.mapper = take()?,
                "--reducer" => opts.reducer = Some(take()?),
                "--redout" => opts.redout = take()?,
                "--distribution" => {
                    opts.distribution = Distribution::parse(&take()?)?
                }
                "--subdir" => opts.subdir = parse_bool(&key, &take()?)?,
                "--ext" => opts.ext = take()?,
                // Fig 2 spells it "delimeter"; the prose spells "delimiter".
                "--delimeter" | "--delimiter" => opts.delimiter = take()?,
                "--exclusive" => opts.exclusive = parse_bool(&key, &take()?)?,
                "--keep" => opts.keep = parse_bool(&key, &take()?)?,
                "--apptype" => opts.apptype = AppType::parse(&take()?)?,
                "--overlap" => opts.overlap = parse_bool(&key, &take()?)?,
                // `--spmd` works bare (a plain switch), as `--spmd=BOOL`,
                // and as `--spmd BOOL` — the bench scripts use the bare
                // form, the config/env layers the explicit one.
                "--spmd" => {
                    opts.spmd = match inline_val.clone() {
                        Some(v) => parse_bool(&key, &v)?,
                        None => match argv.get(i + 1).map(|s| s.as_str()) {
                            Some(
                                "true" | "false" | "1" | "0" | "yes" | "no",
                            ) => {
                                i += 1;
                                parse_bool(&key, &argv[i])?
                            }
                            _ => true,
                        },
                    }
                }
                "--items-per-task" => {
                    opts.items_per_task = Some(parse_count(&key, &take()?)?)
                }
                "--options" => opts.scheduler_options.push(take()?),
                "--scheduler" => {
                    opts.scheduler = SchedulerKind::parse(&take()?)?
                }
                "--workdir" => opts.workdir = Some(PathBuf::from(take()?)),
                "--on-error" => {
                    opts.on_error = Some(OnError::parse(&take()?)?)
                }
                // `--telemetry` mirrors `--spmd`'s three forms: bare
                // switch (redundant — it is on by default — but
                // harmless), `--telemetry=BOOL`, `--telemetry BOOL`.
                "--telemetry" => {
                    opts.telemetry = match inline_val.clone() {
                        Some(v) => parse_bool(&key, &v)?,
                        None => match argv.get(i + 1).map(|s| s.as_str()) {
                            Some(
                                "true" | "false" | "1" | "0" | "yes" | "no",
                            ) => {
                                i += 1;
                                parse_bool(&key, &argv[i])?
                            }
                            _ => true,
                        },
                    }
                }
                // `--trace` takes the same three forms as `--telemetry`.
                "--trace" => {
                    opts.trace = match inline_val.clone() {
                        Some(v) => parse_bool(&key, &v)?,
                        None => match argv.get(i + 1).map(|s| s.as_str()) {
                            Some(
                                "true" | "false" | "1" | "0" | "yes" | "no",
                            ) => {
                                i += 1;
                                parse_bool(&key, &argv[i])?
                            }
                            _ => true,
                        },
                    }
                }
                "--failure-threshold" => {
                    opts.failure_threshold =
                        Some(parse_fraction(&key, &take()?)?)
                }
                other => {
                    return Err(Error::opt(format!("unknown option '{other}'")))
                }
            }
            i += 1;
        }
        opts.validate()?;
        Ok(opts)
    }

    /// Check the option set is internally consistent.
    pub fn validate(&self) -> Result<()> {
        if self.input.as_os_str().is_empty() {
            return Err(Error::opt("--input is required"));
        }
        if self.output.as_os_str().is_empty() {
            return Err(Error::opt("--output is required"));
        }
        if self.mapper.is_empty() {
            return Err(Error::opt("--mapper is required"));
        }
        if self.np == Some(0) {
            return Err(Error::opt("--np must be > 0"));
        }
        if self.ndata == Some(0) {
            return Err(Error::opt("--ndata must be > 0"));
        }
        if self.ext.is_empty() {
            return Err(Error::opt("--ext must be non-empty"));
        }
        if self.redout.is_empty() {
            return Err(Error::opt("--redout must be non-empty"));
        }
        if self.items_per_task == Some(0) {
            return Err(Error::opt("--items-per-task must be > 0"));
        }
        if let Some(t) = self.failure_threshold {
            if !(0.0..=1.0).contains(&t) {
                return Err(Error::opt(format!(
                    "--failure-threshold must be within 0..=1, got {t}"
                )));
            }
        }
        Ok(())
    }

    /// Effective error policy for the map job (submitted through
    /// `JobSpec::error_policy` onto the engine-shared table path).
    pub fn effective_error_policy(
        &self,
    ) -> crate::scheduler::journal::ErrorPolicy {
        crate::scheduler::journal::ErrorPolicy {
            on_error: self.on_error.unwrap_or_default(),
            failure_threshold: self.failure_threshold.unwrap_or(1.0),
            ..crate::scheduler::journal::ErrorPolicy::default()
        }
    }

    /// Whether the SPMD morph is on: `--spmd` was given, or
    /// `--items-per-task` was given (an explicit batch size implies
    /// ganging).
    pub fn spmd_enabled(&self) -> bool {
        self.spmd || self.items_per_task.is_some()
    }

    /// Batch size for the SPMD morph: explicit `--items-per-task`, else
    /// 16 items per persistent instance (enough to amortize the launch
    /// cost by an order of magnitude on the Table 1 workloads without
    /// starving narrow fleets of parallelism).
    pub fn effective_items_per_task(&self) -> usize {
        self.items_per_task.unwrap_or(16)
    }

    /// The output file name for one input file: `<name><delim><ext>`
    /// (§III-A: "the output file name is determined by the name of the
    /// input file with the default extension, '.out'").
    pub fn output_name(&self, input_file_name: &str) -> String {
        format!("{input_file_name}{}{}", self.delimiter, self.ext)
    }

    /// Effective pid for the `.MAPRED.<PID>` directory.
    pub fn effective_pid(&self) -> u32 {
        self.pid.unwrap_or_else(std::process::id)
    }

    /// Serialize every field `resume` needs to re-plan this invocation
    /// identically (stored in the journal's `invocation` record).
    pub fn to_json(&self) -> Json {
        let opt_usize =
            |v: Option<usize>| v.map(Json::from).unwrap_or(Json::Null);
        obj(vec![
            ("np", opt_usize(self.np)),
            ("ndata", opt_usize(self.ndata)),
            ("input", self.input.display().to_string().into()),
            ("output", self.output.display().to_string().into()),
            ("mapper", self.mapper.as_str().into()),
            (
                "reducer",
                self.reducer
                    .as_deref()
                    .map(Json::from)
                    .unwrap_or(Json::Null),
            ),
            ("redout", self.redout.as_str().into()),
            ("distribution", self.distribution.as_str().into()),
            ("subdir", self.subdir.into()),
            ("ext", self.ext.as_str().into()),
            ("delimiter", self.delimiter.as_str().into()),
            ("exclusive", self.exclusive.into()),
            ("keep", self.keep.into()),
            ("apptype", self.apptype.as_str().into()),
            ("overlap", self.overlap.into()),
            ("spmd", self.spmd.into()),
            ("items_per_task", opt_usize(self.items_per_task)),
            (
                "scheduler_options",
                Json::Arr(
                    self.scheduler_options
                        .iter()
                        .map(|s| s.as_str().into())
                        .collect(),
                ),
            ),
            ("scheduler", self.scheduler.as_str().into()),
            (
                "pid",
                self.pid
                    .map(|p| Json::from(p as usize))
                    .unwrap_or(Json::Null),
            ),
            (
                "workdir",
                self.workdir
                    .as_ref()
                    .map(|p| Json::from(p.display().to_string()))
                    .unwrap_or(Json::Null),
            ),
            (
                "on_error",
                self.on_error
                    .map(|p| Json::from(p.as_str()))
                    .unwrap_or(Json::Null),
            ),
            (
                "failure_threshold",
                self.failure_threshold
                    .map(Json::from)
                    .unwrap_or(Json::Null),
            ),
            ("journal", self.journal.into()),
            ("telemetry", self.telemetry.into()),
            ("trace", self.trace.into()),
        ])
    }

    /// Rebuild an option set from [`Options::to_json`] output.  Missing
    /// keys fall back to defaults (forward compatible with journals
    /// written by older builds).
    pub fn from_json(doc: &Json) -> Result<Options> {
        let bad = |what: &str| {
            Error::opt(format!("invalid serialized options: {what}"))
        };
        let s = |key: &str| -> Option<String> {
            doc.get(key).and_then(Json::as_str).map(str::to_string)
        };
        let u = |key: &str| -> Option<usize> {
            doc.get(key).and_then(Json::as_usize)
        };
        let b = |key: &str, dflt: bool| -> bool {
            doc.get(key).and_then(Json::as_bool).unwrap_or(dflt)
        };
        let dflt = Options::default();
        let distribution = match s("distribution") {
            Some(d) => Distribution::parse(&d)?,
            None => dflt.distribution,
        };
        let apptype = match s("apptype") {
            Some(a) => AppType::parse(&a)?,
            None => dflt.apptype,
        };
        let scheduler = match s("scheduler") {
            Some(k) => SchedulerKind::parse(&k)?,
            None => dflt.scheduler,
        };
        let on_error = match s("on_error") {
            Some(p) => Some(OnError::parse(&p)?),
            None => None,
        };
        let scheduler_options = match doc.get("scheduler_options") {
            Some(Json::Arr(arr)) => arr
                .iter()
                .filter_map(|v| v.as_str().map(str::to_string))
                .collect(),
            _ => Vec::new(),
        };
        let opts = Options {
            np: u("np"),
            ndata: u("ndata"),
            input: PathBuf::from(s("input").ok_or_else(|| bad("input"))?),
            output: PathBuf::from(
                s("output").ok_or_else(|| bad("output"))?,
            ),
            mapper: s("mapper").ok_or_else(|| bad("mapper"))?,
            reducer: s("reducer"),
            redout: s("redout").unwrap_or(dflt.redout),
            distribution,
            subdir: b("subdir", false),
            ext: s("ext").unwrap_or(dflt.ext),
            delimiter: s("delimiter").unwrap_or(dflt.delimiter),
            exclusive: b("exclusive", false),
            keep: b("keep", false),
            apptype,
            overlap: b("overlap", false),
            spmd: b("spmd", false),
            items_per_task: u("items_per_task"),
            scheduler_options,
            scheduler,
            pid: u("pid").map(|p| p as u32),
            workdir: s("workdir").map(PathBuf::from),
            on_error,
            failure_threshold: doc
                .get("failure_threshold")
                .and_then(Json::as_f64),
            journal: b("journal", true),
            telemetry: b("telemetry", true),
            trace: b("trace", true),
        };
        opts.validate()?;
        Ok(opts)
    }
}

/// Options of the `llmapreduce worker` subcommand (reproduction extra:
/// the daemon side of `--engine=remote`, DESIGN.md §6).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerOptions {
    /// `--connect=host:port`: the coordinator to register with.
    pub connect: String,
    /// `--slots=N`: concurrent task capacity advertised (default 1).
    pub slots: usize,
    /// `--name=S`: report attribution name (default `worker-<pid>`).
    pub name: Option<String>,
    /// `--heartbeat-ms=N`: liveness beacon period (default 500).
    pub heartbeat_ms: u64,
    /// `--fail-after=N`: chaos knob — drop the connection cold upon
    /// receiving the Nth assignment (fault-tolerance testing).
    pub fail_after: Option<usize>,
    /// `--wire=json|binary`: preferred post-handshake framing,
    /// negotiated with the coordinator (default json — interoperates
    /// with any coordinator, and stays greppable on the wire).
    pub wire: crate::scheduler::remote::protocol::WireMode,
}

impl WorkerOptions {
    /// Parse the argument vector after `llmapreduce worker`.  Accepts
    /// `--key=value` and `--key value`, like the Fig 2 surface.
    pub fn parse_args<I, S>(args: I) -> Result<WorkerOptions>
    where
        I: IntoIterator<Item = S>,
        S: AsRef<str>,
    {
        let mut connect = None;
        let mut slots = 1usize;
        let mut name = None;
        let mut heartbeat_ms = 500u64;
        let mut fail_after = None;
        let mut wire = crate::scheduler::remote::protocol::WireMode::Json;
        let argv: Vec<String> =
            args.into_iter().map(|s| s.as_ref().to_string()).collect();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            let (key, inline_val) = match arg.split_once('=') {
                Some((k, v)) => (k.to_string(), Some(v.to_string())),
                None => (arg.clone(), None),
            };
            let mut take = || -> Result<String> {
                if let Some(v) = inline_val.clone() {
                    Ok(v)
                } else {
                    i += 1;
                    argv.get(i).cloned().ok_or_else(|| {
                        Error::opt(format!("{key} requires a value"))
                    })
                }
            };
            match key.as_str() {
                "--connect" => connect = Some(take()?),
                "--slots" => slots = parse_count(&key, &take()?)?,
                "--name" => name = Some(take()?),
                "--heartbeat-ms" => {
                    heartbeat_ms = parse_count(&key, &take()?)? as u64
                }
                "--fail-after" => {
                    fail_after = Some(parse_count(&key, &take()?)?)
                }
                "--wire" => {
                    wire = crate::scheduler::remote::protocol::WireMode::parse(
                        &take()?,
                    )?
                }
                other => {
                    return Err(Error::opt(format!(
                        "unknown worker option '{other}'"
                    )))
                }
            }
            i += 1;
        }
        let connect = connect.ok_or_else(|| {
            Error::opt("worker requires --connect=host:port")
        })?;
        if connect.is_empty() {
            return Err(Error::opt("--connect must be non-empty"));
        }
        if slots == 0 {
            return Err(Error::opt("--slots must be > 0"));
        }
        if heartbeat_ms == 0 {
            return Err(Error::opt("--heartbeat-ms must be > 0"));
        }
        if fail_after == Some(0) {
            return Err(Error::opt("--fail-after must be > 0"));
        }
        Ok(WorkerOptions {
            connect,
            slots,
            name,
            heartbeat_ms,
            fail_after,
            wire,
        })
    }
}

fn parse_count(key: &str, s: &str) -> Result<usize> {
    s.parse::<usize>()
        .map_err(|_| Error::opt(format!("{key} expects a positive integer, got '{s}'")))
}

fn parse_fraction(key: &str, s: &str) -> Result<f64> {
    s.parse::<f64>().ok().filter(|v| v.is_finite()).ok_or_else(|| {
        Error::opt(format!("{key} expects a number, got '{s}'"))
    })
}

fn parse_bool(key: &str, s: &str) -> Result<bool> {
    match s.to_ascii_lowercase().as_str() {
        "true" | "1" | "yes" => Ok(true),
        "false" | "0" | "no" => Ok(false),
        other => Err(Error::opt(format!(
            "{key} expects true|false, got '{other}'"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> Vec<&'static str> {
        vec!["--input=in", "--output=out", "--mapper=myMapper"]
    }

    #[test]
    fn fig7_style_equals_form() {
        // Fig 7: LLMapReduce --mapper=MatlabCmd.sh --input=input --output=output
        let o = Options::parse_args([
            "--mapper=MatlabCmd.sh",
            "--input=input",
            "--output=output",
        ])
        .unwrap();
        assert_eq!(o.mapper, "MatlabCmd.sh");
        assert_eq!(o.np, None); // DEFAULT mode: one task per file
        assert_eq!(o.apptype, AppType::Siso);
        assert_eq!(o.ext, "out");
        assert_eq!(o.delimiter, ".");
    }

    #[test]
    fn fig15_style_space_form() {
        // Fig 15: LLMapReduce --np 3 --mapper WordFreqCmd.sh --reducer ... --distribution cyclic
        let o = Options::parse_args([
            "--np", "3",
            "--mapper", "WordFreqCmd.sh",
            "--reducer", "ReduceWordFreqCmd.sh",
            "--input", "input",
            "--output", "output",
            "--distribution", "cyclic",
        ])
        .unwrap();
        assert_eq!(o.np, Some(3));
        assert_eq!(o.distribution, Distribution::Cyclic);
        assert_eq!(o.reducer.as_deref(), Some("ReduceWordFreqCmd.sh"));
    }

    #[test]
    fn fig16_mimo() {
        let o = Options::parse_args([
            "--np", "3",
            "--mapper", "WordFreqCmdMulti.sh",
            "--reducer", "ReduceWordFreqCmd.sh",
            "--input", "input",
            "--output", "output",
            "--apptype", "mimo",
        ])
        .unwrap();
        assert_eq!(o.apptype, AppType::Mimo);
    }

    #[test]
    fn both_delimiter_spellings() {
        for spelling in ["--delimeter=_", "--delimiter=_"] {
            let mut args = base();
            args.push(spelling);
            let o = Options::parse_args(args).unwrap();
            assert_eq!(o.delimiter, "_");
        }
    }

    #[test]
    fn ext_changes_output_name() {
        // Fig 10: --ext=gray gives ".gray" instead of ".out".
        let mut args = base();
        args.push("--ext=gray");
        let o = Options::parse_args(args).unwrap();
        assert_eq!(o.output_name("image1.ppm"), "image1.ppm.gray");
    }

    #[test]
    fn custom_delimiter_in_output_name() {
        let o = Options::new("i", "o", "m").ext("gray").delimiter("_");
        assert_eq!(o.output_name("img"), "img_gray");
    }

    #[test]
    fn missing_required_args_rejected() {
        assert!(Options::parse_args(["--input=i", "--output=o"]).is_err());
        assert!(Options::parse_args(["--input=i", "--mapper=m"]).is_err());
        assert!(Options::parse_args(["--output=o", "--mapper=m"]).is_err());
    }

    #[test]
    fn zero_counts_rejected() {
        let mut args = base();
        args.push("--np=0");
        assert!(Options::parse_args(args).is_err());
        let mut args = base();
        args.push("--ndata=0");
        assert!(Options::parse_args(args).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let mut args = base();
        args.push("--bogus=1");
        let err = Options::parse_args(args).unwrap_err().to_string();
        assert!(err.contains("--bogus"), "{err}");
    }

    #[test]
    fn bad_enum_values_rejected() {
        for bad in [
            "--distribution=diagonal",
            "--apptype=simo",
            "--scheduler=pbs",
            "--subdir=maybe",
        ] {
            let mut args = base();
            args.push(bad);
            assert!(Options::parse_args(args).is_err(), "{bad}");
        }
    }

    #[test]
    fn overlap_flag_parses_and_defaults_off() {
        let o = Options::parse_args(base()).unwrap();
        assert!(!o.overlap, "overlap is opt-in");
        let mut args = base();
        args.push("--overlap=true");
        assert!(Options::parse_args(args).unwrap().overlap);
        let mut args = base();
        args.push("--overlap=sideways");
        assert!(Options::parse_args(args).is_err());
        assert!(Options::new("i", "o", "m").overlap(true).overlap);
    }

    #[test]
    fn spmd_flags_parse_and_default_off() {
        let o = Options::parse_args(base()).unwrap();
        assert!(!o.spmd, "spmd is opt-in");
        assert_eq!(o.items_per_task, None);
        assert!(!o.spmd_enabled());
        assert_eq!(o.effective_items_per_task(), 16, "documented default");

        // Bare switch, = form, and space form all work.
        let mut args = base();
        args.push("--spmd");
        let o = Options::parse_args(args).unwrap();
        assert!(o.spmd && o.spmd_enabled());

        let mut args = base();
        args.push("--spmd=true");
        assert!(Options::parse_args(args).unwrap().spmd);

        let o = Options::parse_args([
            "--input=in", "--output=out", "--mapper=m", "--spmd", "false",
        ])
        .unwrap();
        assert!(!o.spmd);

        // Bare --spmd followed by another flag must not eat the flag.
        let o = Options::parse_args([
            "--input=in", "--output=out", "--spmd", "--mapper=m",
        ])
        .unwrap();
        assert!(o.spmd);
        assert_eq!(o.mapper, "m");
    }

    #[test]
    fn items_per_task_implies_spmd_and_rejects_zero() {
        let mut args = base();
        args.push("--items-per-task=8");
        let o = Options::parse_args(args).unwrap();
        assert!(!o.spmd, "flag itself untouched");
        assert!(o.spmd_enabled(), "explicit batch size implies ganging");
        assert_eq!(o.effective_items_per_task(), 8);

        let mut args = base();
        args.push("--items-per-task=0");
        assert!(Options::parse_args(args).is_err());

        let o = Options::new("i", "o", "m").spmd(true).items_per_task(4);
        assert!(o.spmd_enabled());
        assert_eq!(o.effective_items_per_task(), 4);
    }

    #[test]
    fn apptype_spmd_parses() {
        assert_eq!(AppType::parse("spmd").unwrap(), AppType::Spmd);
        assert_eq!(AppType::Spmd.as_str(), "spmd");
        let mut args = base();
        args.push("--apptype=spmd");
        let o = Options::parse_args(args).unwrap();
        assert_eq!(o.apptype, AppType::Spmd);
    }

    #[test]
    fn options_passthrough_accumulates() {
        let mut args = base();
        args.push("--options=-l mem=8G");
        args.push("--options=-q long");
        let o = Options::parse_args(args).unwrap();
        assert_eq!(o.scheduler_options, vec!["-l mem=8G", "-q long"]);
    }

    #[test]
    fn missing_value_is_error() {
        let mut args = base();
        args.push("--np");
        assert!(Options::parse_args(args).is_err());
    }

    #[test]
    fn worker_options_parse_both_forms() {
        let w = WorkerOptions::parse_args([
            "--connect=127.0.0.1:7171",
            "--slots=4",
            "--name=w1",
        ])
        .unwrap();
        assert_eq!(w.connect, "127.0.0.1:7171");
        assert_eq!(w.slots, 4);
        assert_eq!(w.name.as_deref(), Some("w1"));
        assert_eq!(w.heartbeat_ms, 500, "default beacon period");
        assert_eq!(w.fail_after, None);
        assert_eq!(
            w.wire,
            crate::scheduler::remote::protocol::WireMode::Json,
            "line JSON stays the default framing"
        );

        let w = WorkerOptions::parse_args([
            "--connect", "host:9000",
            "--heartbeat-ms", "250",
            "--fail-after", "2",
            "--wire", "binary",
        ])
        .unwrap();
        assert_eq!(w.connect, "host:9000");
        assert_eq!(w.slots, 1, "default one slot");
        assert_eq!(w.heartbeat_ms, 250);
        assert_eq!(w.fail_after, Some(2));
        assert_eq!(
            w.wire,
            crate::scheduler::remote::protocol::WireMode::Binary
        );
    }

    #[test]
    fn worker_options_validation() {
        assert!(WorkerOptions::parse_args::<[&str; 0], &str>([]).is_err());
        assert!(WorkerOptions::parse_args(["--slots=2"]).is_err());
        assert!(
            WorkerOptions::parse_args(["--connect=h:1", "--slots=0"])
                .is_err()
        );
        assert!(WorkerOptions::parse_args([
            "--connect=h:1",
            "--fail-after=0"
        ])
        .is_err());
        assert!(WorkerOptions::parse_args([
            "--connect=h:1",
            "--bogus=1"
        ])
        .is_err());
        assert!(
            WorkerOptions::parse_args(["--connect=h:1", "--wire=zstd"])
                .is_err(),
            "--wire is strict: a typo must not silently fall back"
        );
    }

    #[test]
    fn error_policy_flags_parse_and_validate() {
        let o = Options::parse_args(base()).unwrap();
        assert_eq!(o.on_error, None, "stop is the default");
        assert_eq!(o.failure_threshold, None);
        let p = o.effective_error_policy();
        assert_eq!(p.on_error, OnError::Stop);
        assert_eq!(p.failure_threshold, 1.0, "breaker off by default");

        let mut args = base();
        args.push("--on-error=dlq");
        args.push("--failure-threshold=0.25");
        let o = Options::parse_args(args).unwrap();
        assert_eq!(o.on_error, Some(OnError::Dlq));
        assert_eq!(o.failure_threshold, Some(0.25));
        assert_eq!(o.effective_error_policy().on_error, OnError::Dlq);

        let mut args = base();
        args.push("--failure-threshold=1.5");
        assert!(Options::parse_args(args).is_err(), "out of 0..=1");
        let mut args = base();
        args.push("--on-error=explode");
        assert!(Options::parse_args(args).is_err());
    }

    #[test]
    fn telemetry_flag_parses_and_defaults_on() {
        let o = Options::parse_args(base()).unwrap();
        assert!(o.telemetry, "telemetry is on by default");

        // Opt-out: = form and space form.
        let mut args = base();
        args.push("--telemetry=false");
        assert!(!Options::parse_args(args).unwrap().telemetry);
        let o = Options::parse_args([
            "--input=in",
            "--output=out",
            "--mapper=m",
            "--telemetry",
            "false",
        ])
        .unwrap();
        assert!(!o.telemetry);

        // Bare --telemetry followed by another flag must not eat it.
        let o = Options::parse_args([
            "--input=in", "--output=out", "--telemetry", "--mapper=m",
        ])
        .unwrap();
        assert!(o.telemetry);
        assert_eq!(o.mapper, "m");

        let mut args = base();
        args.push("--telemetry=sideways");
        assert!(Options::parse_args(args).is_err());

        assert!(!Options::new("i", "o", "m").telemetry(false).telemetry);
    }

    #[test]
    fn telemetry_survives_the_json_roundtrip() {
        let o = Options::new("in", "out", "m").telemetry(false);
        let text = o.to_json().to_string_compact();
        let back =
            Options::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(!back.telemetry, "explicit opt-out round-trips");
        // Journals from builds without the key fall back to the default.
        let old = Options::new("in", "out", "m").to_json();
        let mut doc = match old {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        doc.remove("telemetry");
        let back = Options::from_json(&Json::Obj(doc)).unwrap();
        assert!(back.telemetry, "missing key means default-on");
    }

    #[test]
    fn trace_flag_parses_and_defaults_on() {
        let o = Options::parse_args(base()).unwrap();
        assert!(o.trace, "tracing is on by default");

        // Opt-out: = form and space form.
        let mut args = base();
        args.push("--trace=false");
        assert!(!Options::parse_args(args).unwrap().trace);
        let o = Options::parse_args([
            "--input=in",
            "--output=out",
            "--mapper=m",
            "--trace",
            "false",
        ])
        .unwrap();
        assert!(!o.trace);

        // Bare --trace followed by another flag must not eat it.
        let o = Options::parse_args([
            "--input=in", "--output=out", "--trace", "--mapper=m",
        ])
        .unwrap();
        assert!(o.trace);
        assert_eq!(o.mapper, "m");

        let mut args = base();
        args.push("--trace=sideways");
        assert!(Options::parse_args(args).is_err());

        assert!(!Options::new("i", "o", "m").trace(false).trace);
    }

    #[test]
    fn trace_survives_the_json_roundtrip() {
        let o = Options::new("in", "out", "m").trace(false);
        let text = o.to_json().to_string_compact();
        let back =
            Options::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(!back.trace, "explicit opt-out round-trips");

        // Journals from builds without the key fall back to the default.
        let old = Options::new("in", "out", "m").to_json();
        let mut doc = match old {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        doc.remove("trace");
        let back = Options::from_json(&Json::Obj(doc)).unwrap();
        assert!(back.trace, "missing key means default-on");
    }

    #[test]
    fn options_json_roundtrip_for_resume() {
        let o = Options::new("in", "out", "wordcount")
            .np(4)
            .reducer("wordcount-reducer")
            .distribution(Distribution::Cyclic)
            .overlap(true)
            .spmd(true)
            .items_per_task(8)
            .on_error(OnError::Retry)
            .failure_threshold(0.5)
            .keep(true)
            .pid(7)
            .workdir("/tmp/w")
            .scheduler_option("-q long");
        let text = o.to_json().to_string_compact();
        let back =
            Options::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.np, Some(4));
        assert_eq!(back.mapper, "wordcount");
        assert_eq!(back.reducer.as_deref(), Some("wordcount-reducer"));
        assert_eq!(back.distribution, Distribution::Cyclic);
        assert!(back.overlap && back.spmd && back.keep);
        assert_eq!(back.items_per_task, Some(8));
        assert_eq!(back.on_error, Some(OnError::Retry));
        assert_eq!(back.failure_threshold, Some(0.5));
        assert_eq!(back.pid, Some(7));
        assert_eq!(back.workdir, Some(PathBuf::from("/tmp/w")));
        assert_eq!(back.scheduler_options, vec!["-q long"]);
        assert!(back.journal, "journaling survives the roundtrip");
    }

    #[test]
    fn builder_roundtrip() {
        let o = Options::new("in", "out", "map")
            .np(100)
            .ndata(5)
            .reducer("red")
            .distribution(Distribution::Cyclic)
            .apptype(AppType::Mimo)
            .subdir(true)
            .keep(true)
            .exclusive(true)
            .scheduler(SchedulerKind::Slurm)
            .pid(1120);
        o.validate().unwrap();
        assert_eq!(o.effective_pid(), 1120);
        assert_eq!(o.scheduler.as_str(), "slurm");
    }
}
