//! Configuration system: cluster profiles + job defaults from a TOML
//! file, overridable by environment variables and CLI flags.
//!
//! Precedence (lowest to highest): built-in defaults < config file
//! (`llmapreduce.toml`, or `$LLMR_CONFIG`) < `LLMR_*` environment
//! variables < explicit CLI options.
//!
//! ```toml
//! engine = "local"            # or "sim"
//!
//! [cluster]                   # simulator profile
//! nodes = 16
//! slots_per_node = 16
//! dispatch_latency_ms = 50
//! jitter = 0.05
//! failure_rate = 0.0
//! max_retries = 2
//! seed = 24261
//!
//! [job]                       # default Fig 2 options
//! np = 256
//! distribution = "cyclic"
//! apptype = "mimo"
//! scheduler = "slurm"
//! options = ["-l mem=8G"]
//!
//! [spmd]                      # SPMD ganging defaults
//! enabled = true
//! items_per_task = 16
//!
//! [errors]                    # failure handling (DESIGN.md §8)
//! on_error = "dlq"            # stop | retry | dlq | skip
//! failure_threshold = 0.25    # circuit breaker: fail job past this
//!
//! [telemetry]                 # observability (DESIGN.md §9)
//! enabled = true              # event bus + status.json per invocation
//! metrics_listen = "127.0.0.1:9900"   # /metrics + /status endpoint
//! trace = true                # per-task span timings (DESIGN.md §12)
//! ```

use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::error::{Error, IoContext, Result};
use crate::options::{AppType, Distribution, Options, SchedulerKind};
use crate::scheduler::journal::OnError;
use crate::scheduler::sim::ClusterConfig;
use crate::util::toml::TomlDoc;

/// Which engine executes jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    #[default]
    Local,
    Sim,
    /// Simulator that also executes payloads (virtual time, real output).
    SimExec,
    /// Distributed coordinator: tasks ship to `llmapreduce worker`
    /// daemons over TCP (DESIGN.md §6).
    Remote,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "local" => Ok(EngineKind::Local),
            "sim" => Ok(EngineKind::Sim),
            "sim-exec" | "simexec" => Ok(EngineKind::SimExec),
            "remote" => Ok(EngineKind::Remote),
            other => Err(Error::Config(format!(
                "engine must be local|sim|sim-exec|remote, got '{other}'"
            ))),
        }
    }
}

/// `[remote]` profile: how the coordinator fronts a worker fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct RemoteDefaults {
    /// Address the coordinator binds (`--listen`).
    pub listen: String,
    /// Workers to wait for before running jobs (`--min-workers`).
    pub min_workers: usize,
    /// Silence threshold after which a worker is declared dead and its
    /// in-flight tasks reassigned.
    pub heartbeat_timeout: Duration,
    /// Drain all ready tasks for a worker into one `AssignBatch` frame
    /// and overcommit its queue (`--batch-frames`, DESIGN.md §13).
    pub batch_frames: bool,
    /// Idle workers pull queued tasks from the most-backlogged peer
    /// when the central queue is dry (`--steal`).
    pub steal: bool,
}

impl Default for RemoteDefaults {
    fn default() -> Self {
        RemoteDefaults {
            listen: "127.0.0.1:7171".to_string(),
            min_workers: 1,
            heartbeat_timeout: Duration::from_secs(3),
            batch_frames: true,
            steal: true,
        }
    }
}

/// The resolved configuration.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub engine: EngineKind,
    pub cluster: ClusterConfig,
    /// Coordinator/worker profile for `engine = "remote"`.
    pub remote: RemoteDefaults,
    /// Job option defaults applied under explicit CLI values.
    pub job_defaults: JobDefaults,
    /// `[telemetry]` profile: observability surfaces (DESIGN.md §9).
    pub telemetry: TelemetryDefaults,
}

/// `[telemetry]` profile.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TelemetryDefaults {
    /// `[telemetry] enabled`: event bus + `status.json` per invocation.
    /// Telemetry defaults on; a config `false` switches it off for runs
    /// that do not pass `--telemetry` explicitly.
    pub enabled: Option<bool>,
    /// `[telemetry] metrics_listen`: bind a `/metrics` + `/status`
    /// endpoint on the remote coordinator (`--metrics-listen`).
    pub metrics_listen: Option<String>,
    /// `[telemetry] trace`: per-task span timings on journal done
    /// records (DESIGN.md §12).  Tracing defaults on; a config `false`
    /// switches it off for runs that do not pass `--trace` explicitly.
    pub trace: Option<bool>,
}

/// Optional defaults for the Fig 2 surface.
#[derive(Debug, Clone, Default)]
pub struct JobDefaults {
    pub np: Option<usize>,
    pub ndata: Option<usize>,
    pub distribution: Option<Distribution>,
    pub apptype: Option<AppType>,
    pub scheduler: Option<SchedulerKind>,
    pub ext: Option<String>,
    pub exclusive: Option<bool>,
    pub keep: Option<bool>,
    pub scheduler_options: Vec<String>,
    /// `[spmd] enabled`: gang items into persistent-instance batches.
    pub spmd: Option<bool>,
    /// `[spmd] items_per_task`: batch size for ganged tasks.
    pub items_per_task: Option<usize>,
    /// `[errors] on_error`: verdict for a task whose execution errors.
    pub on_error: Option<OnError>,
    /// `[errors] failure_threshold`: circuit-breaker error fraction.
    pub failure_threshold: Option<f64>,
}

impl Config {
    /// Load from a file, if it exists; otherwise defaults.
    pub fn load(path: &Path) -> Result<Config> {
        if !path.is_file() {
            return Ok(Config::default());
        }
        let text = std::fs::read_to_string(path).at(path)?;
        Config::parse(&text)
    }

    /// Locate and load: `$LLMR_CONFIG` or `./llmapreduce.toml`, then
    /// apply `LLMR_*` env overrides.
    pub fn discover() -> Result<Config> {
        let path = std::env::var("LLMR_CONFIG")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("llmapreduce.toml"));
        let mut config = Config::load(&path)?;
        config.apply_env_overrides(|k| std::env::var(k).ok());
        Ok(config)
    }

    /// Parse from TOML text.
    pub fn parse(text: &str) -> Result<Config> {
        let doc = TomlDoc::parse(text)?;
        let mut config = Config::default();

        if let Some(v) = doc.get("engine") {
            config.engine = EngineKind::parse(v.as_str().ok_or_else(
                || Error::Config("engine must be a string".into()),
            )?)?;
        }

        // [cluster]
        let c = &mut config.cluster;
        let usize_key = |doc: &TomlDoc, key: &str| -> Result<Option<usize>> {
            match doc.get(key) {
                None => Ok(None),
                Some(v) => v.as_usize().map(Some).ok_or_else(|| {
                    Error::Config(format!("{key} must be a non-negative int"))
                }),
            }
        };
        if let Some(n) = usize_key(&doc, "cluster.nodes")? {
            c.nodes = n.max(1);
        }
        if let Some(n) = usize_key(&doc, "cluster.slots_per_node")? {
            c.slots_per_node = n.max(1);
        }
        if let Some(ms) = usize_key(&doc, "cluster.dispatch_latency_ms")? {
            c.dispatch_latency = Duration::from_millis(ms as u64);
        }
        if let Some(v) = doc.get("cluster.jitter") {
            c.jitter = v.as_float().ok_or_else(|| {
                Error::Config("cluster.jitter must be a number".into())
            })?;
        }
        if let Some(v) = doc.get("cluster.failure_rate") {
            c.failure_rate = v.as_float().ok_or_else(|| {
                Error::Config("cluster.failure_rate must be a number".into())
            })?;
        }
        if let Some(n) = usize_key(&doc, "cluster.max_retries")? {
            c.max_retries = n;
        }
        if let Some(n) = usize_key(&doc, "cluster.seed")? {
            c.seed = n as u64;
        }
        if !(0.0..=1.0).contains(&c.failure_rate) {
            return Err(Error::Config(
                "cluster.failure_rate must be in [0, 1]".into(),
            ));
        }

        // [remote]
        if let Some(v) = doc.get("remote.listen") {
            config.remote.listen = v
                .as_str()
                .ok_or_else(|| {
                    Error::Config("remote.listen must be a string".into())
                })?
                .to_string();
        }
        if let Some(n) = usize_key(&doc, "remote.min_workers")? {
            config.remote.min_workers = n;
        }
        if let Some(ms) = usize_key(&doc, "remote.heartbeat_timeout_ms")? {
            config.remote.heartbeat_timeout =
                Duration::from_millis(ms as u64);
        }
        if let Some(b) =
            doc.get("remote.batch_frames").and_then(|v| v.as_bool())
        {
            config.remote.batch_frames = b;
        }
        if let Some(b) = doc.get("remote.steal").and_then(|v| v.as_bool())
        {
            config.remote.steal = b;
        }

        // [job]
        let j = &mut config.job_defaults;
        j.np = usize_key(&doc, "job.np")?;
        j.ndata = usize_key(&doc, "job.ndata")?;
        if let Some(v) = doc.get("job.distribution") {
            j.distribution = Some(Distribution::parse(
                v.as_str().unwrap_or_default(),
            )?);
        }
        if let Some(v) = doc.get("job.apptype") {
            j.apptype =
                Some(AppType::parse(v.as_str().unwrap_or_default())?);
        }
        if let Some(v) = doc.get("job.scheduler") {
            j.scheduler = Some(SchedulerKind::parse(
                v.as_str().unwrap_or_default(),
            )?);
        }
        if let Some(v) = doc.get("job.ext") {
            j.ext = v.as_str().map(str::to_string);
        }
        if let Some(v) = doc.get("job.exclusive") {
            j.exclusive = v.as_bool();
        }
        if let Some(v) = doc.get("job.keep") {
            j.keep = v.as_bool();
        }
        // [spmd]
        if let Some(v) = doc.get("spmd.enabled") {
            j.spmd = v.as_bool();
        }
        if let Some(n) = usize_key(&doc, "spmd.items_per_task")? {
            if n == 0 {
                return Err(Error::Config(
                    "spmd.items_per_task must be at least 1".into(),
                ));
            }
            j.items_per_task = Some(n);
        }
        // [errors]
        if let Some(v) = doc.get("errors.on_error") {
            j.on_error =
                Some(OnError::parse(v.as_str().unwrap_or_default())?);
        }
        if let Some(v) = doc.get("errors.failure_threshold") {
            let f = v.as_float().ok_or_else(|| {
                Error::Config(
                    "errors.failure_threshold must be a number".into(),
                )
            })?;
            if !(0.0..=1.0).contains(&f) {
                return Err(Error::Config(
                    "errors.failure_threshold must be in [0, 1]".into(),
                ));
            }
            j.failure_threshold = Some(f);
        }
        // [telemetry]
        if let Some(v) = doc.get("telemetry.enabled") {
            config.telemetry.enabled = v.as_bool();
        }
        if let Some(v) = doc.get("telemetry.metrics_listen") {
            config.telemetry.metrics_listen = Some(
                v.as_str()
                    .ok_or_else(|| {
                        Error::Config(
                            "telemetry.metrics_listen must be a string"
                                .into(),
                        )
                    })?
                    .to_string(),
            );
        }
        if let Some(v) = doc.get("telemetry.trace") {
            config.telemetry.trace = v.as_bool();
        }
        if let Some(v) = doc.get("job.options") {
            j.scheduler_options = v
                .as_str_array()
                .ok_or_else(|| {
                    Error::Config("job.options must be a string array".into())
                })?
                .to_vec();
        }
        Ok(config)
    }

    /// Apply `LLMR_*` environment overrides via a lookup function
    /// (injected for testability).
    pub fn apply_env_overrides(
        &mut self,
        get: impl Fn(&str) -> Option<String>,
    ) {
        if let Some(v) = get("LLMR_ENGINE") {
            if let Ok(e) = EngineKind::parse(&v) {
                self.engine = e;
            }
        }
        if let Some(v) = get("LLMR_NODES") {
            if let Ok(n) = v.parse::<usize>() {
                self.cluster.nodes = n.max(1);
            }
        }
        if let Some(v) = get("LLMR_DISPATCH_MS") {
            if let Ok(ms) = v.parse::<u64>() {
                self.cluster.dispatch_latency = Duration::from_millis(ms);
            }
        }
        if let Some(v) = get("LLMR_SEED") {
            if let Ok(s) = v.parse::<u64>() {
                self.cluster.seed = s;
            }
        }
        if let Some(v) = get("LLMR_LISTEN") {
            if !v.is_empty() {
                self.remote.listen = v;
            }
        }
        if let Some(v) = get("LLMR_MIN_WORKERS") {
            if let Ok(n) = v.parse::<usize>() {
                self.remote.min_workers = n;
            }
        }
        if let Some(v) = get("LLMR_BATCH_FRAMES") {
            match v.to_ascii_lowercase().as_str() {
                "1" | "true" | "yes" => self.remote.batch_frames = true,
                "0" | "false" | "no" => self.remote.batch_frames = false,
                _ => {}
            }
        }
        if let Some(v) = get("LLMR_STEAL") {
            match v.to_ascii_lowercase().as_str() {
                "1" | "true" | "yes" => self.remote.steal = true,
                "0" | "false" | "no" => self.remote.steal = false,
                _ => {}
            }
        }
        if let Some(v) = get("LLMR_SPMD") {
            match v.to_ascii_lowercase().as_str() {
                "1" | "true" | "yes" => self.job_defaults.spmd = Some(true),
                "0" | "false" | "no" => {
                    self.job_defaults.spmd = Some(false);
                }
                _ => {}
            }
        }
        if let Some(v) = get("LLMR_ITEMS_PER_TASK") {
            if let Ok(n) = v.parse::<usize>() {
                if n > 0 {
                    self.job_defaults.items_per_task = Some(n);
                }
            }
        }
        if let Some(v) = get("LLMR_ON_ERROR") {
            if let Ok(e) = OnError::parse(&v) {
                self.job_defaults.on_error = Some(e);
            }
        }
        if let Some(v) = get("LLMR_FAILURE_THRESHOLD") {
            if let Ok(f) = v.parse::<f64>() {
                if (0.0..=1.0).contains(&f) {
                    self.job_defaults.failure_threshold = Some(f);
                }
            }
        }
        if let Some(v) = get("LLMR_TELEMETRY") {
            match v.to_ascii_lowercase().as_str() {
                "1" | "true" | "yes" => {
                    self.telemetry.enabled = Some(true);
                }
                "0" | "false" | "no" => {
                    self.telemetry.enabled = Some(false);
                }
                _ => {}
            }
        }
        if let Some(v) = get("LLMR_METRICS_LISTEN") {
            if !v.is_empty() {
                self.telemetry.metrics_listen = Some(v);
            }
        }
        if let Some(v) = get("LLMR_TRACE") {
            match v.to_ascii_lowercase().as_str() {
                "1" | "true" | "yes" => {
                    self.telemetry.trace = Some(true);
                }
                "0" | "false" | "no" => {
                    self.telemetry.trace = Some(false);
                }
                _ => {}
            }
        }
    }

    /// Fill unset fields of `opts` from the job defaults (CLI wins).
    pub fn apply_job_defaults(&self, opts: &mut Options) {
        let j = &self.job_defaults;
        if opts.np.is_none() {
            opts.np = j.np;
        }
        if opts.ndata.is_none() {
            opts.ndata = j.ndata;
        }
        if let Some(d) = j.distribution {
            if opts.distribution == Distribution::default()
                && d != Distribution::default()
            {
                opts.distribution = d;
            }
        }
        if let Some(a) = j.apptype {
            if opts.apptype == AppType::default() && a != AppType::default()
            {
                opts.apptype = a;
            }
        }
        if let Some(s) = j.scheduler {
            if opts.scheduler == SchedulerKind::default()
                && s != SchedulerKind::default()
            {
                opts.scheduler = s;
            }
        }
        if let Some(e) = &j.ext {
            if opts.ext == "out" {
                opts.ext = e.clone();
            }
        }
        if let Some(x) = j.exclusive {
            opts.exclusive = opts.exclusive || x;
        }
        if let Some(k) = j.keep {
            opts.keep = opts.keep || k;
        }
        for o in &j.scheduler_options {
            if !opts.scheduler_options.contains(o) {
                opts.scheduler_options.push(o.clone());
            }
        }
        if let Some(s) = j.spmd {
            opts.spmd = opts.spmd || s;
        }
        if opts.items_per_task.is_none() {
            opts.items_per_task = j.items_per_task;
        }
        if opts.on_error.is_none() {
            opts.on_error = j.on_error;
        }
        if opts.failure_threshold.is_none() {
            opts.failure_threshold = j.failure_threshold;
        }
        // Telemetry defaults on, so config can only switch it off; an
        // explicit CLI `--telemetry` is indistinguishable from the
        // default (same precedence quirk as apptype above).
        if let Some(t) = self.telemetry.enabled {
            opts.telemetry = opts.telemetry && t;
        }
        // Same rule for span tracing.
        if let Some(t) = self.telemetry.trace {
            opts.trace = opts.trace && t;
        }
    }

    /// Build the configured engine.  The local and remote engines
    /// inherit the cluster profile's failure-injection knobs, so
    /// `engine = "local"` vs `"sim"` vs `"remote"` replay the same retry
    /// pattern (DESIGN.md §4).  `engine = "remote"` binds
    /// `remote.listen` and blocks until `remote.min_workers` workers
    /// register (60s grace) — spawn `llmapreduce worker` daemons first
    /// or concurrently.
    pub fn build_engine(
        &self,
        width: usize,
    ) -> Result<Box<dyn crate::scheduler::Engine>> {
        Ok(match self.engine {
            EngineKind::Local => {
                Box::new(crate::scheduler::local::LocalEngine::with_policy(
                    width,
                    self.cluster.failure_policy(),
                ))
            }
            EngineKind::Sim => Box::new(crate::scheduler::sim::SimEngine::new(
                ClusterConfig {
                    nodes: width.max(1),
                    slots_per_node: 1,
                    ..self.cluster.clone()
                },
            )),
            EngineKind::SimExec => Box::new(
                crate::scheduler::sim::SimEngine::new(ClusterConfig {
                    nodes: width.max(1),
                    slots_per_node: 1,
                    ..self.cluster.clone()
                })
                .execute_payloads(true),
            ),
            EngineKind::Remote => {
                use crate::scheduler::remote::{
                    CoordinatorConfig, RemoteCoordinator,
                };
                let coordinator = RemoteCoordinator::bind(
                    &self.remote.listen,
                    CoordinatorConfig {
                        heartbeat_timeout: self.remote.heartbeat_timeout,
                        policy: self.cluster.failure_policy(),
                        metrics_listen: self
                            .telemetry
                            .metrics_listen
                            .clone(),
                        batch_frames: self.remote.batch_frames,
                        steal: self.remote.steal,
                    },
                )?;
                if self.remote.min_workers > 0 {
                    coordinator.wait_for_workers(
                        self.remote.min_workers,
                        Duration::from_secs(60),
                    )?;
                }
                Box::new(coordinator)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
engine = "sim"

[cluster]
nodes = 8
slots_per_node = 4
dispatch_latency_ms = 25
jitter = 0.1
seed = 99

[job]
np = 64
distribution = "cyclic"
apptype = "mimo"
scheduler = "slurm"
options = ["-l mem=8G"]
"#;

    #[test]
    fn parses_full_config() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.engine, EngineKind::Sim);
        assert_eq!(c.cluster.nodes, 8);
        assert_eq!(c.cluster.slots_per_node, 4);
        assert_eq!(c.cluster.dispatch_latency, Duration::from_millis(25));
        assert_eq!(c.cluster.seed, 99);
        assert_eq!(c.job_defaults.np, Some(64));
        assert_eq!(c.job_defaults.apptype, Some(AppType::Mimo));
    }

    #[test]
    fn defaults_when_empty() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.engine, EngineKind::Local);
        assert_eq!(c.cluster.nodes, ClusterConfig::default().nodes);
        assert!(c.job_defaults.np.is_none());
    }

    #[test]
    fn job_defaults_fill_unset_only() {
        let c = Config::parse(SAMPLE).unwrap();
        let mut opts = Options::new("/in", "/out", "m");
        c.apply_job_defaults(&mut opts);
        assert_eq!(opts.np, Some(64));
        assert_eq!(opts.distribution, Distribution::Cyclic);
        assert_eq!(opts.apptype, AppType::Mimo);
        assert_eq!(opts.scheduler, SchedulerKind::Slurm);
        assert_eq!(opts.scheduler_options, vec!["-l mem=8G"]);

        // Explicit CLI values win.
        let mut explicit = Options::new("/in", "/out", "m")
            .np(4)
            .apptype(AppType::Siso);
        c.apply_job_defaults(&mut explicit);
        assert_eq!(explicit.np, Some(4));
        // apptype default is Siso so config's Mimo applies only when the
        // user left it at default — documented precedence quirk.
        assert_eq!(explicit.apptype, AppType::Mimo);
    }

    #[test]
    fn env_overrides() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.apply_env_overrides(|k| match k {
            "LLMR_ENGINE" => Some("local".into()),
            "LLMR_NODES" => Some("32".into()),
            "LLMR_DISPATCH_MS" => Some("5".into()),
            "LLMR_SEED" => Some("7".into()),
            _ => None,
        });
        assert_eq!(c.engine, EngineKind::Local);
        assert_eq!(c.cluster.nodes, 32);
        assert_eq!(c.cluster.dispatch_latency, Duration::from_millis(5));
        assert_eq!(c.cluster.seed, 7);
    }

    #[test]
    fn remote_wire_knobs_parse_and_env_override() {
        let c = Config::parse(
            "[remote]\nbatch_frames = false\nsteal = false\n",
        )
        .unwrap();
        assert!(!c.remote.batch_frames);
        assert!(!c.remote.steal);

        // Defaults are on: batching is the whole point of the hot path.
        let d = Config::parse("").unwrap();
        assert!(d.remote.batch_frames);
        assert!(d.remote.steal);

        let mut c = Config::parse("").unwrap();
        c.apply_env_overrides(|k| match k {
            "LLMR_BATCH_FRAMES" => Some("no".into()),
            "LLMR_STEAL" => Some("0".into()),
            _ => None,
        });
        assert!(!c.remote.batch_frames);
        assert!(!c.remote.steal);
    }

    #[test]
    fn spmd_section_and_env_overrides() {
        let c = Config::parse(
            "[spmd]\nenabled = true\nitems_per_task = 8\n",
        )
        .unwrap();
        assert_eq!(c.job_defaults.spmd, Some(true));
        assert_eq!(c.job_defaults.items_per_task, Some(8));

        let mut opts = Options::new("/in", "/out", "m");
        c.apply_job_defaults(&mut opts);
        assert!(opts.spmd);
        assert_eq!(opts.items_per_task, Some(8));
        assert!(opts.spmd_enabled());

        // CLI-provided batch size wins over config.
        let mut explicit =
            Options::new("/in", "/out", "m").items_per_task(32);
        c.apply_job_defaults(&mut explicit);
        assert_eq!(explicit.items_per_task, Some(32));

        // Env sits between config and CLI.
        let mut e = Config::parse("[spmd]\nitems_per_task = 8\n").unwrap();
        e.apply_env_overrides(|k| match k {
            "LLMR_SPMD" => Some("true".into()),
            "LLMR_ITEMS_PER_TASK" => Some("4".into()),
            _ => None,
        });
        assert_eq!(e.job_defaults.spmd, Some(true));
        assert_eq!(e.job_defaults.items_per_task, Some(4));

        assert!(
            Config::parse("[spmd]\nitems_per_task = 0\n").is_err(),
            "zero batch size rejected at parse time"
        );
    }

    #[test]
    fn errors_section_env_and_precedence() {
        let c = Config::parse(
            "[errors]\non_error = \"dlq\"\nfailure_threshold = 0.25\n",
        )
        .unwrap();
        assert_eq!(c.job_defaults.on_error, Some(OnError::Dlq));
        assert_eq!(c.job_defaults.failure_threshold, Some(0.25));

        // Config fills unset options; CLI-provided values win.
        let mut opts = Options::new("/in", "/out", "m");
        c.apply_job_defaults(&mut opts);
        assert_eq!(opts.on_error, Some(OnError::Dlq));
        assert_eq!(opts.failure_threshold, Some(0.25));
        let mut explicit = Options::new("/in", "/out", "m")
            .on_error(OnError::Retry)
            .failure_threshold(0.5);
        c.apply_job_defaults(&mut explicit);
        assert_eq!(explicit.on_error, Some(OnError::Retry));
        assert_eq!(explicit.failure_threshold, Some(0.5));

        // Env sits between config and CLI.
        let mut e = c.clone();
        e.apply_env_overrides(|k| match k {
            "LLMR_ON_ERROR" => Some("skip".into()),
            "LLMR_FAILURE_THRESHOLD" => Some("0.75".into()),
            _ => None,
        });
        assert_eq!(e.job_defaults.on_error, Some(OnError::Skip));
        assert_eq!(e.job_defaults.failure_threshold, Some(0.75));

        assert!(
            Config::parse("[errors]\non_error = \"explode\"\n").is_err()
        );
        assert!(
            Config::parse("[errors]\nfailure_threshold = 1.5\n").is_err()
        );
    }

    #[test]
    fn telemetry_section_env_and_precedence() {
        let c = Config::parse(
            "[telemetry]\nenabled = false\n\
             metrics_listen = \"127.0.0.1:9900\"\n",
        )
        .unwrap();
        assert_eq!(c.telemetry.enabled, Some(false));
        assert_eq!(
            c.telemetry.metrics_listen.as_deref(),
            Some("127.0.0.1:9900")
        );

        // A config `false` switches the default-on flag off.
        let mut opts = Options::new("/in", "/out", "m");
        c.apply_job_defaults(&mut opts);
        assert!(!opts.telemetry);

        // Absent section leaves the default untouched.
        let d = Config::parse("").unwrap();
        assert_eq!(d.telemetry, TelemetryDefaults::default());
        let mut opts = Options::new("/in", "/out", "m");
        d.apply_job_defaults(&mut opts);
        assert!(opts.telemetry);

        // Env overrides the config file.
        let mut e = c.clone();
        e.apply_env_overrides(|k| match k {
            "LLMR_TELEMETRY" => Some("yes".into()),
            "LLMR_METRICS_LISTEN" => Some("0.0.0.0:9100".into()),
            _ => None,
        });
        assert_eq!(e.telemetry.enabled, Some(true));
        assert_eq!(
            e.telemetry.metrics_listen.as_deref(),
            Some("0.0.0.0:9100")
        );

        assert!(
            Config::parse("[telemetry]\nmetrics_listen = 9\n").is_err()
        );
    }

    #[test]
    fn trace_knob_config_env_and_precedence() {
        let c = Config::parse("[telemetry]\ntrace = false\n").unwrap();
        assert_eq!(c.telemetry.trace, Some(false));

        // A config `false` switches the default-on flag off.
        let mut opts = Options::new("/in", "/out", "m");
        c.apply_job_defaults(&mut opts);
        assert!(!opts.trace);
        assert!(opts.telemetry, "trace knob leaves telemetry alone");

        // Absent key leaves the default-on flag untouched.
        let d = Config::parse("").unwrap();
        assert_eq!(d.telemetry.trace, None);
        let mut opts = Options::new("/in", "/out", "m");
        d.apply_job_defaults(&mut opts);
        assert!(opts.trace);

        // Env overrides the config file.
        let mut e = c.clone();
        e.apply_env_overrides(|k| match k {
            "LLMR_TRACE" => Some("yes".into()),
            _ => None,
        });
        assert_eq!(e.telemetry.trace, Some(true));
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(Config::parse("engine = \"quantum\"\n").is_err());
        assert!(Config::parse("[cluster]\nfailure_rate = 2.0\n").is_err());
        assert!(Config::parse("[job]\napptype = \"simo\"\n").is_err());
        assert!(Config::parse("[cluster]\nnodes = \"many\"\n").is_err());
    }

    #[test]
    fn missing_file_is_defaults() {
        let c = Config::load(Path::new("/nonexistent/llmr.toml")).unwrap();
        assert_eq!(c.engine, EngineKind::Local);
    }

    #[test]
    fn build_engine_kinds() {
        let mut c = Config::default();
        assert_eq!(c.build_engine(2).unwrap().name(), "local");
        assert!(!c.build_engine(2).unwrap().virtual_time());
        c.engine = EngineKind::Sim;
        assert_eq!(c.build_engine(2).unwrap().name(), "sim");
        assert!(c.build_engine(2).unwrap().virtual_time());
        c.engine = EngineKind::SimExec;
        assert_eq!(c.build_engine(2).unwrap().name(), "sim");
    }

    #[test]
    fn build_remote_engine_binds_without_waiting_when_zero_min_workers() {
        let mut c = Config::default();
        c.engine = EngineKind::Remote;
        c.remote.listen = "127.0.0.1:0".into(); // ephemeral port
        c.remote.min_workers = 0;
        let eng = c.build_engine(2).unwrap();
        assert_eq!(eng.name(), "remote");
        assert!(!eng.virtual_time());
    }

    #[test]
    fn remote_section_parses() {
        let c = Config::parse(
            "engine = \"remote\"\n\n[remote]\nlisten = \"0.0.0.0:9000\"\n\
             min_workers = 4\nheartbeat_timeout_ms = 1500\n",
        )
        .unwrap();
        assert_eq!(c.engine, EngineKind::Remote);
        assert_eq!(c.remote.listen, "0.0.0.0:9000");
        assert_eq!(c.remote.min_workers, 4);
        assert_eq!(
            c.remote.heartbeat_timeout,
            Duration::from_millis(1500)
        );
        // Defaults hold when the section is absent.
        let d = Config::parse("").unwrap();
        assert_eq!(d.remote, RemoteDefaults::default());
    }

    #[test]
    fn remote_env_overrides() {
        let mut c = Config::default();
        c.apply_env_overrides(|k| match k {
            "LLMR_ENGINE" => Some("remote".into()),
            "LLMR_LISTEN" => Some("127.0.0.1:9191".into()),
            "LLMR_MIN_WORKERS" => Some("3".into()),
            _ => None,
        });
        assert_eq!(c.engine, EngineKind::Remote);
        assert_eq!(c.remote.listen, "127.0.0.1:9191");
        assert_eq!(c.remote.min_workers, 3);
    }

    #[test]
    fn local_engine_inherits_cluster_failure_policy() {
        let c = Config::parse(
            "[cluster]\nfailure_rate = 0.5\nmax_retries = 3\nseed = 4\n",
        )
        .unwrap();
        let p = c.cluster.failure_policy();
        assert_eq!(p.failure_rate, 0.5);
        assert_eq!(p.max_retries, 3);
        assert_eq!(p.seed, 4);
    }
}
