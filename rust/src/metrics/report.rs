//! Human-readable tables, CSV, and JSON emission for experiment results.
//!
//! The bench harness prints the same rows/series the paper reports: the
//! speed-up tables (I, II) and the overhead / speed-up curves (18, 19).

use std::time::Duration;

use crate::mapreduce::MapReduceReport;
use crate::metrics::{Measurement, Sweep};
use crate::util::json::{obj, Json};
use crate::util::{fmt_count, fmt_duration};

/// Render a fixed-width text table.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = headers.len();
    let mut widths: Vec<usize> =
        headers.iter().map(|h| h.chars().count()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncols) {
            widths[i] = widths[i].max(cell.chars().count());
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        for w in &widths {
            out.push('+');
            out.push_str(&"-".repeat(w + 2));
        }
        out.push_str("+\n");
    };
    sep(&mut out);
    for (i, h) in headers.iter().enumerate() {
        out.push_str(&format!("| {:w$} ", h, w = widths[i]));
    }
    out.push_str("|\n");
    sep(&mut out);
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncols) {
            out.push_str(&format!("| {:w$} ", cell, w = widths[i]));
        }
        out.push_str("|\n");
    }
    sep(&mut out);
    out
}

/// Tables I/II: speed-up of MIMO over BLOCK.
pub fn speedup_table(
    example: &str,
    block: &Measurement,
    mimo: &Measurement,
) -> String {
    let speedup = block.elapsed.as_secs_f64()
        / mimo.elapsed.as_secs_f64().max(1e-12);
    render_table(
        &["Example", "Type", "Elapsed", "Speed up"],
        &[
            vec![
                example.to_string(),
                "Multiple app launches (BLOCK)".into(),
                fmt_duration(block.elapsed),
                "1".into(),
            ],
            vec![
                String::new(),
                "Single app launch (MIMO)".into(),
                fmt_duration(mimo.elapsed),
                format!("{speedup:.2}"),
            ],
        ],
    )
}

/// Barriered vs overlapped map→reduce (DESIGN.md §4): end-to-end
/// makespan, slot utilization, and the speed-up the removed barrier buys.
/// Overlap shows up on both axes — lower makespan because reduce work
/// fills slots the Fig 1 barrier left idle, higher utilization because
/// the same busy time divides by a shorter span.
pub fn overlap_comparison(
    barriered: &MapReduceReport,
    overlapped: &MapReduceReport,
) -> String {
    let speedup = barriered.elapsed().as_secs_f64()
        / overlapped.elapsed().as_secs_f64().max(1e-12);
    let row = |label: &str, r: &MapReduceReport, s: String| {
        vec![
            label.to_string(),
            fmt_duration(r.elapsed()),
            format!("{:.0}%", r.utilization() * 100.0),
            s,
        ]
    };
    render_table(
        &["Mode", "Makespan", "Utilization", "Speed up"],
        &[
            row("barriered (Fig 1 job dependency)", barriered, "1".into()),
            row(
                "overlapped (task dependencies)",
                overlapped,
                format!("{speedup:.2}"),
            ),
        ],
    )
}

/// Per-worker attribution of one job on the remote engine: where each
/// worker's time went — dispatch wait (queueing before shipping),
/// network/shipping overhead, application startup and compute — plus
/// how many of its tasks had to be reassigned off dead peers.  Tasks
/// without worker attribution (local/sim engines) group under `-`.
pub fn worker_attribution(job: &crate::scheduler::JobReport) -> String {
    use std::collections::BTreeMap;
    #[derive(Default)]
    struct Acc {
        tasks: usize,
        dispatch: Duration,
        shipped: Duration,
        startup: Duration,
        compute: Duration,
        reassigned: usize,
    }
    let mut per: BTreeMap<String, Acc> = BTreeMap::new();
    for t in &job.tasks {
        let key = t.worker.clone().unwrap_or_else(|| "-".to_string());
        let acc = per.entry(key).or_default();
        acc.tasks += 1;
        acc.dispatch += t.dispatch_wait;
        acc.shipped += t.shipped;
        acc.startup += t.startup;
        acc.compute += t.compute;
        acc.reassigned += t.reassigned;
    }
    let rows: Vec<Vec<String>> = per
        .iter()
        .map(|(worker, a)| {
            vec![
                worker.clone(),
                a.tasks.to_string(),
                fmt_duration(a.dispatch),
                fmt_duration(a.shipped),
                fmt_duration(a.startup),
                fmt_duration(a.compute),
                a.reassigned.to_string(),
            ]
        })
        .collect();
    render_table(
        &[
            "worker",
            "tasks",
            "dispatch wait",
            "shipping",
            "startup",
            "compute",
            "reassigned",
        ],
        &rows,
    )
}

/// Crash-recovery summary of one job (DESIGN.md §8): how much work a
/// resume skipped vs re-ran, and where the failure machinery engaged —
/// retries, dead letters, reassignments off dead workers.
pub fn recovery_summary(job: &crate::scheduler::JobReport) -> String {
    let retries: usize = job.tasks.iter().map(|t| t.retries).sum();
    let reassigned: usize =
        job.tasks.iter().map(|t| t.reassigned).sum();
    render_table(
        &["replayed", "re-run", "retries", "dead-lettered", "reassigned"],
        &[vec![
            job.replayed.to_string(),
            job.tasks.len().to_string(),
            retries.to_string(),
            job.dead_lettered().to_string(),
            reassigned.to_string(),
        ]],
    )
}

/// Fig 18: overhead per array task, one row per np, one column per option.
pub fn overhead_series(sweep: &Sweep) -> String {
    let options = sweep.options();
    let mut headers: Vec<&str> = vec!["np (concurrent tasks)"];
    let option_headers: Vec<String> = options
        .iter()
        .map(|o| format!("{o} overhead/task"))
        .collect();
    headers.extend(option_headers.iter().map(|s| s.as_str()));
    let rows: Vec<Vec<String>> = sweep
        .np_values()
        .into_iter()
        .map(|np| {
            let mut row = vec![fmt_count(np)];
            for o in &options {
                row.push(
                    sweep
                        .get(o, np)
                        .map(|m| fmt_duration(m.overhead_per_task))
                        .unwrap_or_else(|| "-".into()),
                );
            }
            row
        })
        .collect();
    render_table(&headers, &rows)
}

/// Fig 19: speed-up vs DEFAULT@1.
pub fn speedup_series(sweep: &Sweep) -> String {
    let baseline = sweep
        .baseline()
        .unwrap_or_else(|| Duration::from_secs(1));
    let options = sweep.options();
    let mut headers: Vec<&str> = vec!["np (concurrent tasks)"];
    let option_headers: Vec<String> =
        options.iter().map(|o| format!("{o} speed-up")).collect();
    headers.extend(option_headers.iter().map(|s| s.as_str()));
    let rows: Vec<Vec<String>> = sweep
        .np_values()
        .into_iter()
        .map(|np| {
            let mut row = vec![fmt_count(np)];
            for o in &options {
                row.push(
                    sweep
                        .get(o, np)
                        .map(|m| format!("{:.2}", m.speedup_vs(baseline)))
                        .unwrap_or_else(|| "-".into()),
                );
            }
            row
        })
        .collect();
    render_table(&headers, &rows)
}

/// CSV emission for plotting (one row per measurement).
pub fn sweep_csv(sweep: &Sweep) -> String {
    let mut out = String::from(
        "option,np,elapsed_s,overhead_per_task_s,total_startup_s,\
         total_compute_s,launches,items\n",
    );
    for m in &sweep.rows {
        out.push_str(&format!(
            "{},{},{:.6},{:.6},{:.6},{:.6},{},{}\n",
            m.option,
            m.np,
            m.elapsed.as_secs_f64(),
            m.overhead_per_task.as_secs_f64(),
            m.total_startup.as_secs_f64(),
            m.total_compute.as_secs_f64(),
            m.launches,
            m.items
        ));
    }
    out
}

/// JSON emission for EXPERIMENTS.md tooling.
pub fn sweep_json(name: &str, sweep: &Sweep) -> Json {
    obj(vec![
        ("experiment", name.into()),
        (
            "rows",
            Json::Arr(
                sweep
                    .rows
                    .iter()
                    .map(|m| {
                        obj(vec![
                            ("option", m.option.as_str().into()),
                            ("np", m.np.into()),
                            ("elapsed_s", m.elapsed.as_secs_f64().into()),
                            (
                                "overhead_per_task_s",
                                m.overhead_per_task.as_secs_f64().into(),
                            ),
                            ("launches", m.launches.into()),
                            ("items", m.items.into()),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meas(option: &str, np: usize, ms: u64) -> Measurement {
        Measurement {
            option: option.into(),
            np,
            elapsed: Duration::from_millis(ms),
            overhead_per_task: Duration::from_millis(ms / 10),
            total_startup: Duration::from_millis(ms / 5),
            total_compute: Duration::from_millis(ms / 2),
            launches: np,
            items: np * 2,
        }
    }

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            &["A", "Blong"],
            &[vec!["x".into(), "y".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(t.contains("Blong"));
    }

    #[test]
    fn speedup_table_matches_paper_shape() {
        let block = meas("BLOCK", 2, 2410);
        let mimo = meas("MIMO", 2, 1000);
        let t = speedup_table("Matlab", &block, &mimo);
        assert!(t.contains("Multiple app launches (BLOCK)"));
        assert!(t.contains("Single app launch (MIMO)"));
        assert!(t.contains("2.41"));
    }

    #[test]
    fn series_tables_have_all_options() {
        let mut s = Sweep::default();
        for np in [1usize, 2, 4] {
            s.push(meas("DEFAULT", np, 1000 / np as u64));
            s.push(meas("BLOCK", np, 900 / np as u64));
            s.push(meas("MIMO", np, 500 / np as u64));
        }
        let o = overhead_series(&s);
        let p = speedup_series(&s);
        for t in [&o, &p] {
            assert!(t.contains("DEFAULT"));
            assert!(t.contains("BLOCK"));
            assert!(t.contains("MIMO"));
        }
        // Fig 19 baseline row: DEFAULT@1 speed-up is 1.00.
        assert!(p.contains("1.00"));
    }

    #[test]
    fn overlap_comparison_shows_makespan_and_utilization() {
        use crate::mapreduce::planner::Plan;
        use crate::options::AppType;
        use crate::scheduler::{JobReport, TaskReport};
        let job = |startup_ms: u64, compute_ms: u64| JobReport {
            slots: 2,
            tasks: vec![TaskReport {
                startup: Duration::from_millis(startup_ms),
                compute: Duration::from_millis(compute_ms),
                ..Default::default()
            }],
            ..Default::default()
        };
        let mk = |elapsed_ms: u64, overlapped: bool| MapReduceReport {
            map: job(20, 100),
            partials: overlapped.then(|| job(0, 40)),
            reduce: Some(job(0, 20)),
            plan: Plan {
                tasks: vec![],
                apptype: AppType::Siso,
                nfiles: 0,
            },
            redout_path: None,
            mapred_dir: None,
            overlapped,
            total_elapsed: Duration::from_millis(elapsed_ms),
        };
        let barriered = mk(200, false);
        let overlapped = mk(130, true);
        assert!(overlapped.utilization() > barriered.utilization());
        let t = overlap_comparison(&barriered, &overlapped);
        assert!(t.contains("barriered"), "{t}");
        assert!(t.contains("overlapped"), "{t}");
        assert!(t.contains("1.54"), "barrier/overlap speed-up row: {t}");
    }

    #[test]
    fn worker_attribution_groups_and_sums() {
        use crate::scheduler::{JobReport, TaskReport};
        let task = |worker: &str, ship_ms: u64, reassigned: usize| {
            TaskReport {
                worker: Some(worker.to_string()),
                shipped: Duration::from_millis(ship_ms),
                compute: Duration::from_millis(10),
                ..Default::default()
            }
        };
        let job = JobReport {
            tasks: vec![
                task("w1", 5, 0),
                task("w1", 7, 1),
                task("w2", 3, 0),
            ],
            ..Default::default()
        };
        let t = worker_attribution(&job);
        assert!(t.contains("w1"), "{t}");
        assert!(t.contains("w2"), "{t}");
        assert!(t.contains("shipping"), "{t}");
        // w1 row: 2 tasks, 12ms shipped, 1 reassignment.
        let w1_row = t.lines().find(|l| l.contains("w1")).unwrap();
        assert!(w1_row.contains("| 2 "), "{w1_row}");
        assert!(w1_row.contains("12"), "{w1_row}");
    }

    #[test]
    fn recovery_summary_counts_the_failure_machinery() {
        use crate::scheduler::{JobReport, TaskReport};
        let job = JobReport {
            replayed: 3,
            tasks: vec![
                TaskReport {
                    retries: 2,
                    ..Default::default()
                },
                TaskReport {
                    dead_lettered: true,
                    reassigned: 1,
                    ..Default::default()
                },
            ],
            ..Default::default()
        };
        let t = recovery_summary(&job);
        let row = t.lines().nth(3).unwrap();
        assert!(row.contains("| 3 "), "replayed: {row}");
        assert!(row.contains("| 2 "), "re-run + retries: {row}");
        assert!(row.contains("| 1 "), "dlq + reassigned: {row}");
    }

    #[test]
    fn csv_roundtrip_row_count() {
        let mut s = Sweep::default();
        s.push(meas("MIMO", 1, 10));
        s.push(meas("MIMO", 2, 5));
        let csv = sweep_csv(&s);
        assert_eq!(csv.lines().count(), 3); // header + 2
    }

    #[test]
    fn json_emission_parses() {
        let mut s = Sweep::default();
        s.push(meas("BLOCK", 4, 100));
        let j = sweep_json("fig18", &s);
        let text = j.to_string_pretty();
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(
            back.get("experiment").unwrap().as_str(),
            Some("fig18")
        );
    }
}
