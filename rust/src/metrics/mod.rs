//! Metrics: phase timing decomposition and experiment records.
//!
//! The paper's §IV quantities, computed from [`JobReport`]s:
//!
//! * **overhead per array task** (Fig 18's y-axis) — dispatch + startup;
//! * **job elapsed time** and **speed-up vs DEFAULT@1** (Fig 19);
//! * **BLOCK vs MIMO speed-up** (Tables I and II).

pub mod report;

use std::time::Duration;

use crate::scheduler::JobReport;

/// One measured experiment cell: an option at a width.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Label, e.g. "MIMO" or "BLOCK".
    pub option: String,
    /// Concurrent array tasks (np).
    pub np: usize,
    pub elapsed: Duration,
    pub overhead_per_task: Duration,
    pub total_startup: Duration,
    pub total_compute: Duration,
    pub launches: usize,
    pub items: usize,
}

impl Measurement {
    pub fn from_report(
        option: impl Into<String>,
        np: usize,
        r: &JobReport,
    ) -> Measurement {
        // Fig 18 normalizes overhead per *concurrent process*, not per
        // array task: DEFAULT mode has one array task per file, but the
        // paper attributes the summed overhead to the np width slots.
        let total_overhead = r.total_startup() + r.total_dispatch();
        Measurement {
            option: option.into(),
            np,
            elapsed: r.makespan,
            overhead_per_task: total_overhead / np.max(1) as u32,
            total_startup: r.total_startup(),
            total_compute: r.total_compute(),
            launches: r.total_launches(),
            items: r.total_items(),
        }
    }

    /// Speed-up of this measurement relative to a baseline elapsed time.
    pub fn speedup_vs(&self, baseline: Duration) -> f64 {
        baseline.as_secs_f64() / self.elapsed.as_secs_f64().max(1e-12)
    }
}

/// A sweep: measurements across np values for several options, as in
/// Figs 18/19.
#[derive(Debug, Clone, Default)]
pub struct Sweep {
    pub rows: Vec<Measurement>,
}

impl Sweep {
    pub fn push(&mut self, m: Measurement) {
        self.rows.push(m);
    }

    pub fn get(&self, option: &str, np: usize) -> Option<&Measurement> {
        self.rows
            .iter()
            .find(|m| m.option == option && m.np == np)
    }

    /// The Fig 19 baseline: DEFAULT at np = 1.
    pub fn baseline(&self) -> Option<Duration> {
        self.get("DEFAULT", 1).map(|m| m.elapsed)
    }

    /// Distinct np values, ascending.
    pub fn np_values(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.rows.iter().map(|m| m.np).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Distinct options in first-seen order.
    pub fn options(&self) -> Vec<String> {
        let mut seen = Vec::new();
        for m in &self.rows {
            if !seen.contains(&m.option) {
                seen.push(m.option.clone());
            }
        }
        seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::TaskReport;

    fn report(startup_ms: u64, compute_ms: u64) -> JobReport {
        JobReport {
            makespan: Duration::from_millis(startup_ms + compute_ms),
            tasks: vec![TaskReport {
                startup: Duration::from_millis(startup_ms),
                compute: Duration::from_millis(compute_ms),
                launches: 1,
                items: 1,
                ..Default::default()
            }],
            ..Default::default()
        }
    }

    #[test]
    fn measurement_from_report() {
        let m = Measurement::from_report("MIMO", 4, &report(100, 400));
        assert_eq!(m.elapsed, Duration::from_millis(500));
        assert_eq!(m.total_startup, Duration::from_millis(100));
        assert_eq!(m.launches, 1);
    }

    #[test]
    fn speedup_math() {
        let m = Measurement::from_report("MIMO", 1, &report(0, 100));
        assert!((m.speedup_vs(Duration::from_millis(500)) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn sweep_lookup_and_baseline() {
        let mut s = Sweep::default();
        s.push(Measurement::from_report("DEFAULT", 1, &report(10, 90)));
        s.push(Measurement::from_report("MIMO", 1, &report(1, 9)));
        s.push(Measurement::from_report("MIMO", 4, &report(1, 4)));
        assert_eq!(s.baseline(), Some(Duration::from_millis(100)));
        assert_eq!(s.np_values(), vec![1, 4]);
        assert_eq!(s.options(), vec!["DEFAULT", "MIMO"]);
        assert!(s.get("MIMO", 4).is_some());
        assert!(s.get("BLOCK", 1).is_none());
    }
}
