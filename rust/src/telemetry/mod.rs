//! Live telemetry (DESIGN.md §9): an engine-shared event bus, a
//! zero-dependency metrics registry, and the surfaces that render them.
//!
//! The layer is three decoupled pieces:
//!
//! 1. **Events** ([`Event`], [`EventBus`]) — every `JobTable`
//!    transition (the same hook points the crash journal rides) plus
//!    the remote coordinator's worker lifecycle emits a typed event
//!    with a monotonic timestamp.  Emission is free when nobody
//!    subscribed, so engines emit unconditionally.
//! 2. **Registry** ([`Registry`], [`Histogram`]) — counters, gauges
//!    and fixed-bucket latency histograms with per-job / per-worker
//!    labels, rendered as Prometheus text exposition or
//!    `util::json`.
//! 3. **Surfaces** ([`Collector`], [`StatusWriter`],
//!    [`MetricsListener`]) — a bus subscriber folds events into the
//!    registry and a live job/worker snapshot; a dedicated thread
//!    atomically rewrites `status.json` in the `.MAPRED.<pid>`
//!    workdir; an optional `--metrics-listen host:port` endpoint
//!    serves `/metrics` and `/status`; and the `llmapreduce status` /
//!    `llmapreduce top` subcommands fold the same data offline
//!    ([`fold_workdir`]) or live ([`fetch`]).
//!
//! Enabled by default on the CLI (`--telemetry=false` opts out) and
//! opt-in per `JobSpec` from the library, exactly like the journal.
//!
//! PR 9 adds a fourth piece on top of the same event stream: the
//! **tracing layer** ([`trace`]) assembles per-task span timelines
//! (`queued → dispatched → ship-out → startup → compute → result`),
//! exports Chrome trace-event JSON for Perfetto / `chrome://tracing`,
//! and reconstructs the critical path — live via [`TraceCollector`]
//! or offline from the journal via [`trace_workdir`] (DESIGN.md §12).

pub mod bus;
pub mod event;
pub mod registry;
pub mod surface;
pub mod trace;

pub use bus::{EventBus, Subscriber, SubscriptionId};
pub use event::{Event, Stamped};
pub use registry::{Histogram, Registry, LATENCY_BOUNDS_SECS};
pub use surface::{
    fetch, fold_workdir, render_status, render_top, Collector,
    InvocationTelemetry, MetricsListener, StatusWriter, STATUS_FILE,
};
pub use trace::{
    chrome_trace, critical_path, render_trace_report, stragglers,
    trace_json, trace_workdir, utilization_gaps, CriticalLink,
    CriticalPath, JobTrace, Phase, Span, Straggler, TaskTrace, Trace,
    TraceCollector, STRAGGLER_FACTOR,
};
