//! The typed event taxonomy (DESIGN.md §9).
//!
//! One [`Event`] per observable transition, emitted at exactly the hook
//! points the crash journal rides (`scheduler::table`) plus the remote
//! coordinator's worker lifecycle.  Events carry *data*, not
//! interpretation: the metrics registry, the `status.json` writer and
//! any future subscriber fold the same stream their own way.
//!
//! Timestamps are **monotonic offsets** from the owning
//! [`crate::telemetry::EventBus`]'s creation instant, not wall-clock:
//! subscribers sequence and difference them safely across clock steps,
//! and snapshots stay comparable within one process lifetime.

use std::time::Duration;

/// One observable transition.  Field names mirror the journal's record
/// schema where the two overlap, so a journal replay and an event fold
/// agree on vocabulary.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A job was admitted to the engine-shared table.
    JobSubmitted {
        job: u64,
        name: String,
        ntasks: usize,
    },
    /// A task was handed to a worker thread/daemon.
    TaskAssigned {
        job: u64,
        task_id: usize,
        /// Daemon name on the remote engine; `None` in-process.
        worker: Option<String>,
    },
    /// A task completed successfully (or as a dead-letter placeholder).
    TaskDone {
        job: u64,
        task_id: usize,
        worker: Option<String>,
        dispatch_wait: Duration,
        startup: Duration,
        compute: Duration,
        retries: usize,
        dead_lettered: bool,
        /// Full span decomposition for the tracing layer — the same
        /// numbers the journal's done record persists, so live and
        /// offline traces agree.  `None` when the job runs with
        /// `--trace=false`.
        timing: Option<crate::scheduler::TaskTiming>,
    },
    /// A task consumed one retry (injected failure or error budget).
    TaskRetry {
        job: u64,
        task_id: usize,
        attempt: usize,
    },
    /// A task reported a terminal execution error.
    TaskFailed {
        job: u64,
        task_id: usize,
        msg: String,
    },
    /// A task was reclaimed from a dead worker and requeued.
    TaskReassigned { job: u64, task_id: usize },
    /// A job completed (all tasks landed).
    JobDone { job: u64 },
    /// A job failed (directly or via dependency cascade).
    JobFailed { job: u64, msg: String },
    /// The failure-rate circuit breaker tripped on a job.
    BreakerTripped {
        job: u64,
        errors: usize,
        ntasks: usize,
    },
    /// A crashed invocation was picked up by `llmapreduce resume`:
    /// `done` of `total` tasks were satisfied from the journal.
    Resumed { done: usize, total: usize },
    /// A worker daemon registered with the coordinator.
    WorkerRegistered { worker: String, slots: usize },
    /// A liveness beacon arrived from a worker.
    WorkerHeartbeat { worker: String },
    /// A worker was declared dead (connection drop or heartbeat lapse).
    WorkerDead { worker: String },
    /// The engine's ready-queue depth changed.
    QueueDepth { depth: usize },
}

impl Event {
    /// The job this event belongs to, when it is job-scoped (worker
    /// lifecycle and queue-depth events are engine-scoped).
    pub fn job(&self) -> Option<u64> {
        match self {
            Event::JobSubmitted { job, .. }
            | Event::TaskAssigned { job, .. }
            | Event::TaskDone { job, .. }
            | Event::TaskRetry { job, .. }
            | Event::TaskFailed { job, .. }
            | Event::TaskReassigned { job, .. }
            | Event::JobDone { job }
            | Event::JobFailed { job, .. }
            | Event::BreakerTripped { job, .. } => Some(*job),
            Event::Resumed { .. }
            | Event::WorkerRegistered { .. }
            | Event::WorkerHeartbeat { .. }
            | Event::WorkerDead { .. }
            | Event::QueueDepth { .. } => None,
        }
    }
}

/// An [`Event`] as delivered to subscribers: stamped with a bus-unique
/// sequence number and a monotonic offset from the bus's creation.
#[derive(Debug, Clone)]
pub struct Stamped {
    /// Strictly increasing per bus; gaps never occur.
    pub seq: u64,
    /// Monotonic offset from the bus's creation instant.
    pub at: Duration,
    pub event: Event,
}
