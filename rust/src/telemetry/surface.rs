//! Live surfaces over the event stream (DESIGN.md §9): the
//! [`Collector`] fold, the atomic `status.json` writer, the
//! `--metrics-listen` endpoint, and the offline folds behind
//! `llmapreduce status` / `llmapreduce top`.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::scheduler::journal::{Replay, JOURNAL_FILE};
use crate::util::json::{obj, Json};

use super::bus::{EventBus, Subscriber, SubscriptionId};
use super::event::{Event, Stamped};
use super::registry::{Histogram, Registry};

/// Snapshot file name under the `.MAPRED.<pid>` workdir.
pub const STATUS_FILE: &str = "status.json";

// ---------------------------------------------------------------------------
// Collector: the one fold every surface reads
// ---------------------------------------------------------------------------

#[derive(Default)]
struct JobLive {
    name: String,
    ntasks: usize,
    done: usize,
    /// Tasks completed as dead-letter placeholders.
    errors: usize,
    /// Terminal task-error events (pre-policy).
    task_errors: usize,
    retries: usize,
    reassigned: usize,
    /// Assigned-minus-landed estimate; clamped at render time.
    running: i64,
    completed: bool,
    failed: Option<String>,
}

#[derive(Default)]
struct WorkerLive {
    slots: usize,
    alive: bool,
    done: usize,
}

#[derive(Default)]
struct Live {
    jobs: BTreeMap<u64, JobLive>,
    workers: BTreeMap<String, WorkerLive>,
    queue_depth: usize,
    resumed: Option<(usize, usize)>,
    last_seq: u64,
    last_at: Duration,
}

/// Bus subscriber that folds events into a [`Registry`] plus a
/// job/worker snapshot — the single source every live surface
/// (`status.json`, `/metrics`, `/status`, `top`) renders from.
#[derive(Default)]
pub struct Collector {
    registry: Registry,
    live: Mutex<Live>,
}

impl Collector {
    /// A fresh, empty collector.
    pub fn new() -> Collector {
        Collector::default()
    }

    /// The metric store this collector feeds.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Prometheus text exposition of the collected metrics.
    pub fn render_prometheus(&self) -> String {
        self.registry.render_prometheus()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Live> {
        self.live.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// The full live snapshot as canonical JSON — the `status.json`
    /// body and the `/status` response.
    pub fn snapshot(&self) -> Json {
        let live = self.lock();
        let mut jobs = BTreeMap::new();
        let mut t_submitted = 0usize;
        let mut t_done = 0usize;
        let mut t_errors = 0usize;
        let mut t_retries = 0usize;
        let mut t_running = 0usize;
        let mut jobs_failed = 0usize;
        for (id, j) in live.jobs.iter() {
            let running = j.running.max(0) as usize;
            t_submitted += j.ntasks;
            t_done += j.done;
            t_errors += j.errors;
            t_retries += j.retries;
            t_running += running;
            let state = if j.failed.is_some() {
                jobs_failed += 1;
                "failed"
            } else if j.completed {
                "done"
            } else {
                "running"
            };
            jobs.insert(
                id.to_string(),
                obj(vec![
                    ("name", Json::Str(j.name.clone())),
                    ("ntasks", Json::Num(j.ntasks as f64)),
                    ("done", Json::Num(j.done as f64)),
                    ("running", Json::Num(running as f64)),
                    ("errors", Json::Num(j.errors as f64)),
                    ("task_errors", Json::Num(j.task_errors as f64)),
                    ("retries", Json::Num(j.retries as f64)),
                    ("reassigned", Json::Num(j.reassigned as f64)),
                    ("state", Json::Str(state.to_string())),
                    (
                        "failed",
                        match &j.failed {
                            Some(m) => Json::Str(m.clone()),
                            None => Json::Null,
                        },
                    ),
                ]),
            );
        }
        let workers: BTreeMap<String, Json> = live
            .workers
            .iter()
            .map(|(name, w)| {
                (
                    name.clone(),
                    obj(vec![
                        ("slots", Json::Num(w.slots as f64)),
                        ("alive", Json::Bool(w.alive)),
                        ("done", Json::Num(w.done as f64)),
                    ]),
                )
            })
            .collect();
        let latency = |metric: &str| match self.registry.histogram_merged(metric) {
            Some(h) => h.to_json(),
            None => Json::Null,
        };
        let mut top = vec![
            ("v", Json::Num(1.0)),
            ("seq", Json::Num(live.last_seq as f64)),
            ("at_ms", Json::Num(live.last_at.as_millis() as f64)),
            ("queue_depth", Json::Num(live.queue_depth as f64)),
            (
                "totals",
                obj(vec![
                    ("submitted", Json::Num(t_submitted as f64)),
                    ("done", Json::Num(t_done as f64)),
                    ("running", Json::Num(t_running as f64)),
                    ("errors", Json::Num(t_errors as f64)),
                    ("retries", Json::Num(t_retries as f64)),
                    ("failed_jobs", Json::Num(jobs_failed as f64)),
                ]),
            ),
            ("jobs", Json::Obj(jobs)),
            ("workers", Json::Obj(workers)),
            (
                "latency",
                obj(vec![
                    ("startup", latency("llmr_task_startup_seconds")),
                    ("compute", latency("llmr_task_compute_seconds")),
                    ("dispatch", latency("llmr_task_dispatch_seconds")),
                ]),
            ),
            ("metrics", self.registry.to_json()),
        ];
        if let Some((done, total)) = live.resumed {
            top.push((
                "resumed",
                obj(vec![
                    ("done", Json::Num(done as f64)),
                    ("total", Json::Num(total as f64)),
                ]),
            ));
        }
        obj(top)
    }
}

fn worker_label(worker: &Option<String>) -> &str {
    worker.as_deref().unwrap_or("local")
}

impl Subscriber for Collector {
    fn on_event(&self, ev: &Stamped) {
        let mut live = self.lock();
        live.last_seq = ev.seq;
        live.last_at = ev.at;
        // Job-scoped events label metrics by job *name* (stable across
        // resume generations); fall back to the id for events that
        // outran their submit record.
        let job_name = |live: &Live, id: u64| {
            live.jobs
                .get(&id)
                .map(|j| j.name.clone())
                .unwrap_or_else(|| id.to_string())
        };
        match &ev.event {
            Event::JobSubmitted { job, name, ntasks } => {
                let j = live.jobs.entry(*job).or_default();
                j.name = name.clone();
                j.ntasks = *ntasks;
                self.registry.inc(
                    "llmr_tasks_submitted_total",
                    &[("job", name)],
                    *ntasks as u64,
                );
            }
            Event::TaskAssigned { job, worker, .. } => {
                let name = job_name(&live, *job);
                if let Some(j) = live.jobs.get_mut(job) {
                    j.running += 1;
                }
                self.registry.inc(
                    "llmr_tasks_assigned_total",
                    &[("job", &name), ("worker", worker_label(worker))],
                    1,
                );
            }
            Event::TaskDone {
                job,
                worker,
                dispatch_wait,
                startup,
                compute,
                dead_lettered,
                ..
            } => {
                let name = job_name(&live, *job);
                if let Some(j) = live.jobs.get_mut(job) {
                    j.done += 1;
                    j.running -= 1;
                    if *dead_lettered {
                        j.errors += 1;
                    }
                }
                if let Some(w) = worker {
                    live.workers.entry(w.clone()).or_default().done += 1;
                }
                let wl = worker_label(worker);
                self.registry.inc(
                    "llmr_tasks_done_total",
                    &[("job", &name), ("worker", wl)],
                    1,
                );
                if *dead_lettered {
                    self.registry.inc(
                        "llmr_tasks_dead_lettered_total",
                        &[("job", &name)],
                        1,
                    );
                }
                let w = [("worker", wl)];
                self.registry.observe(
                    "llmr_task_dispatch_seconds",
                    &w,
                    dispatch_wait.as_secs_f64(),
                );
                self.registry
                    .observe("llmr_task_startup_seconds", &w, startup.as_secs_f64());
                self.registry
                    .observe("llmr_task_compute_seconds", &w, compute.as_secs_f64());
            }
            Event::TaskRetry { job, .. } => {
                let name = job_name(&live, *job);
                if let Some(j) = live.jobs.get_mut(job) {
                    j.retries += 1;
                    // The attempt goes back to the queue; it is not
                    // running until reassigned.
                    j.running -= 1;
                }
                self.registry
                    .inc("llmr_task_retries_total", &[("job", &name)], 1);
            }
            Event::TaskFailed { job, .. } => {
                let name = job_name(&live, *job);
                if let Some(j) = live.jobs.get_mut(job) {
                    j.task_errors += 1;
                    j.running -= 1;
                }
                self.registry
                    .inc("llmr_tasks_failed_total", &[("job", &name)], 1);
            }
            Event::TaskReassigned { job, .. } => {
                let name = job_name(&live, *job);
                if let Some(j) = live.jobs.get_mut(job) {
                    j.reassigned += 1;
                    j.running -= 1;
                }
                self.registry
                    .inc("llmr_tasks_reassigned_total", &[("job", &name)], 1);
            }
            Event::JobDone { job } => {
                if let Some(j) = live.jobs.get_mut(job) {
                    j.completed = true;
                    j.running = 0;
                }
                self.registry.inc("llmr_jobs_done_total", &[], 1);
            }
            Event::JobFailed { job, msg } => {
                if let Some(j) = live.jobs.get_mut(job) {
                    j.failed = Some(msg.clone());
                    j.running = 0;
                }
                self.registry.inc("llmr_jobs_failed_total", &[], 1);
            }
            Event::BreakerTripped { job, .. } => {
                let name = job_name(&live, *job);
                self.registry
                    .inc("llmr_breaker_tripped_total", &[("job", &name)], 1);
            }
            Event::Resumed { done, total } => {
                live.resumed = Some((*done, *total));
                self.registry
                    .inc("llmr_tasks_replayed_total", &[], *done as u64);
            }
            Event::WorkerRegistered { worker, slots } => {
                let w = live.workers.entry(worker.clone()).or_default();
                w.slots = *slots;
                w.alive = true;
                let alive = live.workers.values().filter(|w| w.alive).count();
                self.registry
                    .set_gauge("llmr_worker_slots", &[("worker", worker)], *slots as f64);
                self.registry
                    .set_gauge("llmr_workers_alive", &[], alive as f64);
            }
            Event::WorkerHeartbeat { worker } => {
                self.registry.inc(
                    "llmr_worker_heartbeats_total",
                    &[("worker", worker)],
                    1,
                );
            }
            Event::WorkerDead { worker } => {
                live.workers.entry(worker.clone()).or_default().alive = false;
                let alive = live.workers.values().filter(|w| w.alive).count();
                self.registry
                    .inc("llmr_workers_dead_total", &[("worker", worker)], 1);
                self.registry
                    .set_gauge("llmr_workers_alive", &[], alive as f64);
            }
            Event::QueueDepth { depth } => {
                live.queue_depth = *depth;
                self.registry
                    .set_gauge("llmr_queue_depth", &[], *depth as f64);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// StatusWriter: atomic status.json snapshots off the dispatch path
// ---------------------------------------------------------------------------

struct WriterFlags {
    dirty: bool,
    stop: bool,
}

struct WriterShared {
    collector: Arc<Collector>,
    path: PathBuf,
    flags: Mutex<WriterFlags>,
    cv: Condvar,
}

impl WriterShared {
    /// Serialize a snapshot and atomically swap it into place
    /// (temp-file + rename — readers of `status.json` never observe a
    /// torn write, unlike plain `fs::write`).  IO errors are swallowed
    /// like journal appends: telemetry must never take down the job.
    fn write_now(&self) {
        let body = self.collector.snapshot().to_string_compact();
        let tmp = self.path.with_file_name(".status.json.tmp");
        if std::fs::write(&tmp, body).is_ok() {
            let _ = std::fs::rename(&tmp, &self.path);
        }
    }
}

/// The bus subscriber half of [`StatusWriter`]: marks the snapshot
/// dirty and wakes the writer thread — nothing else, so emitters never
/// wait on file IO.
struct StatusNotifier(Arc<WriterShared>);

impl Subscriber for StatusNotifier {
    fn on_event(&self, _ev: &Stamped) {
        let mut flags = self.0.flags.lock().unwrap_or_else(|p| p.into_inner());
        flags.dirty = true;
        self.0.cv.notify_one();
    }
}

/// Dedicated thread that rewrites `status.json` whenever events have
/// arrived since the last write.  Writes coalesce naturally: every
/// transition *batch* lands as one snapshot, not one write per event.
/// Dropping the writer flushes a final snapshot and joins the thread.
pub struct StatusWriter {
    shared: Arc<WriterShared>,
    handle: Option<JoinHandle<()>>,
}

impl StatusWriter {
    /// Start the writer thread; it owns `path` until drop.
    pub fn spawn(collector: Arc<Collector>, path: PathBuf) -> StatusWriter {
        let shared = Arc::new(WriterShared {
            collector,
            path,
            flags: Mutex::new(WriterFlags {
                dirty: false,
                stop: false,
            }),
            cv: Condvar::new(),
        });
        let thread_shared = shared.clone();
        let handle = std::thread::Builder::new()
            .name("llmr-status-writer".into())
            .spawn(move || {
                let mut flags = thread_shared
                    .flags
                    .lock()
                    .unwrap_or_else(|p| p.into_inner());
                loop {
                    while !flags.dirty && !flags.stop {
                        flags = thread_shared
                            .cv
                            .wait(flags)
                            .unwrap_or_else(|p| p.into_inner());
                    }
                    if flags.dirty {
                        flags.dirty = false;
                        drop(flags);
                        thread_shared.write_now();
                        flags = thread_shared
                            .flags
                            .lock()
                            .unwrap_or_else(|p| p.into_inner());
                        continue;
                    }
                    break; // stop && !dirty
                }
                drop(flags);
                // Final snapshot so the on-disk file reflects the last
                // transition even if no write raced it in.
                thread_shared.write_now();
            })
            .expect("spawn status writer thread");
        StatusWriter {
            shared,
            handle: Some(handle),
        }
    }

    /// The subscriber to attach to the bus.
    pub fn notifier(&self) -> Arc<dyn Subscriber> {
        Arc::new(StatusNotifier(self.shared.clone()))
    }

    /// Where snapshots land.
    pub fn path(&self) -> &Path {
        &self.shared.path
    }
}

impl Drop for StatusWriter {
    fn drop(&mut self) {
        {
            let mut flags =
                self.shared.flags.lock().unwrap_or_else(|p| p.into_inner());
            flags.stop = true;
            self.shared.cv.notify_one();
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// InvocationTelemetry: the bundle a Session/resume wires up
// ---------------------------------------------------------------------------

/// One invocation's telemetry plumbing: a [`Collector`] and a
/// [`StatusWriter`] subscribed to an engine's bus.  Dropping it
/// unsubscribes both and flushes the final `status.json` — do that
/// *before* the workdir is removed.
pub struct InvocationTelemetry {
    bus: Arc<EventBus>,
    collector: Arc<Collector>,
    subs: Vec<SubscriptionId>,
    writer: Option<StatusWriter>,
}

impl InvocationTelemetry {
    /// Subscribe a fresh collector + status writer to `bus`, writing
    /// snapshots at `status_path`.
    pub fn attach(bus: Arc<EventBus>, status_path: PathBuf) -> InvocationTelemetry {
        let collector = Arc::new(Collector::new());
        let writer = StatusWriter::spawn(collector.clone(), status_path);
        let subs = vec![
            bus.subscribe(collector.clone()),
            bus.subscribe(writer.notifier()),
        ];
        InvocationTelemetry {
            bus,
            collector,
            subs,
            writer: Some(writer),
        }
    }

    /// The bus this bundle is subscribed to (thread it into
    /// `JobSpec::telemetry`).
    pub fn bus(&self) -> &Arc<EventBus> {
        &self.bus
    }

    /// The invocation's collector (for tests and live endpoints).
    pub fn collector(&self) -> &Arc<Collector> {
        &self.collector
    }
}

impl Drop for InvocationTelemetry {
    fn drop(&mut self) {
        for id in self.subs.drain(..) {
            self.bus.unsubscribe(id);
        }
        // Joins the writer thread, which flushes the final snapshot.
        self.writer.take();
    }
}

// ---------------------------------------------------------------------------
// MetricsListener: the --metrics-listen endpoint
// ---------------------------------------------------------------------------

/// TCP endpoint serving `/metrics` (Prometheus text) and `/status`
/// (snapshot JSON) from a [`Collector`].  Speaks both the repo's raw
/// line protocol (`printf '/metrics\n' | nc`) and minimal HTTP GET
/// (`curl http://host:port/metrics`), because scrapers expect HTTP but
/// everything else in `scheduler::remote` is line-framed.
pub struct MetricsListener {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsListener {
    /// Bind `addr` and serve until drop.
    pub fn bind(addr: &str, collector: Arc<Collector>) -> Result<MetricsListener> {
        let listener = TcpListener::bind(addr).map_err(|e| {
            Error::Scheduler(format!("metrics listener bind {addr}: {e}"))
        })?;
        let local = listener.local_addr().map_err(|e| {
            Error::Scheduler(format!("metrics listener addr: {e}"))
        })?;
        listener.set_nonblocking(true).map_err(|e| {
            Error::Scheduler(format!("metrics listener nonblocking: {e}"))
        })?;
        let stop = Arc::new(AtomicBool::new(false));
        let thread_stop = stop.clone();
        let handle = std::thread::Builder::new()
            .name("llmr-metrics-listener".into())
            .spawn(move || {
                while !thread_stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((conn, _)) => {
                            // Serve inline: responses are small and
                            // bounded by socket timeouts, so a slow
                            // client cannot wedge the accept loop long.
                            let _ = serve_conn(conn, &collector);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(25));
                        }
                        Err(_) => {
                            std::thread::sleep(Duration::from_millis(25));
                        }
                    }
                }
            })
            .expect("spawn metrics listener thread");
        Ok(MetricsListener {
            addr: local,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (useful with port 0 in tests).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsListener {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_conn(conn: TcpStream, collector: &Collector) -> std::io::Result<()> {
    conn.set_nonblocking(false)?;
    conn.set_read_timeout(Some(Duration::from_secs(2)))?;
    conn.set_write_timeout(Some(Duration::from_secs(2)))?;
    let mut reader = BufReader::new(conn.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let line = line.trim();
    // "GET /metrics HTTP/1.1" or bare "/metrics".
    let (http, path) = match line.strip_prefix("GET ") {
        Some(rest) => (true, rest.split_whitespace().next().unwrap_or("")),
        None => (false, line),
    };
    let (status, ctype, body) = match path {
        "/metrics" => (
            "200 OK",
            "text/plain; version=0.0.4",
            collector.render_prometheus(),
        ),
        "/status" => {
            let mut body = collector.snapshot().to_string_compact();
            body.push('\n');
            ("200 OK", "application/json", body)
        }
        _ => (
            "404 Not Found",
            "text/plain",
            format!("unknown path {path:?}; try /metrics or /status\n"),
        ),
    };
    let mut out = conn;
    if http {
        write!(
            out,
            "HTTP/1.0 {status}\r\nContent-Type: {ctype}\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        )?;
    }
    out.write_all(body.as_bytes())?;
    out.flush()?;
    Ok(())
}

/// Line-protocol client for `top` and tests: send one request line to
/// a [`MetricsListener`] and read the raw response body.
pub fn fetch(addr: &str, path: &str) -> Result<String> {
    use std::net::ToSocketAddrs;
    // Resolve + connect with a bounded timeout so `top` against a
    // dead or firewalled endpoint fails fast instead of hanging on
    // the OS connect deadline.
    let sa = addr
        .to_socket_addrs()
        .map_err(|e| {
            Error::Scheduler(format!(
                "metrics endpoint {addr} does not resolve: {e}"
            ))
        })?
        .next()
        .ok_or_else(|| {
            Error::Scheduler(format!(
                "metrics endpoint {addr} resolves to no address"
            ))
        })?;
    let stream = TcpStream::connect_timeout(&sa, Duration::from_secs(2))
        .map_err(|e| {
            Error::Scheduler(format!(
                "connect to metrics endpoint {addr}: {e}"
            ))
        })?;
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .and_then(|()| stream.set_write_timeout(Some(Duration::from_secs(5))))
        .map_err(|e| Error::Scheduler(format!("metrics socket setup: {e}")))?;
    let mut stream = stream;
    stream
        .write_all(format!("{path}\n").as_bytes())
        .and_then(|()| stream.flush())
        .and_then(|()| stream.shutdown(Shutdown::Write))
        .map_err(|e| Error::Scheduler(format!("metrics request: {e}")))?;
    let mut body = String::new();
    stream
        .read_to_string(&mut body)
        .map_err(|e| Error::Scheduler(format!("metrics response: {e}")))?;
    Ok(body)
}

// ---------------------------------------------------------------------------
// Offline folds + rendering for `llmapreduce status` / `top`
// ---------------------------------------------------------------------------

/// Fold a (possibly crashed) workdir into status JSON.
///
/// The journal, when present, is **authoritative** for done/error
/// counts: it is fsync'd per transition and is exactly what a
/// subsequent `resume` acts on, while `status.json` batches and may
/// trail by a write.  `status.json` enriches the fold with what the
/// journal cannot know (latency quantiles, worker attribution, queue
/// depth); on journal-less runs (`--journal=false`) it stands alone.
pub fn fold_workdir(workdir: &Path) -> Result<Json> {
    let journal_path = workdir.join(JOURNAL_FILE);
    let status_path = workdir.join(STATUS_FILE);
    let status_json = std::fs::read_to_string(&status_path)
        .ok()
        .and_then(|t| Json::parse(&t).ok());
    if !journal_path.is_file() {
        return match status_json {
            Some(Json::Obj(mut map)) => {
                map.insert("source".into(), Json::Str("status.json".into()));
                Ok(Json::Obj(map))
            }
            _ => Err(Error::opt(format!(
                "no {JOURNAL_FILE} or {STATUS_FILE} under {} — nothing to report",
                workdir.display()
            ))),
        };
    }

    let replay = Replay::load(&journal_path)?;
    let mut jobs = BTreeMap::new();
    for (id, j) in replay.jobs.iter() {
        let state = if j.failed.is_some() {
            "failed"
        } else if j.completed {
            "done"
        } else {
            "interrupted"
        };
        jobs.insert(
            id.to_string(),
            obj(vec![
                ("name", Json::Str(j.name.clone())),
                ("ntasks", Json::Num(j.ntasks as f64)),
                ("done", Json::Num(j.done.len() as f64)),
                ("errors", Json::Num(j.dead_lettered.len() as f64)),
                ("task_errors", Json::Num(j.task_errors as f64)),
                ("retries", Json::Num(j.retries as f64)),
                ("reassigned", Json::Num(j.reassigns as f64)),
                ("breaker", Json::Bool(j.breaker)),
                ("state", Json::Str(state.to_string())),
                (
                    "failed",
                    match &j.failed {
                        Some(m) => Json::Str(m.clone()),
                        None => Json::Null,
                    },
                ),
            ]),
        );
    }

    let mut top = vec![
        ("v", Json::Num(1.0)),
        ("source", Json::Str("journal".into())),
        ("records", Json::Num(replay.records as f64)),
        ("resumes", Json::Num(replay.resumes as f64)),
        ("jobs", Json::Obj(jobs)),
    ];

    // The counts a `resume` of this workdir would act on: completion
    // unioned across generations of the *mapper* job, by task id.
    if let Some(inv) = &replay.invocation {
        let map_name = crate::apps::registry::resolve_mapper(&inv.mapper)
            .map(|m| m.name().to_string())
            .unwrap_or_else(|_| inv.mapper.clone());
        let done = replay.done_task_ids(&map_name);
        let errors = replay.dead_lettered_task_ids(&map_name);
        top.push((
            "map",
            obj(vec![
                ("name", Json::Str(map_name)),
                ("ntasks", Json::Num(inv.ntasks as f64)),
                ("done", Json::Num(done.len() as f64)),
                ("errors", Json::Num(errors.len() as f64)),
                (
                    "pending",
                    Json::Num(inv.ntasks.saturating_sub(done.len()) as f64),
                ),
            ]),
        ));
    }

    // Enrichment the journal cannot provide.
    if let Some(s) = &status_json {
        for key in ["latency", "workers", "queue_depth"] {
            if let Some(v) = s.get(key) {
                top.push((key, v.clone()));
            }
        }
    }
    Ok(obj(top))
}

fn num(j: Option<&Json>) -> usize {
    j.and_then(|v| v.as_usize()).unwrap_or(0)
}

fn jstr(j: Option<&Json>) -> String {
    j.and_then(|v| v.as_str()).unwrap_or("-").to_string()
}

fn latency_rows(status: &Json) -> Vec<Vec<String>> {
    let ms = |j: Option<&Json>| match j.and_then(|v| v.as_f64()) {
        Some(v) => format!("{:.1}ms", v * 1e3),
        None => "-".to_string(),
    };
    let mut rows = Vec::new();
    if let Some(lat) = status.get("latency") {
        for phase in ["dispatch", "startup", "compute"] {
            let h = match lat.get(phase) {
                Some(h) if !matches!(h, Json::Null) => h,
                _ => continue,
            };
            rows.push(vec![
                phase.to_string(),
                ms(h.get("p50")),
                ms(h.get("p95")),
                ms(h.get("p99")),
                num(h.get("count")).to_string(),
            ]);
        }
    }
    rows
}

fn jobs_rows(status: &Json) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    if let Some(jobs) = status.get("jobs").and_then(|j| j.as_obj()) {
        for (id, j) in jobs {
            rows.push(vec![
                id.clone(),
                jstr(j.get("name")),
                format!("{}/{}", num(j.get("done")), num(j.get("ntasks"))),
                num(j.get("running")).to_string(),
                num(j.get("errors")).to_string(),
                num(j.get("retries")).to_string(),
                num(j.get("reassigned")).to_string(),
                jstr(j.get("state")),
            ]);
        }
    }
    rows
}

/// Render a [`fold_workdir`] result (or a live snapshot — the shapes
/// share their table-backing keys) as the `llmapreduce status` report.
pub fn render_status(status: &Json) -> String {
    use crate::metrics::report::render_table;
    let mut out = String::new();
    let source = jstr(status.get("source"));
    if source != "-" {
        out.push_str(&format!("source: {source}"));
        let resumes = num(status.get("resumes"));
        if resumes > 0 {
            out.push_str(&format!(" (resumed {resumes}x)"));
        }
        out.push('\n');
    }
    if let Some(map) = status.get("map") {
        out.push_str(&format!(
            "map {}: {}/{} done, {} dead-lettered, {} pending re-run\n",
            jstr(map.get("name")),
            num(map.get("done")),
            num(map.get("ntasks")),
            num(map.get("errors")),
            num(map.get("pending")),
        ));
    }
    let jobs = jobs_rows(status);
    if !jobs.is_empty() {
        out.push_str(&render_table(
            &[
                "job", "name", "done", "running", "errors", "retries", "reassigned",
                "state",
            ],
            &jobs,
        ));
    }
    let lat = latency_rows(status);
    if !lat.is_empty() {
        out.push_str(&render_table(
            &["phase", "p50", "p95", "p99", "count"],
            &lat,
        ));
    }
    out
}

/// Render one `top` frame from a live snapshot (the `/status` body or
/// `status.json`).
pub fn render_top(status: &Json) -> String {
    use crate::metrics::report::render_table;
    let totals = status.get("totals");
    let header = format!(
        "queue {} | running {} | done {} | errors {} | retries {} | t+{}ms\n",
        num(status.get("queue_depth")),
        num(totals.and_then(|t| t.get("running"))),
        num(totals.and_then(|t| t.get("done"))),
        num(totals.and_then(|t| t.get("errors"))),
        num(totals.and_then(|t| t.get("retries"))),
        num(status.get("at_ms")),
    );
    let mut out = header;
    let jobs = jobs_rows(status);
    if !jobs.is_empty() {
        out.push_str(&render_table(
            &[
                "job", "name", "done", "running", "errors", "retries", "reassigned",
                "state",
            ],
            &jobs,
        ));
    }
    if let Some(workers) = status.get("workers").and_then(|w| w.as_obj()) {
        if !workers.is_empty() {
            let rows: Vec<Vec<String>> = workers
                .iter()
                .map(|(name, w)| {
                    vec![
                        name.clone(),
                        num(w.get("slots")).to_string(),
                        if w.get("alive").and_then(|a| a.as_bool()).unwrap_or(false) {
                            "yes".to_string()
                        } else {
                            "no".to_string()
                        },
                        num(w.get("done")).to_string(),
                    ]
                })
                .collect();
            out.push_str(&render_table(&["worker", "slots", "alive", "done"], &rows));
        }
    }
    let lat = latency_rows(status);
    if !lat.is_empty() {
        out.push_str(&render_table(
            &["phase", "p50", "p95", "p99", "count"],
            &lat,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stamped(seq: u64, event: Event) -> Stamped {
        Stamped {
            seq,
            at: Duration::from_millis(seq),
            event,
        }
    }

    fn feed(collector: &Collector, events: Vec<Event>) {
        for (i, ev) in events.into_iter().enumerate() {
            collector.on_event(&stamped(i as u64, ev));
        }
    }

    #[test]
    fn collector_folds_a_job_lifecycle() {
        let c = Collector::new();
        feed(
            &c,
            vec![
                Event::JobSubmitted {
                    job: 1,
                    name: "wordcount".into(),
                    ntasks: 2,
                },
                Event::QueueDepth { depth: 2 },
                Event::TaskAssigned {
                    job: 1,
                    task_id: 1,
                    worker: Some("w0".into()),
                },
                Event::TaskDone {
                    job: 1,
                    task_id: 1,
                    worker: Some("w0".into()),
                    dispatch_wait: Duration::from_millis(2),
                    startup: Duration::from_millis(3),
                    compute: Duration::from_millis(40),
                    retries: 0,
                    dead_lettered: false,
                    timing: None,
                },
                Event::TaskAssigned {
                    job: 1,
                    task_id: 2,
                    worker: Some("w1".into()),
                },
                Event::TaskDone {
                    job: 1,
                    task_id: 2,
                    worker: Some("w1".into()),
                    dispatch_wait: Duration::from_millis(1),
                    startup: Duration::from_millis(2),
                    compute: Duration::from_millis(30),
                    retries: 0,
                    dead_lettered: true,
                    timing: None,
                },
                Event::JobDone { job: 1 },
                Event::QueueDepth { depth: 0 },
            ],
        );
        let r = c.registry();
        assert_eq!(r.counter_total("llmr_tasks_done_total"), 2);
        assert_eq!(
            r.counter_value(
                "llmr_tasks_done_total",
                &[("job", "wordcount"), ("worker", "w0")]
            ),
            1
        );
        assert_eq!(r.counter_total("llmr_tasks_dead_lettered_total"), 1);
        assert_eq!(r.gauge_value("llmr_queue_depth", &[]), Some(0.0));
        assert_eq!(
            r.histogram_merged("llmr_task_compute_seconds").unwrap().count(),
            2
        );

        let snap = c.snapshot();
        let job = snap.get("jobs").unwrap().get("1").unwrap();
        assert_eq!(job.get("done").unwrap().as_usize(), Some(2));
        assert_eq!(job.get("errors").unwrap().as_usize(), Some(1));
        assert_eq!(job.get("state").unwrap().as_str(), Some("done"));
        let totals = snap.get("totals").unwrap();
        assert_eq!(totals.get("done").unwrap().as_usize(), Some(2));
        // Renderers accept the snapshot shape.
        let frame = render_top(&snap);
        assert!(frame.contains("wordcount"));
        assert!(frame.starts_with("queue 0 | running 0 | done 2"));
    }

    #[test]
    fn status_writer_snapshots_atomically_and_flushes_on_drop() {
        let dir = std::env::temp_dir()
            .join(format!("llmr-statuswriter-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let bus = Arc::new(EventBus::new());
        let tele =
            InvocationTelemetry::attach(bus.clone(), dir.join(STATUS_FILE));
        bus.emit(Event::JobSubmitted {
            job: 1,
            name: "j".into(),
            ntasks: 1,
        });
        bus.emit(Event::JobDone { job: 1 });
        drop(tele);
        assert!(!bus.active(), "drop unsubscribes");
        let text = std::fs::read_to_string(dir.join(STATUS_FILE)).unwrap();
        let snap = Json::parse(&text).unwrap();
        assert_eq!(
            snap.get("jobs").unwrap().get("1").unwrap().get("state").unwrap(),
            &Json::Str("done".into())
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn metrics_listener_serves_line_protocol_and_http() {
        let collector = Arc::new(Collector::new());
        collector.on_event(&stamped(
            0,
            Event::QueueDepth { depth: 5 },
        ));
        let listener =
            MetricsListener::bind("127.0.0.1:0", collector.clone()).unwrap();
        let addr = listener.local_addr().to_string();
        let text = fetch(&addr, "/metrics").unwrap();
        assert!(text.contains("llmr_queue_depth 5"));
        let status = fetch(&addr, "/status").unwrap();
        let snap = Json::parse(status.trim()).unwrap();
        assert_eq!(snap.get("queue_depth").unwrap().as_usize(), Some(5));
        // HTTP GET framing on the same port.
        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.0 200 OK"));
        assert!(resp.contains("llmr_queue_depth 5"));
        // Unknown paths are a 404, not a hang.
        let notfound = fetch(&addr, "/nope").unwrap();
        assert!(notfound.contains("unknown path"));
    }
}
