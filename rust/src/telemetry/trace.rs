//! Distributed tracing (DESIGN.md §12): per-task span timelines,
//! Chrome trace-event export, and critical-path analysis.
//!
//! The tracing layer is a *fold* over the same facts every other
//! surface consumes — the journal's traced done records offline, or
//! [`crate::telemetry::Event::TaskDone`] timings live — so a trace
//! assembled after SIGKILL from `journal.jsonl` agrees byte-for-byte
//! with one folded from the event stream of an uninterrupted run.
//!
//! **Span model.**  Each finished task attempt is tiled into at most
//! six contiguous phases on a µs timeline relative to job submission:
//!
//! ```text
//! queued → dispatched → ship-out → startup → compute → result
//! [0 ............................................. finished_us]
//! ```
//!
//! The tiling is *exact by construction*: phase boundaries are clamped
//! monotone (`queued` ends at `started − dispatch`, `dispatched` at
//! `started`, then ship-out/startup/compute consume their measured
//! durations capped by the time remaining, and `result` absorbs the
//! remainder up to `finished`).  Zero-width phases are dropped.  The
//! sum of a task's span durations therefore equals `finished_us`
//! exactly, which is what makes the critical-path report's per-phase
//! totals sum to the makespan.
//!
//! `ship-out` is the outbound half of the remote engine's shipping
//! overhead.  When the worker stamped its completion frame
//! (PR 9 workers report recv/exec-start/exec-end on their own
//! monotonic clock, aligned via the heartbeat-RTT clock-offset
//! estimate — DESIGN.md §12), the coordinator resolves it exactly;
//! legacy frames fall back to splitting `shipped` symmetrically.
//!
//! **Critical path.**  Tasks carry no explicit dependency edges in the
//! journal, so the chain is reconstructed from the timeline: start at
//! the task that determines the makespan, then repeatedly link to the
//! latest-finishing task that completed before the current link became
//! eligible (its `queued → dispatched` boundary).  Within a Session
//! chain the jobs are submitted together, so a reduce task's queue
//! wait is exactly the upstream map's runtime and the walk recovers
//! the map → partial → reduce dependency order.  Each link's spans are
//! trimmed to start where the previous link finished, so the chain
//! tiles `[0, makespan]` with no gaps or overlaps.

use std::collections::{BTreeMap, HashSet};
use std::path::Path;
use std::sync::Mutex;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::scheduler::journal::{Replay, JOURNAL_FILE};
use crate::scheduler::TaskTiming;
use crate::util::json::{obj, Json};

use super::bus::Subscriber;
use super::event::{Event, Stamped};

/// One phase of a task attempt's timeline, in execution order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Phase {
    /// Waiting for eligibility + a free slot (includes upstream jobs).
    Queued,
    /// Dispatch latency: picked by the scheduler, not yet running.
    Dispatched,
    /// Outbound wire shipping (remote engine; absent in-process).
    ShipOut,
    /// Application start-up inside the task.
    Startup,
    /// Per-item compute.
    Compute,
    /// Result return: ship-back + completion bookkeeping remainder.
    Result,
}

impl Phase {
    /// All phases, in timeline order.
    pub const ALL: [Phase; 6] = [
        Phase::Queued,
        Phase::Dispatched,
        Phase::ShipOut,
        Phase::Startup,
        Phase::Compute,
        Phase::Result,
    ];

    /// Stable lower-case name (Chrome trace slice names, report rows).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Queued => "queued",
            Phase::Dispatched => "dispatched",
            Phase::ShipOut => "ship-out",
            Phase::Startup => "startup",
            Phase::Compute => "compute",
            Phase::Result => "result",
        }
    }
}

/// One phase interval on the job-submission-relative µs timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub phase: Phase,
    pub start_us: u64,
    pub end_us: u64,
}

impl Span {
    pub fn dur_us(&self) -> u64 {
        self.end_us - self.start_us
    }
}

/// The assembled timeline of one task's successful attempt.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskTrace {
    pub job: u64,
    pub task_id: usize,
    /// Retries consumed before this (successful) attempt.
    pub attempt: usize,
    /// The persisted decomposition the spans were tiled from.
    pub timing: TaskTiming,
    /// Contiguous, clamped-monotone phase tiling of
    /// `[0, timing.finished_us]`; zero-width phases omitted.
    pub spans: Vec<Span>,
}

impl TaskTrace {
    /// Assemble a task trace by tiling `timing` (see module docs).
    pub fn new(
        job: u64,
        task_id: usize,
        attempt: usize,
        timing: TaskTiming,
    ) -> TaskTrace {
        let spans = tile(&timing);
        TaskTrace {
            job,
            task_id,
            attempt,
            timing,
            spans,
        }
    }

    /// When the task became dispatchable (its `queued` phase ended).
    pub fn eligible_us(&self) -> u64 {
        self.timing
            .started_us
            .min(self.timing.finished_us)
            .saturating_sub(self.timing.dispatch_us)
    }

    pub fn finished_us(&self) -> u64 {
        self.timing.finished_us.max(self.timing.started_us)
    }
}

/// Tile a timing decomposition into contiguous spans covering
/// `[0, finished]` exactly (module docs).  Defensive about
/// inconsistent inputs: every boundary is clamped so the tiling is
/// monotone regardless of what a corrupt journal reports.
fn tile(t: &TaskTiming) -> Vec<Span> {
    let finished = t.finished_us.max(t.started_us);
    let started = t.started_us.min(finished);
    let q_end = started.saturating_sub(t.dispatch_us);
    let mut spans = Vec::with_capacity(Phase::ALL.len());
    let mut push = |phase: Phase, a: u64, b: u64| {
        if b > a {
            spans.push(Span {
                phase,
                start_us: a,
                end_us: b,
            });
        }
    };
    push(Phase::Queued, 0, q_end);
    push(Phase::Dispatched, q_end, started);
    let mut cur = started;
    // The worker-resolved outbound slice when present, else half the
    // round-trip shipping overhead; always bounded by time remaining.
    let ship_out = t
        .ship_out_us
        .unwrap_or(t.shipped_us / 2)
        .min(finished - cur);
    push(Phase::ShipOut, cur, cur + ship_out);
    cur += ship_out;
    let startup = t.startup_us.min(finished - cur);
    push(Phase::Startup, cur, cur + startup);
    cur += startup;
    let compute = t.compute_us.min(finished - cur);
    push(Phase::Compute, cur, cur + compute);
    cur += compute;
    push(Phase::Result, cur, finished);
    spans
}

/// One job's assembled task traces.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JobTrace {
    pub name: String,
    pub ntasks: usize,
    /// Keyed by task id; one entry per *completed* task with timings.
    pub tasks: BTreeMap<usize, TaskTrace>,
}

/// A whole invocation's trace: every job's task timelines on one
/// µs axis.  Jobs of a Session chain are submitted together, so their
/// per-job-submission-relative timelines are mutually comparable
/// (module docs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub jobs: BTreeMap<u64, JobTrace>,
    /// `resumed` journal markers folded in (offline assembly only).
    pub resumes: usize,
}

impl Trace {
    /// Assemble from a journal replay — the offline path behind
    /// `llmapreduce trace`, which works after SIGKILL exactly like
    /// `status` (both fold the same fsync'd records).
    pub fn from_replay(replay: &Replay) -> Trace {
        let mut trace = Trace {
            resumes: replay.resumes,
            ..Trace::default()
        };
        for (id, j) in replay.jobs.iter() {
            if j.timings.is_empty() {
                continue;
            }
            let name = if j.name.is_empty() {
                format!("job-{id}")
            } else {
                j.name.clone()
            };
            let jt = trace.jobs.entry(*id).or_default();
            jt.name = name;
            jt.ntasks = j.ntasks;
            for (task_id, (retries, timing)) in j.timings.iter() {
                jt.tasks.insert(
                    *task_id,
                    TaskTrace::new(*id, *task_id, *retries, timing.clone()),
                );
            }
        }
        trace
    }

    /// Every assembled task across all jobs.
    pub fn tasks(&self) -> impl Iterator<Item = &TaskTrace> {
        self.jobs.values().flat_map(|j| j.tasks.values())
    }

    /// The latest task completion — the measured makespan, µs.
    pub fn makespan_us(&self) -> u64 {
        self.tasks().map(|t| t.finished_us()).max().unwrap_or(0)
    }
}

/// Bus subscriber that assembles a [`Trace`] live — the in-process
/// twin of [`Trace::from_replay`] (both fold the same `TaskTiming`
/// values, so the results agree).
#[derive(Default)]
pub struct TraceCollector {
    trace: Mutex<Trace>,
}

impl TraceCollector {
    pub fn new() -> TraceCollector {
        TraceCollector::default()
    }

    /// The trace assembled so far.
    pub fn snapshot(&self) -> Trace {
        self.trace.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }
}

impl Subscriber for TraceCollector {
    fn on_event(&self, ev: &Stamped) {
        let mut trace =
            self.trace.lock().unwrap_or_else(|p| p.into_inner());
        match &ev.event {
            Event::JobSubmitted { job, name, ntasks } => {
                let jt = trace.jobs.entry(*job).or_default();
                jt.name = name.clone();
                jt.ntasks = *ntasks;
            }
            Event::TaskDone {
                job,
                task_id,
                retries,
                timing: Some(t),
                ..
            } => {
                let jt = trace.jobs.entry(*job).or_default();
                if jt.name.is_empty() {
                    jt.name = format!("job-{job}");
                }
                jt.tasks.insert(
                    *task_id,
                    TaskTrace::new(*job, *task_id, *retries, t.clone()),
                );
            }
            Event::Resumed { .. } => trace.resumes += 1,
            _ => {}
        }
    }
}

/// Assemble an offline trace from a (possibly crashed) workdir's
/// journal.
pub fn trace_workdir(workdir: &Path) -> Result<Trace> {
    let journal_path = workdir.join(JOURNAL_FILE);
    if !journal_path.is_file() {
        return Err(Error::opt(format!(
            "no {JOURNAL_FILE} under {} — tracing needs a journaled \
             run (--journal=true, the default)",
            workdir.display()
        )));
    }
    let replay = Replay::load(&journal_path)?;
    let trace = Trace::from_replay(&replay);
    if trace.jobs.is_empty() {
        return Err(Error::opt(format!(
            "journal under {} has no span timings — the run used \
             --trace=false, or predates tracing, or no task completed",
            workdir.display()
        )));
    }
    Ok(trace)
}

// ---------------------------------------------------------------------------
// Chrome trace-event export
// ---------------------------------------------------------------------------

/// Render a trace as Chrome trace-event JSON (the `{"traceEvents":
/// [...]}` object form), loadable in Perfetto / `chrome://tracing`.
///
/// Mapping (DESIGN.md §12): one *process* per job (`pid` = job id,
/// named via a `process_name` metadata event), one *thread* per task
/// (`tid` = task id), one complete (`ph:"X"`) slice per phase span
/// plus an umbrella `task N` slice covering `[0, finished_us]` so
/// phase slices nest inside their task's bounds.  Timestamps are µs,
/// the format's native unit.  Every slice carries task / worker /
/// attempt / batch attribution in `args`.
pub fn chrome_trace(trace: &Trace) -> Json {
    let mut events = Vec::new();
    for (job_id, job) in trace.jobs.iter() {
        let pid = *job_id as usize;
        events.push(obj(vec![
            ("name", "process_name".into()),
            ("ph", "M".into()),
            ("pid", pid.into()),
            (
                "args",
                obj(vec![("name", Json::Str(job.name.clone()))]),
            ),
        ]));
        for task in job.tasks.values() {
            let tid = task.task_id;
            let attribution = || {
                obj(vec![
                    ("task", tid.into()),
                    (
                        "worker",
                        match &task.timing.worker {
                            Some(w) => Json::Str(w.clone()),
                            None => Json::Null,
                        },
                    ),
                    ("attempt", task.attempt.into()),
                    ("items", task.timing.items.into()),
                ])
            };
            events.push(obj(vec![
                ("name", "thread_name".into()),
                ("ph", "M".into()),
                ("pid", pid.into()),
                ("tid", tid.into()),
                (
                    "args",
                    obj(vec![(
                        "name",
                        Json::Str(format!("task {tid}")),
                    )]),
                ),
            ]));
            // Umbrella slice: phase slices nest inside it (Chrome
            // trace nests same-tid "X" events by containment).
            events.push(obj(vec![
                ("name", Json::Str(format!("task {tid}"))),
                ("cat", "task".into()),
                ("ph", "X".into()),
                ("pid", pid.into()),
                ("tid", tid.into()),
                ("ts", 0usize.into()),
                ("dur", (task.finished_us() as usize).into()),
                ("args", attribution()),
            ]));
            for span in &task.spans {
                events.push(obj(vec![
                    ("name", span.phase.name().into()),
                    ("cat", "phase".into()),
                    ("ph", "X".into()),
                    ("pid", pid.into()),
                    ("tid", tid.into()),
                    ("ts", (span.start_us as usize).into()),
                    ("dur", (span.dur_us() as usize).into()),
                    ("args", attribution()),
                ]));
            }
        }
    }
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", "ms".into()),
    ])
}

/// Render a trace as the raw span-tree JSON (`--format=json`): the
/// assembled structure itself, for tooling that wants the tiling
/// without the Chrome event encoding.
pub fn trace_json(trace: &Trace) -> Json {
    let jobs: BTreeMap<String, Json> = trace
        .jobs
        .iter()
        .map(|(id, job)| {
            let tasks: BTreeMap<String, Json> = job
                .tasks
                .iter()
                .map(|(tid, t)| {
                    let spans: Vec<Json> = t
                        .spans
                        .iter()
                        .map(|s| {
                            obj(vec![
                                ("phase", s.phase.name().into()),
                                (
                                    "start_us",
                                    (s.start_us as usize).into(),
                                ),
                                ("end_us", (s.end_us as usize).into()),
                            ])
                        })
                        .collect();
                    (
                        tid.to_string(),
                        obj(vec![
                            ("attempt", t.attempt.into()),
                            (
                                "worker",
                                match &t.timing.worker {
                                    Some(w) => Json::Str(w.clone()),
                                    None => Json::Null,
                                },
                            ),
                            ("items", t.timing.items.into()),
                            (
                                "finished_us",
                                (t.finished_us() as usize).into(),
                            ),
                            ("spans", Json::Arr(spans)),
                        ]),
                    )
                })
                .collect();
            (
                id.to_string(),
                obj(vec![
                    ("name", Json::Str(job.name.clone())),
                    ("ntasks", job.ntasks.into()),
                    ("tasks", Json::Obj(tasks)),
                ]),
            )
        })
        .collect();
    obj(vec![
        ("v", 1usize.into()),
        ("resumes", trace.resumes.into()),
        ("makespan_us", (trace.makespan_us() as usize).into()),
        ("jobs", Json::Obj(jobs)),
    ])
}

// ---------------------------------------------------------------------------
// Critical-path analysis
// ---------------------------------------------------------------------------

/// One link of the critical path: a task and the slice of its spans
/// that lies on the path (trimmed to start where the previous link
/// finished).
#[derive(Debug, Clone, PartialEq)]
pub struct CriticalLink {
    pub job: u64,
    pub task_id: usize,
    pub spans: Vec<Span>,
}

/// The longest dependency-ordered chain of spans (module docs): its
/// links tile `[0, makespan_us]` exactly, so `phase_totals_us` sums to
/// `makespan_us`.
#[derive(Debug, Clone, PartialEq)]
pub struct CriticalPath {
    pub links: Vec<CriticalLink>,
    pub makespan_us: u64,
    /// Total path time per phase, in [`Phase::ALL`] order.
    pub phase_totals_us: [u64; 6],
}

impl CriticalPath {
    /// Invariant check: the per-phase totals tile the makespan.
    pub fn totals_cover_makespan(&self) -> bool {
        self.phase_totals_us.iter().sum::<u64>() == self.makespan_us
    }
}

/// Reconstruct the critical path of a trace (None when it has no
/// tasks).  See the module docs for the chain heuristic.
pub fn critical_path(trace: &Trace) -> Option<CriticalPath> {
    let all: Vec<&TaskTrace> = trace.tasks().collect();
    let mut cur = *all.iter().max_by_key(|t| t.finished_us())?;
    let mut visited: HashSet<(u64, usize)> = HashSet::new();
    visited.insert((cur.job, cur.task_id));
    let mut chain = vec![cur];
    loop {
        // The latest-finishing task that completed before `cur` became
        // eligible is its most plausible release dependency.
        let window = cur.eligible_us();
        let pred = all
            .iter()
            .copied()
            .filter(|t| {
                let fin = t.finished_us();
                fin > 0
                    && fin <= window
                    && !visited.contains(&(t.job, t.task_id))
            })
            .max_by_key(|t| t.finished_us());
        match pred {
            Some(p) => {
                visited.insert((p.job, p.task_id));
                chain.push(p);
                cur = p;
            }
            None => break,
        }
    }
    chain.reverse();
    // Trim each link's tiling to start where the previous one ended.
    // Every task's spans tile [0, finished], and each link's start
    // cursor (the previous finish) lies inside the next link's queued
    // span, so the trimmed links tile [0, makespan] gaplessly.
    let mut cursor = 0u64;
    let mut links = Vec::with_capacity(chain.len());
    let mut phase_totals_us = [0u64; 6];
    for t in chain {
        let mut spans = Vec::new();
        for s in &t.spans {
            let start = s.start_us.max(cursor);
            if s.end_us > start {
                spans.push(Span {
                    phase: s.phase,
                    start_us: start,
                    end_us: s.end_us,
                });
                phase_totals_us[s.phase as usize] += s.end_us - start;
            }
        }
        cursor = cursor.max(t.finished_us());
        links.push(CriticalLink {
            job: t.job,
            task_id: t.task_id,
            spans,
        });
    }
    Some(CriticalPath {
        links,
        makespan_us: cursor,
        phase_totals_us,
    })
}

// ---------------------------------------------------------------------------
// Stragglers + utilization gaps
// ---------------------------------------------------------------------------

/// Default straggler threshold: compute > 2x the job's median.
pub const STRAGGLER_FACTOR: f64 = 2.0;

/// A task whose compute time stands out against its job's median.
#[derive(Debug, Clone, PartialEq)]
pub struct Straggler {
    pub job: u64,
    pub task_id: usize,
    pub worker: Option<String>,
    pub compute_us: u64,
    /// The job's median task compute time.
    pub median_us: u64,
}

/// Tasks whose compute exceeds `factor` x their job's median compute
/// (jobs need at least two completed tasks and a nonzero median to
/// yield a meaningful baseline).
pub fn stragglers(trace: &Trace, factor: f64) -> Vec<Straggler> {
    let mut out = Vec::new();
    for (job_id, job) in trace.jobs.iter() {
        if job.tasks.len() < 2 {
            continue;
        }
        let mut computes: Vec<u64> =
            job.tasks.values().map(|t| t.timing.compute_us).collect();
        computes.sort_unstable();
        let mid = computes.len() / 2;
        let median_us = if computes.len() % 2 == 1 {
            computes[mid]
        } else {
            (computes[mid - 1] + computes[mid]) / 2
        };
        if median_us == 0 {
            continue;
        }
        for t in job.tasks.values() {
            if t.timing.compute_us as f64 > factor * median_us as f64 {
                out.push(Straggler {
                    job: *job_id,
                    task_id: t.task_id,
                    worker: t.timing.worker.clone(),
                    compute_us: t.timing.compute_us,
                    median_us,
                });
            }
        }
    }
    out
}

/// Intervals within `[0, makespan]` where *no* task was executing
/// (`started..finished`): dead time the schedule could reclaim.  The
/// leading gap (before the first task starts) covers dispatch of the
/// first wave.
pub fn utilization_gaps(trace: &Trace) -> Vec<(u64, u64)> {
    let mut busy: Vec<(u64, u64)> = trace
        .tasks()
        .map(|t| {
            (t.timing.started_us.min(t.finished_us()), t.finished_us())
        })
        .filter(|(s, f)| f > s)
        .collect();
    busy.sort_unstable();
    let mut gaps = Vec::new();
    let mut cursor = 0u64;
    for (s, f) in busy {
        if s > cursor {
            gaps.push((cursor, s));
        }
        cursor = cursor.max(f);
    }
    gaps
}

// ---------------------------------------------------------------------------
// Terminal report
// ---------------------------------------------------------------------------

fn fmt_us(us: u64) -> String {
    crate::util::fmt_duration(Duration::from_micros(us))
}

/// Render the terminal critical-path report: the chain, per-phase
/// totals (which sum to the makespan — the tiling invariant), top
/// utilization gaps, and stragglers.
pub fn render_trace_report(trace: &Trace) -> String {
    use crate::metrics::report::render_table;
    let ntasks: usize = trace.jobs.values().map(|j| j.tasks.len()).sum();
    let mut out = format!(
        "trace: {} job(s), {} traced task(s), makespan {}\n",
        trace.jobs.len(),
        ntasks,
        fmt_us(trace.makespan_us()),
    );
    if trace.resumes > 0 {
        out.push_str(&format!("  (resumed {}x)\n", trace.resumes));
    }
    let Some(path) = critical_path(trace) else {
        out.push_str("no completed tasks to analyze\n");
        return out;
    };

    out.push_str(&format!(
        "\ncritical path ({} link(s)):\n",
        path.links.len()
    ));
    let rows: Vec<Vec<String>> = path
        .links
        .iter()
        .map(|l| {
            let name = trace
                .jobs
                .get(&l.job)
                .map(|j| j.name.clone())
                .unwrap_or_else(|| l.job.to_string());
            let on_path: u64 = l.spans.iter().map(Span::dur_us).sum();
            let dominant = l
                .spans
                .iter()
                .max_by_key(|s| s.dur_us())
                .map(|s| s.phase.name())
                .unwrap_or("-");
            vec![
                name,
                l.task_id.to_string(),
                fmt_us(on_path),
                dominant.to_string(),
            ]
        })
        .collect();
    out.push_str(&render_table(
        &["job", "task", "on-path", "dominant phase"],
        &rows,
    ));

    out.push_str("\nper-phase totals on the critical path:\n");
    let total: u64 = path.phase_totals_us.iter().sum();
    let rows: Vec<Vec<String>> = Phase::ALL
        .iter()
        .map(|p| {
            let us = path.phase_totals_us[*p as usize];
            let pct = if total > 0 {
                format!("{:.1}%", us as f64 / total as f64 * 100.0)
            } else {
                "-".to_string()
            };
            vec![p.name().to_string(), fmt_us(us), pct]
        })
        .collect();
    out.push_str(&render_table(&["phase", "total", "share"], &rows));
    out.push_str(&format!(
        "  sum {} == makespan {}\n",
        fmt_us(total),
        fmt_us(path.makespan_us)
    ));

    let gaps = utilization_gaps(trace);
    let gap_total: u64 = gaps.iter().map(|(s, f)| f - s).sum();
    if gaps.is_empty() {
        out.push_str("\nutilization gaps: none\n");
    } else {
        let (ls, lf) = gaps
            .iter()
            .copied()
            .max_by_key(|(s, f)| f - s)
            .expect("nonempty gaps");
        out.push_str(&format!(
            "\nutilization gaps: {} across {} gap(s); \
             largest {} at t+{}\n",
            fmt_us(gap_total),
            gaps.len(),
            fmt_us(lf - ls),
            fmt_us(ls),
        ));
    }

    let slow = stragglers(trace, STRAGGLER_FACTOR);
    if slow.is_empty() {
        out.push_str(&format!(
            "stragglers (> {STRAGGLER_FACTOR}x median compute): none\n"
        ));
    } else {
        out.push_str(&format!(
            "stragglers (> {STRAGGLER_FACTOR}x median compute):\n"
        ));
        let rows: Vec<Vec<String>> = slow
            .iter()
            .map(|s| {
                let name = trace
                    .jobs
                    .get(&s.job)
                    .map(|j| j.name.clone())
                    .unwrap_or_else(|| s.job.to_string());
                vec![
                    name,
                    s.task_id.to_string(),
                    s.worker.clone().unwrap_or_else(|| "-".into()),
                    fmt_us(s.compute_us),
                    fmt_us(s.median_us),
                ]
            })
            .collect();
        out.push_str(&render_table(
            &["job", "task", "worker", "compute", "job median"],
            &rows,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn timing(
        started_ms: u64,
        finished_ms: u64,
        dispatch_ms: u64,
        startup_ms: u64,
        compute_ms: u64,
    ) -> TaskTiming {
        TaskTiming {
            started_us: started_ms * 1000,
            finished_us: finished_ms * 1000,
            dispatch_us: dispatch_ms * 1000,
            startup_us: startup_ms * 1000,
            compute_us: compute_ms * 1000,
            ..Default::default()
        }
    }

    fn task(
        job: u64,
        id: usize,
        started_ms: u64,
        finished_ms: u64,
        compute_ms: u64,
    ) -> TaskTrace {
        TaskTrace::new(
            job,
            id,
            0,
            timing(started_ms, finished_ms, 1, 1, compute_ms),
        )
    }

    fn trace_of(tasks: Vec<TaskTrace>) -> Trace {
        let mut trace = Trace::default();
        for t in tasks {
            let jt = trace.jobs.entry(t.job).or_default();
            jt.name = format!("job-{}", t.job);
            jt.ntasks += 1;
            jt.tasks.insert(t.task_id, t);
        }
        trace
    }

    #[test]
    fn tiling_is_contiguous_and_covers_exactly() {
        let t = TaskTiming {
            started_us: 5_000,
            finished_us: 40_000,
            dispatch_us: 2_000,
            startup_us: 3_000,
            compute_us: 25_000,
            shipped_us: 8_000,
            ship_out_us: Some(3_000),
            ..Default::default()
        };
        let spans = tile(&t);
        // Contiguous from 0 to finished, in phase order.
        assert_eq!(spans[0].start_us, 0);
        for w in spans.windows(2) {
            assert_eq!(w[0].end_us, w[1].start_us);
            assert!(w[0].phase < w[1].phase);
        }
        assert_eq!(spans.last().unwrap().end_us, 40_000);
        let total: u64 = spans.iter().map(Span::dur_us).sum();
        assert_eq!(total, 40_000);
        // The resolved outbound slice is used verbatim.
        let ship = spans
            .iter()
            .find(|s| s.phase == Phase::ShipOut)
            .unwrap();
        assert_eq!(ship.dur_us(), 3_000);
    }

    #[test]
    fn tiling_without_worker_stamps_splits_shipped_symmetrically() {
        let t = TaskTiming {
            started_us: 0,
            finished_us: 20_000,
            compute_us: 10_000,
            shipped_us: 6_000,
            ship_out_us: None,
            ..Default::default()
        };
        let spans = tile(&t);
        let ship = spans
            .iter()
            .find(|s| s.phase == Phase::ShipOut)
            .unwrap();
        assert_eq!(ship.dur_us(), 3_000);
        // The inbound half lands in the `result` remainder.
        let result = spans
            .iter()
            .find(|s| s.phase == Phase::Result)
            .unwrap();
        assert_eq!(result.dur_us(), 7_000);
    }

    #[test]
    fn tiling_clamps_inconsistent_inputs() {
        // Claims more compute than the task's wall window.
        let t = TaskTiming {
            started_us: 10_000,
            finished_us: 12_000,
            dispatch_us: 50_000,
            startup_us: 5_000,
            compute_us: 50_000,
            ..Default::default()
        };
        let spans = tile(&t);
        assert_eq!(spans.last().unwrap().end_us, 12_000);
        let total: u64 = spans.iter().map(Span::dur_us).sum();
        assert_eq!(total, 12_000, "clamped tiling still covers exactly");
        for w in spans.windows(2) {
            assert_eq!(w[0].end_us, w[1].start_us);
        }
    }

    #[test]
    fn critical_path_chains_across_jobs_and_tiles_makespan() {
        // Map job 1: three tasks; reduce job 2: one task queued behind
        // the map (eligible 1ms after task 3 — the last mapper — ends).
        let reduce = TaskTrace::new(
            2,
            1,
            0,
            timing(62, 80, 1, 1, 15),
        );
        let trace = trace_of(vec![
            task(1, 1, 2, 30, 25),
            task(1, 2, 2, 40, 35),
            task(1, 3, 2, 60, 55),
            reduce,
        ]);
        let path = critical_path(&trace).unwrap();
        assert_eq!(path.makespan_us, 80_000);
        assert!(path.totals_cover_makespan());
        // Chain: mapper task 3 (finishes at 60ms, inside the reduce's
        // 60ms queued window) then the reduce.
        let ids: Vec<(u64, usize)> =
            path.links.iter().map(|l| (l.job, l.task_id)).collect();
        assert_eq!(ids, vec![(1, 3), (2, 1)]);
        // The reduce link's queued span is trimmed to the residual
        // wait after the mapper finished.
        let reduce_link = &path.links[1];
        let q = reduce_link
            .spans
            .iter()
            .find(|s| s.phase == Phase::Queued)
            .unwrap();
        assert_eq!(q.start_us, 60_000);
        // Links tile [0, makespan] with no gaps or overlaps.
        let mut all: Vec<Span> = path
            .links
            .iter()
            .flat_map(|l| l.spans.iter().copied())
            .collect();
        all.sort_by_key(|s| s.start_us);
        assert_eq!(all[0].start_us, 0);
        for w in all.windows(2) {
            assert_eq!(w[0].end_us, w[1].start_us);
        }
        assert_eq!(all.last().unwrap().end_us, 80_000);
    }

    #[test]
    fn critical_path_of_single_task_is_its_own_tiling() {
        let trace = trace_of(vec![task(1, 1, 5, 50, 40)]);
        let path = critical_path(&trace).unwrap();
        assert_eq!(path.links.len(), 1);
        assert!(path.totals_cover_makespan());
        assert_eq!(path.makespan_us, 50_000);
    }

    #[test]
    fn empty_trace_has_no_critical_path() {
        assert!(critical_path(&Trace::default()).is_none());
        assert_eq!(Trace::default().makespan_us(), 0);
    }

    #[test]
    fn stragglers_flag_tasks_past_factor_times_median() {
        let trace = trace_of(vec![
            task(1, 1, 0, 10, 10),
            task(1, 2, 0, 11, 11),
            task(1, 3, 0, 12, 12),
            task(1, 4, 0, 50, 50),
        ]);
        let slow = stragglers(&trace, 2.0);
        assert_eq!(slow.len(), 1);
        assert_eq!(slow[0].task_id, 4);
        assert_eq!(slow[0].median_us, 11_500);
        // A lone task is never a straggler (no baseline).
        let lone = trace_of(vec![task(2, 1, 0, 50, 50)]);
        assert!(stragglers(&lone, 2.0).is_empty());
    }

    #[test]
    fn utilization_gaps_are_the_complement_of_busy_time() {
        let trace = trace_of(vec![
            task(1, 1, 5, 20, 10),
            task(1, 2, 10, 30, 15),
            task(1, 3, 50, 60, 8),
        ]);
        let gaps = utilization_gaps(&trace);
        assert_eq!(gaps, vec![(0, 5_000), (30_000, 50_000)]);
    }

    #[test]
    fn chrome_export_nests_spans_inside_task_bounds() {
        let trace = trace_of(vec![task(1, 1, 2, 30, 25), task(1, 2, 2, 45, 40)]);
        let doc = chrome_trace(&trace);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // Per task: umbrella finish bound, keyed (pid, tid).
        let mut bounds: BTreeMap<(usize, usize), usize> = BTreeMap::new();
        for e in events {
            if e.get("name").unwrap().as_str() == Some("process_name") {
                continue;
            }
            let pid = e.get("pid").unwrap().as_usize().unwrap();
            let tid = e.get("tid").unwrap().as_usize().unwrap();
            if e.get("ph").unwrap().as_str() == Some("M") {
                continue;
            }
            let ts = e.get("ts").unwrap().as_usize().unwrap();
            let dur = e.get("dur").unwrap().as_usize().unwrap();
            let name = e.get("name").unwrap().as_str().unwrap();
            if name.starts_with("task ") {
                bounds.insert((pid, tid), ts + dur);
                assert_eq!(ts, 0);
            } else {
                let end = bounds
                    .get(&(pid, tid))
                    .expect("umbrella precedes phases");
                assert!(ts + dur <= *end, "{name} escapes its task");
                // Attribution rides every span.
                let args = e.get("args").unwrap();
                assert_eq!(
                    args.get("task").unwrap().as_usize().unwrap(),
                    tid
                );
                assert!(args.get("attempt").is_some());
                assert!(args.get("worker").is_some());
                assert!(args.get("items").is_some());
            }
        }
        assert_eq!(bounds.len(), 2);
        // The export is valid JSON end to end.
        let text = doc.to_string_compact();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn trace_json_dump_roundtrips_structurally() {
        let trace = trace_of(vec![task(1, 1, 2, 30, 25)]);
        let doc = trace_json(&trace);
        assert_eq!(doc.get("v").unwrap().as_usize(), Some(1));
        assert_eq!(
            doc.get("makespan_us").unwrap().as_usize(),
            Some(30_000)
        );
        let spans = doc
            .get("jobs")
            .and_then(|j| j.get("1"))
            .and_then(|j| j.get("tasks"))
            .and_then(|t| t.get("1"))
            .and_then(|t| t.get("spans"))
            .and_then(|s| s.as_arr())
            .unwrap();
        assert!(!spans.is_empty());
        assert!(Json::parse(&doc.to_string_compact()).is_ok());
    }

    #[test]
    fn live_collector_agrees_with_replay_assembly() {
        use crate::telemetry::EventBus;
        use std::sync::Arc;

        let t1 = timing(2, 30, 1, 1, 25);
        let t2 = timing(2, 45, 1, 1, 40);
        // Live: fold events through a TraceCollector.
        let bus = EventBus::new();
        let collector = Arc::new(TraceCollector::new());
        bus.subscribe(collector.clone());
        bus.emit(Event::JobSubmitted {
            job: 1,
            name: "wordcount".into(),
            ntasks: 2,
        });
        for (id, t) in [(1usize, &t1), (2, &t2)] {
            bus.emit(Event::TaskDone {
                job: 1,
                task_id: id,
                worker: None,
                dispatch_wait: Duration::ZERO,
                startup: Duration::ZERO,
                compute: Duration::ZERO,
                retries: 0,
                dead_lettered: false,
                timing: Some(t.clone()),
            });
        }
        let live = collector.snapshot();

        // Offline: fold the same timings through a journal replay.
        let mut replay = Replay::default();
        replay.apply(crate::scheduler::journal::Record::JobSubmitted {
            job: 1,
            name: "wordcount".into(),
            ntasks: 2,
            task_ids: vec![1, 2],
        });
        for (idx, (id, t)) in [(1usize, t1), (2, t2)].into_iter().enumerate()
        {
            replay.apply(crate::scheduler::journal::Record::TaskDone {
                job: 1,
                idx,
                task_id: id,
                retries: 0,
                dead_lettered: false,
                timing: Some(t),
            });
        }
        let offline = Trace::from_replay(&replay);
        assert_eq!(live, offline);
    }

    #[test]
    fn report_renders_every_section() {
        let trace = trace_of(vec![
            task(1, 1, 2, 30, 25),
            task(1, 2, 2, 31, 26),
            task(1, 3, 40, 200, 155),
        ]);
        let r = render_trace_report(&trace);
        assert!(r.contains("critical path"), "{r}");
        assert!(r.contains("per-phase totals"), "{r}");
        assert!(r.contains("compute"), "{r}");
        assert!(r.contains("stragglers"), "{r}");
        assert!(r.contains("utilization gaps"), "{r}");
    }
}
