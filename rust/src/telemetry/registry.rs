//! Zero-dependency metrics registry: counters, gauges and fixed-bucket
//! latency histograms, keyed by `(name, sorted labels)`.
//!
//! The registry knows nothing about events — it is a passive store fed
//! by [`crate::telemetry::Collector`] (or anything else) and rendered
//! in two encodings:
//!
//! * Prometheus text exposition (`# TYPE` lines, `_bucket{le=...}` /
//!   `_sum` / `_count` histogram series) for scraping `/metrics`;
//! * [`crate::util::json::Json`] for `status.json` and `/status`.
//!
//! Both encodings are canonical (BTreeMap ordering) so tests can
//! compare strings.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::{obj, Json};

/// Upper bucket bounds (seconds) shared by all latency histograms:
/// exponential from 1ms to 30s, plus the implicit `+Inf` bucket.
/// Fixed bounds keep `record` allocation-free and make histograms from
/// different runs mergeable bucket-by-bucket.
pub const LATENCY_BOUNDS_SECS: [f64; 14] = [
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0,
];

/// A fixed-bucket histogram (Prometheus semantics: per-bucket counts
/// are non-cumulative internally, cumulative in the exposition).
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    bounds: Vec<f64>,
    /// One count per bound, plus the final `+Inf` overflow bucket.
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Histogram {
    /// A histogram over the given ascending upper bounds.
    pub fn new(bounds: &[f64]) -> Histogram {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]));
        Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
            count: 0,
        }
    }

    /// A histogram over [`LATENCY_BOUNDS_SECS`].
    pub fn latency() -> Histogram {
        Histogram::new(&LATENCY_BOUNDS_SECS)
    }

    /// Record one observation.
    pub fn record(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|b| v <= *b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all observations.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// The upper bounds (without the implicit `+Inf`).
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket (non-cumulative) counts; last entry is the `+Inf`
    /// overflow bucket.
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    /// Cumulative counts per bucket, Prometheus `_bucket` style; the
    /// last entry always equals [`Histogram::count`].
    pub fn cumulative(&self) -> Vec<u64> {
        let mut acc = 0u64;
        self.counts
            .iter()
            .map(|c| {
                acc += c;
                acc
            })
            .collect()
    }

    /// Estimate the `q`-quantile (q in [0, 1]) by linear interpolation
    /// within the containing bucket — the `histogram_quantile` rule.
    /// Returns `None` on an empty histogram.  Estimates are clamped to
    /// the containing bucket's bounds; observations past the last
    /// finite bound report that bound (the estimate cannot exceed what
    /// the buckets resolve).
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = q * self.count as f64;
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            let prev = cum;
            cum += c;
            if (cum as f64) >= rank && *c > 0 {
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                if i >= self.bounds.len() {
                    // +Inf bucket: no finite upper edge to interpolate
                    // toward; report the last finite bound.
                    return Some(*self.bounds.last().unwrap_or(&lo));
                }
                let hi = self.bounds[i];
                let frac = ((rank - prev as f64) / *c as f64).clamp(0.0, 1.0);
                return Some(lo + (hi - lo) * frac);
            }
        }
        Some(*self.bounds.last().unwrap_or(&0.0))
    }

    /// Canonical JSON summary: count/sum, p50/p95/p99 estimates, and
    /// cumulative buckets (`le: null` is the `+Inf` bucket).
    pub fn to_json(&self) -> Json {
        let mut buckets: Vec<Json> = Vec::with_capacity(self.counts.len());
        let cum = self.cumulative();
        for (i, c) in cum.iter().enumerate() {
            let le = match self.bounds.get(i) {
                Some(b) => Json::Num(*b),
                None => Json::Null, // +Inf
            };
            buckets.push(obj(vec![("le", le), ("count", Json::Num(*c as f64))]));
        }
        let q = |p: f64| match self.quantile(p) {
            Some(v) => Json::Num(v),
            None => Json::Null,
        };
        obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum", Json::Num(self.sum)),
            ("p50", q(0.50)),
            ("p95", q(0.95)),
            ("p99", q(0.99)),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

/// `(metric name, sorted label pairs)` — the identity of one series.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
struct SeriesKey {
    name: String,
    labels: Vec<(String, String)>,
}

fn key(name: &str, labels: &[(&str, &str)]) -> SeriesKey {
    let mut labels: Vec<(String, String)> = labels
        .iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect();
    labels.sort();
    SeriesKey {
        name: name.to_string(),
        labels,
    }
}

#[derive(Default)]
struct State {
    counters: BTreeMap<SeriesKey, u64>,
    gauges: BTreeMap<SeriesKey, f64>,
    histograms: BTreeMap<SeriesKey, Histogram>,
}

/// Thread-safe metric store.  All mutation goes through one short
/// mutex; readers snapshot under the same lock.
#[derive(Default)]
pub struct Registry {
    state: Mutex<State>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Add `by` to a counter series (created at zero on first touch).
    pub fn inc(&self, name: &str, labels: &[(&str, &str)], by: u64) {
        *self.lock().counters.entry(key(name, labels)).or_insert(0) += by;
    }

    /// Set a gauge series to `v`.
    pub fn set_gauge(&self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.lock().gauges.insert(key(name, labels), v);
    }

    /// Record `v` into a latency histogram series (created with
    /// [`LATENCY_BOUNDS_SECS`] on first touch).
    pub fn observe(&self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.lock()
            .histograms
            .entry(key(name, labels))
            .or_insert_with(Histogram::latency)
            .record(v);
    }

    /// Current value of a counter series (0 if never touched).
    pub fn counter_value(&self, name: &str, labels: &[(&str, &str)]) -> u64 {
        self.lock()
            .counters
            .get(&key(name, labels))
            .copied()
            .unwrap_or(0)
    }

    /// Sum of a counter across all label combinations.
    pub fn counter_total(&self, name: &str) -> u64 {
        self.lock()
            .counters
            .iter()
            .filter(|(k, _)| k.name == name)
            .map(|(_, v)| *v)
            .sum()
    }

    /// Current value of a gauge series, if set.
    pub fn gauge_value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        self.lock().gauges.get(&key(name, labels)).copied()
    }

    /// Clone of a histogram series, if it exists.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Option<Histogram> {
        self.lock().histograms.get(&key(name, labels)).cloned()
    }

    /// A merged clone of all histogram series sharing `name`
    /// (bucket-by-bucket sum across label combinations), if any exist.
    pub fn histogram_merged(&self, name: &str) -> Option<Histogram> {
        let state = self.lock();
        let mut merged: Option<Histogram> = None;
        for (k, h) in state.histograms.iter() {
            if k.name != name {
                continue;
            }
            match &mut merged {
                None => merged = Some(h.clone()),
                Some(m) => {
                    for (dst, src) in m.counts.iter_mut().zip(h.counts.iter()) {
                        *dst += src;
                    }
                    m.sum += h.sum;
                    m.count += h.count;
                }
            }
        }
        merged
    }

    /// Prometheus text exposition of every series, canonically ordered.
    pub fn render_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let state = self.lock();
        let mut out = String::new();
        let mut last_type: Option<(String, &str)> = None;
        let mut type_line = |out: &mut String, name: &str, kind: &str| {
            if last_type.as_ref().map(|(n, k)| (n.as_str(), *k)) != Some((name, kind)) {
                let _ = writeln!(out, "# TYPE {name} {kind}");
                last_type = Some((name.to_string(), kind));
            }
        };
        for (k, v) in state.counters.iter() {
            type_line(&mut out, &k.name, "counter");
            let _ = writeln!(out, "{}{} {}", k.name, render_labels(&k.labels, None), v);
        }
        for (k, v) in state.gauges.iter() {
            type_line(&mut out, &k.name, "gauge");
            let _ = writeln!(
                out,
                "{}{} {}",
                k.name,
                render_labels(&k.labels, None),
                fmt_f64(*v)
            );
        }
        for (k, h) in state.histograms.iter() {
            type_line(&mut out, &k.name, "histogram");
            let cum = h.cumulative();
            for (i, c) in cum.iter().enumerate() {
                let le = match h.bounds.get(i) {
                    Some(b) => fmt_f64(*b),
                    None => "+Inf".to_string(),
                };
                let _ = writeln!(
                    out,
                    "{}_bucket{} {}",
                    k.name,
                    render_labels(&k.labels, Some(&le)),
                    c
                );
            }
            let _ = writeln!(
                out,
                "{}_sum{} {}",
                k.name,
                render_labels(&k.labels, None),
                fmt_f64(h.sum)
            );
            let _ = writeln!(
                out,
                "{}_count{} {}",
                k.name,
                render_labels(&k.labels, None),
                h.count
            );
        }
        out
    }

    /// The whole registry as canonical JSON:
    /// `{"counters": [...], "gauges": [...], "histograms": [...]}`.
    pub fn to_json(&self) -> Json {
        let state = self.lock();
        let labels_json = |labels: &[(String, String)]| {
            Json::Obj(
                labels
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                    .collect(),
            )
        };
        let counters = state
            .counters
            .iter()
            .map(|(k, v)| {
                obj(vec![
                    ("name", Json::Str(k.name.clone())),
                    ("labels", labels_json(&k.labels)),
                    ("value", Json::Num(*v as f64)),
                ])
            })
            .collect();
        let gauges = state
            .gauges
            .iter()
            .map(|(k, v)| {
                obj(vec![
                    ("name", Json::Str(k.name.clone())),
                    ("labels", labels_json(&k.labels)),
                    ("value", Json::Num(*v)),
                ])
            })
            .collect();
        let histograms = state
            .histograms
            .iter()
            .map(|(k, h)| {
                let mut o = h.to_json();
                if let Json::Obj(map) = &mut o {
                    map.insert("name".to_string(), Json::Str(k.name.clone()));
                    map.insert("labels".to_string(), labels_json(&k.labels));
                }
                o
            })
            .collect();
        obj(vec![
            ("counters", Json::Arr(counters)),
            ("gauges", Json::Arr(gauges)),
            ("histograms", Json::Arr(histograms)),
        ])
    }
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let state = self.lock();
        f.debug_struct("Registry")
            .field("counters", &state.counters.len())
            .field("gauges", &state.gauges.len())
            .field("histograms", &state.histograms.len())
            .finish()
    }
}

/// `{a="x",b="y"}` with Prometheus escaping, empty string for no
/// labels; `le` (when given) is appended last like promtool renders.
fn render_labels(labels: &[(String, String)], le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    format!("{{{}}}", parts.join(","))
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Render without the `1e-3` exponent form promtool tolerates but
/// humans squint at; integral values drop the fraction.
fn fmt_f64(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let mut h = Histogram::new(&[0.1, 1.0, 10.0]);
        assert_eq!(h.quantile(0.5), None);
        for v in [0.05, 0.5, 0.5, 5.0] {
            h.record(v);
        }
        h.record(100.0); // +Inf bucket
        assert_eq!(h.count(), 5);
        assert_eq!(h.bucket_counts(), &[1, 2, 1, 1]);
        assert_eq!(h.cumulative(), vec![1, 3, 4, 5]);
        let p50 = h.quantile(0.5).unwrap();
        assert!((0.1..=1.0).contains(&p50), "p50={p50}");
        // Everything past the last finite bound reports that bound.
        assert_eq!(h.quantile(1.0), Some(10.0));
        assert!((h.sum() - 106.05).abs() < 1e-9);
    }

    #[test]
    fn registry_exposition_covers_all_kinds() {
        let r = Registry::new();
        r.inc("llmr_tasks_done_total", &[("worker", "w0"), ("job", "1")], 3);
        r.set_gauge("llmr_queue_depth", &[], 2.0);
        r.observe("llmr_task_compute_seconds", &[("worker", "w0")], 0.02);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE llmr_tasks_done_total counter"));
        assert!(text.contains("llmr_tasks_done_total{job=\"1\",worker=\"w0\"} 3"));
        assert!(text.contains("# TYPE llmr_queue_depth gauge"));
        assert!(text.contains("llmr_queue_depth 2"));
        assert!(text.contains("llmr_task_compute_seconds_bucket{worker=\"w0\",le=\"0.025\"} 1"));
        assert!(text.contains("llmr_task_compute_seconds_bucket{worker=\"w0\",le=\"+Inf\"} 1"));
        assert!(text.contains("llmr_task_compute_seconds_count{worker=\"w0\"} 1"));
        // JSON side round-trips through the parser.
        let parsed = crate::util::json::Json::parse(&r.to_json().to_string_compact()).unwrap();
        assert_eq!(parsed.get("counters").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(r.counter_total("llmr_tasks_done_total"), 3);
    }

    #[test]
    fn merged_histogram_sums_across_labels() {
        let r = Registry::new();
        r.observe("h", &[("worker", "a")], 0.002);
        r.observe("h", &[("worker", "b")], 0.002);
        let m = r.histogram_merged("h").unwrap();
        assert_eq!(m.count(), 2);
        assert!(r.histogram_merged("missing").is_none());
    }
}
