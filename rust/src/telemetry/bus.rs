//! The engine-shared event bus.
//!
//! One [`EventBus`] lives inside each engine (and can be created
//! standalone for engines that predate telemetry).  Emitters are the
//! scheduler's transition points; subscribers are folds like
//! [`crate::telemetry::Collector`].
//!
//! Design constraints (DESIGN.md §9):
//!
//! * **Lock-cheap on the dispatch path.**  `emit` with zero
//!   subscribers is one relaxed atomic load — engines emit
//!   unconditionally and pay nothing when nobody is watching.
//!   Call sites that would have to *build* an event (clone a worker
//!   name, format an error) guard on [`EventBus::active`] first.
//! * **Deterministic observed order.**  Fan-out happens synchronously
//!   under the subscriber lock, so every subscriber sees events in
//!   exactly `seq` order — the property `tests/properties.rs` pins.
//!   The flip side is a contract: subscribers must not block.  The
//!   built-in subscribers only touch their own short mutexes and hand
//!   file/socket IO to dedicated threads.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::event::{Event, Stamped};

/// Opaque handle returned by [`EventBus::subscribe`]; pass it back to
/// [`EventBus::unsubscribe`] so long-lived engines do not accumulate
/// dead subscribers across invocations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubscriptionId(u64);

/// A sink for stamped events.  Implementations must be cheap and
/// non-blocking: they run synchronously on the emitting thread, which
/// may hold engine locks.
pub trait Subscriber: Send + Sync {
    /// Observe one event.  Called in strict `seq` order.
    fn on_event(&self, ev: &Stamped);
}

/// Multi-subscriber fan-out point with monotonic stamping.
pub struct EventBus {
    origin: Instant,
    seq: AtomicU64,
    next_sub: AtomicU64,
    /// Mirrors `subs.len()` so `active()` never locks.
    nsubs: AtomicUsize,
    subs: Mutex<Vec<(u64, Arc<dyn Subscriber>)>>,
}

impl Default for EventBus {
    fn default() -> Self {
        Self::new()
    }
}

impl EventBus {
    /// A fresh bus; its creation instant is the origin all event
    /// timestamps offset from.
    pub fn new() -> Self {
        EventBus {
            origin: Instant::now(),
            seq: AtomicU64::new(0),
            next_sub: AtomicU64::new(0),
            nsubs: AtomicUsize::new(0),
            subs: Mutex::new(Vec::new()),
        }
    }

    /// The instant event offsets are measured from.
    pub fn origin(&self) -> Instant {
        self.origin
    }

    /// True when at least one subscriber is attached.  Emitters that
    /// would allocate to *construct* an event check this first; plain
    /// `emit` already no-ops for free without it.
    pub fn active(&self) -> bool {
        self.nsubs.load(Ordering::Relaxed) > 0
    }

    /// Attach a subscriber; it sees every event emitted from now on.
    pub fn subscribe(&self, sub: Arc<dyn Subscriber>) -> SubscriptionId {
        let id = self.next_sub.fetch_add(1, Ordering::Relaxed);
        let mut subs = self.subs.lock().unwrap_or_else(|p| p.into_inner());
        subs.push((id, sub));
        self.nsubs.store(subs.len(), Ordering::Relaxed);
        SubscriptionId(id)
    }

    /// Detach a subscriber.  Unknown ids are ignored (double
    /// unsubscribe is harmless).
    pub fn unsubscribe(&self, id: SubscriptionId) {
        let mut subs = self.subs.lock().unwrap_or_else(|p| p.into_inner());
        subs.retain(|(sid, _)| *sid != id.0);
        self.nsubs.store(subs.len(), Ordering::Relaxed);
    }

    /// Stamp and fan out one event.  Free when nobody subscribed.
    pub fn emit(&self, event: Event) {
        if self.nsubs.load(Ordering::Relaxed) == 0 {
            return;
        }
        let subs = self.subs.lock().unwrap_or_else(|p| p.into_inner());
        if subs.is_empty() {
            return;
        }
        // Stamp under the lock so observed order == seq order.
        let stamped = Stamped {
            seq: self.seq.fetch_add(1, Ordering::Relaxed),
            at: self.origin.elapsed(),
            event,
        };
        for (_, sub) in subs.iter() {
            sub.on_event(&stamped);
        }
    }
}

impl std::fmt::Debug for EventBus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EventBus")
            .field("seq", &self.seq.load(Ordering::Relaxed))
            .field("subscribers", &self.nsubs.load(Ordering::Relaxed))
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Rec(Mutex<Vec<Stamped>>);
    impl Subscriber for Rec {
        fn on_event(&self, ev: &Stamped) {
            self.0.lock().unwrap().push(ev.clone());
        }
    }

    #[test]
    fn fan_out_stamps_in_order_and_unsubscribe_stops_delivery() {
        let bus = EventBus::new();
        assert!(!bus.active());
        let rec = Arc::new(Rec(Mutex::new(Vec::new())));
        let id = bus.subscribe(rec.clone());
        assert!(bus.active());
        bus.emit(Event::QueueDepth { depth: 1 });
        bus.emit(Event::JobDone { job: 7 });
        bus.unsubscribe(id);
        assert!(!bus.active());
        bus.emit(Event::QueueDepth { depth: 0 });
        let got = rec.0.lock().unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].seq, 0);
        assert_eq!(got[1].seq, 1);
        assert!(got[0].at <= got[1].at);
        assert_eq!(got[1].event, Event::JobDone { job: 7 });
    }

    #[test]
    fn emit_without_subscribers_is_a_noop_and_consumes_no_seq() {
        let bus = EventBus::new();
        bus.emit(Event::QueueDepth { depth: 3 });
        let rec = Arc::new(Rec(Mutex::new(Vec::new())));
        bus.subscribe(rec.clone());
        bus.emit(Event::QueueDepth { depth: 4 });
        assert_eq!(rec.0.lock().unwrap()[0].seq, 0);
    }

    #[test]
    fn concurrent_emitters_never_duplicate_or_skip_seq() {
        let bus = Arc::new(EventBus::new());
        let rec = Arc::new(Rec(Mutex::new(Vec::new())));
        bus.subscribe(rec.clone());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let bus = bus.clone();
            handles.push(std::thread::spawn(move || {
                for d in 0..50 {
                    bus.emit(Event::QueueDepth { depth: d });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let got = rec.0.lock().unwrap();
        assert_eq!(got.len(), 200);
        for (i, ev) in got.iter().enumerate() {
            assert_eq!(ev.seq, i as u64);
        }
    }
}
