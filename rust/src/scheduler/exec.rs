//! Shared task execution: turns a [`TaskWork`] into measured phase timings.
//!
//! Used by the local engine (wall-clock) and by the simulator when it runs
//! in executing mode (real outputs, virtual queueing time).

use std::time::Duration;

use crate::apps::run_map_task;
use crate::error::Result;
use crate::options::AppType;
use crate::scheduler::TaskWork;

/// Measured execution of one task's payload.
#[derive(Debug, Clone, Default)]
pub struct ExecOutcome {
    pub startup: Duration,
    pub compute: Duration,
    pub launches: usize,
    pub items: usize,
}

/// Execute the payload right here, right now, and measure it.
pub fn execute(work: &TaskWork) -> Result<ExecOutcome> {
    match work {
        TaskWork::Map { app, pairs, mode } => {
            let (startup, compute, launches) =
                run_map_task(app.as_ref(), pairs, *mode)?;
            Ok(ExecOutcome {
                startup,
                compute,
                launches,
                items: pairs.len(),
            })
        }
        TaskWork::Reduce {
            app,
            input_dir,
            out_file,
        } => {
            let t0 = std::time::Instant::now();
            app.reduce(input_dir, out_file)?;
            Ok(ExecOutcome {
                startup: Duration::ZERO,
                compute: t0.elapsed(),
                launches: 1,
                items: 1,
            })
        }
        TaskWork::ReducePartial {
            app,
            files,
            out_file,
        } => {
            let t0 = std::time::Instant::now();
            app.reduce_partial(files, out_file)?;
            Ok(ExecOutcome {
                startup: Duration::ZERO,
                compute: t0.elapsed(),
                launches: 1,
                items: files.len(),
            })
        }
        TaskWork::Synthetic {
            startup,
            per_item,
            items,
            launches,
        } => {
            // Synthetic work really spins so wall-clock engines stay honest.
            let spin = |d: Duration| {
                let t = std::time::Instant::now();
                while t.elapsed() < d {
                    std::hint::spin_loop();
                }
            };
            let t0 = std::time::Instant::now();
            spin(*startup * (*launches as u32));
            let startup_spent = t0.elapsed();
            let t1 = std::time::Instant::now();
            spin(*per_item * (*items as u32));
            Ok(ExecOutcome {
                startup: startup_spent,
                compute: t1.elapsed(),
                launches: *launches,
                items: *items,
            })
        }
    }
}

/// Extract a human-readable message from a `catch_unwind` payload —
/// shared by every engine that runs app code on its own threads (local
/// workers, remote worker daemons): a payload panic must fail the job
/// with its message, not kill the executing thread.
pub(crate) fn panic_message(
    panic: Box<dyn std::any::Any + Send>,
) -> String {
    panic
        .downcast_ref::<&str>()
        .map(|s| (*s).to_string())
        .or_else(|| panic.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".into())
}

/// What the payload would cost on the virtual clock, without executing it.
/// Used by the simulator in pure-timing mode.
pub fn virtual_cost(work: &TaskWork) -> ExecOutcome {
    match work {
        TaskWork::Map { app, pairs, mode } => {
            let hint = app.cost_hint();
            let launches = match mode {
                AppType::Siso => pairs.len(),
                AppType::Mimo | AppType::Spmd => {
                    usize::from(!pairs.is_empty())
                }
            };
            ExecOutcome {
                startup: hint.startup * launches as u32,
                compute: hint.per_item * pairs.len() as u32,
                launches,
                items: pairs.len(),
            }
        }
        TaskWork::Reduce { .. } => ExecOutcome {
            startup: Duration::ZERO,
            compute: Duration::from_millis(1),
            launches: 1,
            items: 1,
        },
        TaskWork::ReducePartial { files, .. } => ExecOutcome {
            startup: Duration::ZERO,
            compute: Duration::from_millis(1),
            launches: 1,
            items: files.len(),
        },
        TaskWork::Synthetic {
            startup,
            per_item,
            items,
            launches,
        } => ExecOutcome {
            startup: *startup * (*launches as u32),
            compute: *per_item * (*items as u32),
            launches: *launches,
            items: *items,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_virtual_cost_arithmetic() {
        let w = TaskWork::Synthetic {
            startup: Duration::from_millis(100),
            per_item: Duration::from_millis(10),
            items: 8,
            launches: 8,
        };
        let c = virtual_cost(&w);
        assert_eq!(c.startup, Duration::from_millis(800));
        assert_eq!(c.compute, Duration::from_millis(80));
    }

    #[test]
    fn synthetic_execute_spins_about_right() {
        let w = TaskWork::Synthetic {
            startup: Duration::from_millis(2),
            per_item: Duration::from_millis(1),
            items: 3,
            launches: 1,
        };
        let out = execute(&w).unwrap();
        assert!(out.startup >= Duration::from_millis(2));
        assert!(out.compute >= Duration::from_millis(3));
        assert_eq!(out.launches, 1);
        assert_eq!(out.items, 3);
    }

    #[test]
    fn mimo_virtual_cost_single_launch() {
        use crate::apps::testutil::CountingApp;
        use std::sync::Arc;
        let pairs: Vec<_> = (0..10)
            .map(|i| {
                (
                    std::path::PathBuf::from(format!("in{i}")),
                    std::path::PathBuf::from(format!("out{i}")),
                )
            })
            .collect();
        let mk = |mode| TaskWork::Map {
            app: Arc::new(CountingApp::new()),
            pairs: pairs.clone(),
            mode,
        };
        let siso = virtual_cost(&mk(AppType::Siso));
        let mimo = virtual_cost(&mk(AppType::Mimo));
        assert_eq!(siso.launches, 10);
        assert_eq!(mimo.launches, 1);
        assert_eq!(siso.compute, mimo.compute);
        assert_eq!(siso.startup, mimo.startup * 10);
        // The ganged morph costs the same as MIMO on the virtual clock:
        // one launch, per-item compute.
        let spmd = virtual_cost(&mk(AppType::Spmd));
        assert_eq!(spmd.launches, 1);
        assert_eq!(spmd.startup, mimo.startup);
        assert_eq!(spmd.compute, mimo.compute);
    }
}
