//! The engine-shared job table: one dependency/completion state machine.
//!
//! [`crate::scheduler::local::LocalEngine`] and
//! [`crate::scheduler::remote::RemoteCoordinator`] schedule work against
//! very different substrates (an in-process thread pool vs. TCP-attached
//! worker daemons), but the *queueing semantics* — admission, whole-job
//! barriers ([`JobSpec::depends_on`]), task-granularity edges
//! ([`JobSpec::task_deps`]), failure cascade, zero-task degenerate jobs,
//! report assembly — must be identical, or the same pipeline would
//! behave differently per `--engine`.  [`JobTable`] is that shared state
//! machine, extracted from the local engine's dispatcher.  Callers hold
//! it behind their own mutex and own their own ready queue; the table
//! answers "which `(job, task)` pairs just became dispatchable".
//!
//! The table is wall-clock (`Instant`-stamped eligibility for
//! `dispatch_wait`); the virtual-time simulator keeps its own event loop.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::scheduler::journal::{
    DeadLetter, ErrorPolicy, Journal, OnError, Record,
};
use crate::scheduler::{
    JobId, JobReport, JobSpec, TaskReport, TaskSpec, TaskWork,
};
use crate::telemetry::{Event, EventBus};

/// Eligibility gate of one task.
#[derive(Debug, Clone)]
enum Gate {
    /// Ready to dispatch (and already on, or about to join, the queue).
    Open,
    /// Waiting for the whole dependency job (Fig 1 barrier).
    Job,
    /// Waiting for `n` specific upstream tasks (overlapped reduce).
    Tasks(usize),
}

/// Table-owned state of one submitted job.
struct Job {
    name: String,
    tasks: Arc<Vec<TaskSpec>>,
    /// Original task count — survives `shed()`, because late submits of
    /// dependents validate their task edges against it.
    ntasks: usize,
    submitted_at: Instant,
    gates: Vec<Gate>,
    /// When each task became dispatchable (for `dispatch_wait`).
    eligible_at: Vec<Option<Instant>>,
    /// Injected-failure attempts consumed so far, per task.
    attempts: Vec<usize>,
    /// Real execution-error retries consumed so far, per task (kept
    /// separate from `attempts` so error retries never perturb the
    /// deterministic injected-failure schedule).
    error_attempts: Vec<usize>,
    /// Tasks terminally errored (dead-lettered or skipped) — the
    /// numerator of the circuit breaker.
    errors: usize,
    reports: Vec<Option<TaskReport>>,
    done_tasks: Vec<bool>,
    /// Tasks not yet successfully completed.
    remaining: usize,
    /// Jobs whose whole-job barrier waits on this job.
    barrier_dependents: Vec<JobId>,
    /// task index here → dependent (job, task index) edges to release.
    task_dependents: HashMap<usize, Vec<(JobId, usize)>>,
    /// Whole-node allocation requested (`--exclusive`).  The local
    /// engine has no nodes (one slot is one slot); the remote engine
    /// gives such tasks a whole worker.
    exclusive: bool,
    /// Crash journal shared with every job of this invocation; `None`
    /// when journaling is off (benches, bare engine tests).
    journal: Option<Arc<Journal>>,
    /// Telemetry bus this job's transitions are published to — rides
    /// the exact same hook points as the journal (DESIGN.md §9).
    telemetry: Option<Arc<EventBus>>,
    /// Persist per-task span timings on done records (`--trace`,
    /// DESIGN.md §12).  The event bus always carries them.
    trace: bool,
    /// What a task's terminal execution error does to this job.
    policy: ErrorPolicy,
    /// Completed report or failure message; `Some` means the job is over.
    outcome: Option<Result<JobReport, String>>,
}

impl Job {
    /// Drop the per-task state once an outcome is set.  Waiters only
    /// ever clone the outcome, and every code path that touches the
    /// per-task vectors checks `outcome.is_none()` first — so after
    /// completion the task specs (which can hold thousands of input
    /// pairs) are dead weight a long-lived engine would otherwise retain
    /// forever.
    fn shed(&mut self) {
        self.tasks = Arc::new(Vec::new());
        self.gates = Vec::new();
        self.eligible_at = Vec::new();
        self.attempts = Vec::new();
        self.error_attempts = Vec::new();
        self.reports = Vec::new();
        self.done_tasks = Vec::new();
    }

    /// The job's bus, only when someone is listening — call sites that
    /// clone strings to *build* an event gate on this, so silent runs
    /// pay one atomic load per transition.
    fn bus(&self) -> Option<&Arc<EventBus>> {
        self.telemetry.as_ref().filter(|b| b.active())
    }
}

/// Borrowed view of one job's fate (see [`JobTable::outcome`]).
pub(crate) enum Outcome<'a> {
    /// Never admitted to this table.
    Unknown,
    /// Admitted, still running.
    Running,
    /// Completed successfully.
    Done(&'a JobReport),
    /// Failed (directly or via dependency cascade).
    Failed(&'a str),
}

/// Execution-time snapshot of one task, handed to whatever runs it.
pub(crate) struct TaskView {
    /// The job's task array (shared — workers index into it).
    pub tasks: Arc<Vec<TaskSpec>>,
    pub submitted_at: Instant,
    /// Injected-failure attempts already consumed.
    pub attempt: usize,
    /// When the task became dispatchable.
    pub eligible_at: Option<Instant>,
    /// Whole-node allocation (`JobSpec::exclusive`).
    pub exclusive: bool,
}

impl TaskView {
    /// Placement-affinity key of the task's input shard: a hash of the
    /// directory its first input file lives in, so tasks reading the
    /// same shard score toward the same worker (warm page cache /
    /// shared filesystem locality).  `None` for work without file
    /// inputs (reduce output fan-in hashes its input dir too; synthetic
    /// timing payloads have no locality to exploit).
    pub fn shard_key(&self, idx: usize) -> Option<u64> {
        let dir = match &self.tasks.get(idx)?.work {
            TaskWork::Map { pairs, .. } => {
                pairs.first().and_then(|(inp, _)| inp.parent())
            }
            TaskWork::Reduce { input_dir, .. } => Some(input_dir.as_path()),
            TaskWork::ReducePartial { files, .. } => {
                files.first().and_then(|f| f.parent())
            }
            TaskWork::Synthetic { .. } => None,
        }?;
        // FNV-1a over the path bytes: cheap, deterministic, and the
        // coordinator only ever compares keys for equality.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in dir.as_os_str().as_encoded_bytes() {
            h ^= u64::from(*b);
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        Some(h)
    }
}

/// The shared dependency/completion state machine (module docs).
pub(crate) struct JobTable {
    jobs: HashMap<JobId, Job>,
    /// Execution width reported in assembled [`JobReport`]s.
    slots: usize,
}

impl JobTable {
    pub fn new(slots: usize) -> Self {
        JobTable {
            jobs: HashMap::new(),
            slots,
        }
    }

    /// Update the reported execution width (the remote coordinator's
    /// width changes as workers attach and die).
    pub fn set_slots(&mut self, slots: usize) {
        self.slots = slots;
    }

    /// Task count of a job this table has admitted (survives completion).
    pub fn ntasks(&self, id: JobId) -> Option<usize> {
        self.jobs.get(&id).map(|j| j.ntasks)
    }

    /// The job's current fate.
    pub fn outcome(&self, id: JobId) -> Outcome<'_> {
        match self.jobs.get(&id).map(|j| &j.outcome) {
            None => Outcome::Unknown,
            Some(None) => Outcome::Running,
            Some(Some(Ok(r))) => Outcome::Done(r),
            Some(Some(Err(m))) => Outcome::Failed(m),
        }
    }

    /// Whether the job is admitted and still undecided.
    pub fn is_live(&self, id: JobId) -> bool {
        matches!(self.outcome(id), Outcome::Running)
    }

    /// Snapshot what executing task `idx` of `jid` needs; `None` when
    /// the job is over, the task already completed (a stale queue entry
    /// from a reassignment race must not re-execute), or unknown.
    pub fn view(&self, jid: JobId, idx: usize) -> Option<TaskView> {
        let job = self.jobs.get(&jid)?;
        // The bounds check also shields against hostile wire frames
        // naming task indices the job never had.
        if job.outcome.is_some() || idx >= job.ntasks || job.done_tasks[idx]
        {
            return None;
        }
        Some(TaskView {
            tasks: job.tasks.clone(),
            submitted_at: job.submitted_at,
            attempt: job.attempts[idx],
            eligible_at: job.eligible_at[idx],
            exclusive: job.exclusive,
        })
    }

    /// Record one consumed injected-failure attempt; `false` when the job
    /// is already over (caller drops the task instead of requeueing).
    pub fn bump_attempt(&mut self, jid: JobId, idx: usize) -> bool {
        match self.jobs.get_mut(&jid) {
            Some(job) if job.outcome.is_none() && idx < job.ntasks => {
                job.attempts[idx] += 1;
                if let Some(j) = &job.journal {
                    j.record(&Record::TaskRetry {
                        job: jid.0,
                        idx,
                        task_id: job.tasks[idx].task_id,
                        attempt: job.attempts[idx],
                    });
                }
                if let Some(bus) = job.bus() {
                    bus.emit(Event::TaskRetry {
                        job: jid.0,
                        task_id: job.tasks[idx].task_id,
                        attempt: job.attempts[idx],
                    });
                }
                true
            }
            _ => false,
        }
    }

    /// Journal that `(jid, idx)` was handed to a worker/slot.
    pub fn note_assigned(&self, jid: JobId, idx: usize, worker: Option<&str>) {
        let Some(job) = self.jobs.get(&jid) else { return };
        if job.outcome.is_some() || idx >= job.ntasks {
            return;
        }
        if let Some(j) = &job.journal {
            j.record(&Record::TaskAssigned {
                job: jid.0,
                idx,
                task_id: job.tasks[idx].task_id,
                worker: worker.map(str::to_string),
            });
        }
        if let Some(bus) = job.bus() {
            bus.emit(Event::TaskAssigned {
                job: jid.0,
                task_id: job.tasks[idx].task_id,
                worker: worker.map(str::to_string),
            });
        }
    }

    /// Journal that `(jid, idx)` was reclaimed from a dead worker.
    pub fn note_reassigned(&self, jid: JobId, idx: usize) {
        let Some(job) = self.jobs.get(&jid) else { return };
        if job.outcome.is_some() || idx >= job.ntasks {
            return;
        }
        if let Some(j) = &job.journal {
            j.record(&Record::TaskReassigned {
                job: jid.0,
                idx,
                task_id: job.tasks[idx].task_id,
            });
        }
        if let Some(bus) = job.bus() {
            bus.emit(Event::TaskReassigned {
                job: jid.0,
                task_id: job.tasks[idx].task_id,
            });
        }
    }

    fn empty_report(&self, jid: JobId, name: &str, at: Instant) -> JobReport {
        JobReport {
            job_id: jid.0,
            name: name.to_string(),
            makespan: at.elapsed(),
            slots: self.slots,
            replayed: 0,
            tasks: Vec::new(),
        }
    }

    /// Admit one job: resolve its dependency edges into per-task gates,
    /// register reverse edges on the upstream job, and return whatever is
    /// immediately dispatchable.  The spec must already have passed
    /// [`crate::scheduler::validate_submit`].
    pub fn admit(
        &mut self,
        jid: JobId,
        spec: JobSpec,
        submitted_at: Instant,
    ) -> Vec<(JobId, usize)> {
        let JobSpec {
            name,
            tasks,
            depends_on,
            task_deps,
            exclusive,
            journal,
            error_policy,
            telemetry,
            trace,
        } = spec;
        let n = tasks.len();
        if let Some(j) = &journal {
            j.record(&Record::JobSubmitted {
                job: jid.0,
                name: name.clone(),
                ntasks: n,
                task_ids: tasks.iter().map(|t| t.task_id).collect(),
            });
        }
        if let Some(bus) = telemetry.as_ref().filter(|b| b.active()) {
            bus.emit(Event::JobSubmitted {
                job: jid.0,
                name: name.clone(),
                ntasks: n,
            });
        }
        let mut job = Job {
            name,
            tasks: Arc::new(tasks),
            ntasks: n,
            submitted_at,
            gates: vec![Gate::Open; n],
            eligible_at: vec![None; n],
            attempts: vec![0; n],
            error_attempts: vec![0; n],
            errors: 0,
            reports: vec![None; n],
            done_tasks: vec![false; n],
            remaining: n,
            barrier_dependents: Vec::new(),
            task_dependents: HashMap::new(),
            exclusive,
            journal,
            telemetry,
            trace,
            policy: error_policy,
            outcome: None,
        };

        // Whether this job was registered to wait on the upstream's
        // whole-job completion signal (drives zero-task completion below).
        let mut barrier_registered = false;
        if let Some(dep) = depends_on {
            // Group this job's task edges by dependent index.
            let mut edges: HashMap<usize, Vec<usize>> = HashMap::new();
            for &(i, u) in &task_deps {
                edges.entry(i).or_default().push(u);
            }
            match self.jobs.get_mut(&dep) {
                Some(upstream) => match &upstream.outcome {
                    Some(Ok(_)) => {} // dependency satisfied: gates open
                    Some(Err(msg)) => {
                        let m =
                            format!("dependency job {dep} failed: {msg}");
                        if let Some(j) = &job.journal {
                            j.record(&Record::JobFailed {
                                job: jid.0,
                                msg: m.clone(),
                            });
                        }
                        if let Some(bus) = job.bus() {
                            bus.emit(Event::JobFailed {
                                job: jid.0,
                                msg: m.clone(),
                            });
                        }
                        job.outcome = Some(Err(m));
                        job.shed();
                        self.jobs.insert(jid, job);
                        return Vec::new();
                    }
                    None => {
                        for i in 0..n {
                            if let Some(ups) = edges.get(&i) {
                                let mut open_count = 0usize;
                                for &u in ups {
                                    if upstream.done_tasks[u] {
                                        continue;
                                    }
                                    upstream
                                        .task_dependents
                                        .entry(u)
                                        .or_default()
                                        .push((jid, i));
                                    open_count += 1;
                                }
                                if open_count > 0 {
                                    job.gates[i] = Gate::Tasks(open_count);
                                }
                            } else {
                                job.gates[i] = Gate::Job;
                            }
                        }
                        // Zero-task dependents and any Job-gated task wait
                        // for the upstream completion signal.
                        if n == 0
                            || job
                                .gates
                                .iter()
                                .any(|g| matches!(g, Gate::Job))
                        {
                            upstream.barrier_dependents.push(jid);
                            barrier_registered = true;
                        }
                    }
                },
                None => {
                    // Validated at submit; can only mean the dependency
                    // was itself dropped on an earlier admission failure.
                    let m =
                        format!("dependency job {dep} was never admitted");
                    if let Some(j) = &job.journal {
                        j.record(&Record::JobFailed {
                            job: jid.0,
                            msg: m.clone(),
                        });
                    }
                    if let Some(bus) = job.bus() {
                        bus.emit(Event::JobFailed {
                            job: jid.0,
                            msg: m.clone(),
                        });
                    }
                    job.outcome = Some(Err(m));
                    job.shed();
                    self.jobs.insert(jid, job);
                    return Vec::new();
                }
            }
        }

        // A zero-task job completes at admission only when it is not
        // barriered on a still-running upstream (barrier release
        // completes it otherwise, once the upstream lands).
        if n == 0 && !barrier_registered {
            if let Some(j) = &job.journal {
                j.record(&Record::JobDone { job: jid.0 });
            }
            if let Some(bus) = job.bus() {
                bus.emit(Event::JobDone { job: jid.0 });
            }
            job.outcome =
                Some(Ok(self.empty_report(jid, &job.name, submitted_at)));
        }
        let now = Instant::now();
        let mut ready = Vec::new();
        for i in 0..n {
            if matches!(job.gates[i], Gate::Open) {
                job.eligible_at[i] = Some(now);
                ready.push((jid, i));
            }
        }
        self.jobs.insert(jid, job);
        ready
    }

    /// Record a successful task: release task-granularity dependents,
    /// complete the job when its last task lands, and open downstream
    /// whole-job barriers.  Returns every `(job, task)` pair that became
    /// dispatchable.
    pub fn on_task_done(
        &mut self,
        jid: JobId,
        idx: usize,
        report: TaskReport,
    ) -> Vec<(JobId, usize)> {
        let slots = self.slots;
        let (released, completed) = {
            let Some(job) = self.jobs.get_mut(&jid) else {
                return Vec::new();
            };
            if job.outcome.is_some()
                || idx >= job.ntasks
                || job.done_tasks[idx]
            {
                // Job over, hostile index, or stale duplicate.
                return Vec::new();
            }
            // One µs decomposition feeds both sinks, so an offline
            // journal replay and a live event fold build identical
            // traces.  `--trace=false` trims the journal record only.
            let timing = crate::scheduler::TaskTiming::from_report(&report);
            if let Some(j) = &job.journal {
                j.record(&Record::TaskDone {
                    job: jid.0,
                    idx,
                    task_id: report.task_id,
                    retries: report.retries,
                    dead_lettered: report.dead_lettered,
                    timing: job.trace.then(|| timing.clone()),
                });
            }
            if let Some(bus) = job.bus() {
                bus.emit(Event::TaskDone {
                    job: jid.0,
                    task_id: report.task_id,
                    worker: report.worker.clone(),
                    dispatch_wait: report.dispatch_wait,
                    startup: report.startup,
                    compute: report.compute,
                    retries: report.retries,
                    dead_lettered: report.dead_lettered,
                    timing: Some(timing),
                });
            }
            job.done_tasks[idx] = true;
            job.reports[idx] = Some(report);
            job.remaining -= 1;
            let released =
                job.task_dependents.remove(&idx).unwrap_or_default();
            let completed = job.remaining == 0;
            complete_if_last(job, jid, completed, slots);
            (released, completed)
        };

        // Open task-granularity gates on dependents (the overlapped path).
        let now = Instant::now();
        let mut ready = Vec::new();
        for (dj, di) in released {
            if let Some(dep_job) = self.jobs.get_mut(&dj) {
                if dep_job.outcome.is_some() {
                    continue;
                }
                if let Gate::Tasks(remaining) = &mut dep_job.gates[di] {
                    *remaining -= 1;
                    if *remaining == 0 {
                        dep_job.gates[di] = Gate::Open;
                        dep_job.eligible_at[di] = Some(now);
                        ready.push((dj, di));
                    }
                }
            }
        }

        if completed {
            self.open_barriers(jid, &mut ready);
        }
        ready
    }

    /// Open whole-job barriers downstream of `jid`, transitively
    /// completing degenerate zero-task dependents; extends `ready` with
    /// barrier-released tasks.
    fn open_barriers(&mut self, jid: JobId, ready: &mut Vec<(JobId, usize)>) {
        let mut done_stack = vec![jid];
        while let Some(id) = done_stack.pop() {
            let dependents = self
                .jobs
                .get_mut(&id)
                .map(|j| std::mem::take(&mut j.barrier_dependents))
                .unwrap_or_default();
            for dj in dependents {
                let mut newly_done = false;
                let slots = self.slots;
                if let Some(d) = self.jobs.get_mut(&dj) {
                    if d.outcome.is_some() {
                        continue;
                    }
                    let now = Instant::now();
                    for di in 0..d.gates.len() {
                        if matches!(d.gates[di], Gate::Job) {
                            d.gates[di] = Gate::Open;
                            d.eligible_at[di] = Some(now);
                            ready.push((dj, di));
                        }
                    }
                    if d.ntasks == 0 {
                        if let Some(j) = &d.journal {
                            j.record(&Record::JobDone { job: dj.0 });
                        }
                        if let Some(bus) = d.bus() {
                            bus.emit(Event::JobDone { job: dj.0 });
                        }
                        d.outcome = Some(Ok(JobReport {
                            job_id: dj.0,
                            name: d.name.clone(),
                            makespan: d.submitted_at.elapsed(),
                            slots,
                            replayed: 0,
                            tasks: Vec::new(),
                        }));
                        d.shed();
                        newly_done = true;
                    }
                }
                if newly_done {
                    done_stack.push(dj);
                }
            }
        }
    }

    /// Jobs admitted but not yet decided (the remote coordinator fails
    /// them all when the whole worker fleet is lost).
    pub fn live_jobs(&self) -> Vec<JobId> {
        self.jobs
            .iter()
            .filter(|(_, j)| j.outcome.is_none())
            .map(|(id, _)| *id)
            .collect()
    }

    /// Fail `jid` and cascade the failure through every dependent job.
    pub fn fail_job(&mut self, jid: JobId, msg: String) {
        let mut stack = vec![(jid, msg)];
        while let Some((id, m)) = stack.pop() {
            let dependents: Vec<JobId> = {
                let Some(job) = self.jobs.get_mut(&id) else { continue };
                if job.outcome.is_some() {
                    continue;
                }
                if let Some(j) = &job.journal {
                    j.record(&Record::JobFailed {
                        job: id.0,
                        msg: m.clone(),
                    });
                }
                if let Some(bus) = job.bus() {
                    bus.emit(Event::JobFailed {
                        job: id.0,
                        msg: m.clone(),
                    });
                }
                job.outcome = Some(Err(m.clone()));
                job.shed();
                let mut deps: Vec<JobId> =
                    std::mem::take(&mut job.barrier_dependents);
                for (_, edges) in std::mem::take(&mut job.task_dependents) {
                    deps.extend(edges.into_iter().map(|(dj, _)| dj));
                }
                deps.sort_unstable();
                deps.dedup();
                deps
            };
            for dj in dependents {
                stack.push((dj, format!("dependency job {id} failed: {m}")));
            }
        }
    }

    /// Apply the job's [`ErrorPolicy`] to a task's terminal execution
    /// error.  This sits on the engine-shared transition path — both the
    /// local dispatcher and the remote coordinator route real task
    /// errors here — so `--on-error` semantics cannot diverge per
    /// `--engine`.  Distinct from `bump_attempt`, which tracks
    /// *injected* failures: error retries consume their own budget and
    /// never perturb the deterministic injection schedule.
    pub fn on_task_error(
        &mut self,
        jid: JobId,
        idx: usize,
        msg: &str,
        worker: Option<&str>,
    ) -> ErrorAction {
        // Decide under the job borrow; fail/complete after it ends.
        enum Verdict {
            Fail(String),
            Requeue,
            Complete(TaskReport),
        }
        let verdict = {
            let Some(job) = self.jobs.get_mut(&jid) else {
                return ErrorAction::Ignore;
            };
            if job.outcome.is_some()
                || idx >= job.ntasks
                || job.done_tasks[idx]
            {
                return ErrorAction::Ignore;
            }
            let task_id = job.tasks[idx].task_id;
            if let Some(j) = &job.journal {
                j.record(&Record::TaskFailed {
                    job: jid.0,
                    idx,
                    task_id,
                    msg: msg.to_string(),
                });
            }
            if let Some(bus) = job.bus() {
                bus.emit(Event::TaskFailed {
                    job: jid.0,
                    task_id,
                    msg: msg.to_string(),
                });
            }
            let policy = job.policy;
            match policy.on_error {
                OnError::Stop => Verdict::Fail(msg.to_string()),
                OnError::Retry
                    if job.error_attempts[idx] < policy.max_retries =>
                {
                    job.error_attempts[idx] += 1;
                    if let Some(j) = &job.journal {
                        j.record(&Record::TaskRetry {
                            job: jid.0,
                            idx,
                            task_id,
                            attempt: job.error_attempts[idx],
                        });
                    }
                    if let Some(bus) = job.bus() {
                        bus.emit(Event::TaskRetry {
                            job: jid.0,
                            task_id,
                            attempt: job.error_attempts[idx],
                        });
                    }
                    Verdict::Requeue
                }
                terminal @ (OnError::Retry
                | OnError::Dlq
                | OnError::Skip) => {
                    job.errors += 1;
                    if policy.breaker_tripped(job.errors, job.ntasks) {
                        if let Some(j) = &job.journal {
                            j.record(&Record::BreakerTripped {
                                job: jid.0,
                                errors: job.errors,
                                ntasks: job.ntasks,
                                threshold: policy.failure_threshold,
                            });
                        }
                        if let Some(bus) = job.bus() {
                            bus.emit(Event::BreakerTripped {
                                job: jid.0,
                                errors: job.errors,
                                ntasks: job.ntasks,
                            });
                        }
                        Verdict::Fail(format!(
                            "circuit breaker tripped: {}/{} tasks \
                             errored (failure threshold {}); last \
                             error: {msg}",
                            job.errors,
                            job.ntasks,
                            policy.failure_threshold
                        ))
                    } else {
                        // Skip drops the work silently; dlq (and a
                        // retry budget running dry) records it first.
                        let dead_lettered = terminal != OnError::Skip;
                        if dead_lettered {
                            if let Some(j) = &job.journal {
                                j.dead_letter(&DeadLetter {
                                    job: jid.0,
                                    task_id,
                                    attempts: job.error_attempts[idx],
                                    worker: worker.map(str::to_string),
                                    error: DeadLetter::tail(msg),
                                    inputs: task_inputs(&job.tasks[idx]),
                                });
                            }
                        }
                        Verdict::Complete(TaskReport {
                            task_id,
                            retries: job.attempts[idx],
                            dead_lettered,
                            worker: worker.map(str::to_string),
                            ..Default::default()
                        })
                    }
                }
            }
        };
        match verdict {
            Verdict::Fail(m) => {
                self.fail_job(jid, m);
                ErrorAction::FailJob
            }
            Verdict::Requeue => ErrorAction::Requeue,
            Verdict::Complete(report) => {
                ErrorAction::Completed(self.on_task_done(jid, idx, report))
            }
        }
    }
}

/// Verdict of [`JobTable::on_task_error`]: what the engine does with the
/// errored `(job, task)` pair.
#[derive(Debug)]
pub(crate) enum ErrorAction {
    /// The job (and its dependents) failed — drop the task.
    FailJob,
    /// Retry budget left: put the task back on the ready queue.
    Requeue,
    /// The task was counted complete (dead-lettered or skipped); these
    /// downstream tasks just became dispatchable.
    Completed(Vec<(JobId, usize)>),
    /// Stale (job already over or task already done) — drop silently.
    Ignore,
}

/// Input paths of a task, for dead-letter attribution (what `dlq
/// reprocess` re-plans over).
fn task_inputs(task: &TaskSpec) -> Vec<String> {
    match &task.work {
        TaskWork::Map { pairs, .. } => pairs
            .iter()
            .map(|(input, _)| input.display().to_string())
            .collect(),
        TaskWork::Reduce { input_dir, .. } => {
            vec![input_dir.display().to_string()]
        }
        TaskWork::ReducePartial { files, .. } => {
            files.iter().map(|f| f.display().to_string()).collect()
        }
        TaskWork::Synthetic { .. } => Vec::new(),
    }
}

/// Completion arm of [`JobTable::on_task_done`]: assemble the report once
/// the last task landed.  Split out so the borrow of `job` ends before
/// the dependent-release pass.
fn complete_if_last(job: &mut Job, jid: JobId, completed: bool, slots: usize) {
    if !completed {
        return;
    }
    let tasks: Vec<TaskReport> = job
        .reports
        .iter_mut()
        .map(|r| r.take().expect("every task reported"))
        .collect();
    if let Some(j) = &job.journal {
        j.record(&Record::JobDone { job: jid.0 });
    }
    if let Some(bus) = job.bus() {
        bus.emit(Event::JobDone { job: jid.0 });
    }
    job.outcome = Some(Ok(JobReport {
        job_id: jid.0,
        name: job.name.clone(),
        makespan: job.submitted_at.elapsed(),
        slots,
        replayed: 0,
        tasks,
    }));
    job.shed();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::TaskWork;
    use std::time::Duration;

    fn synth_tasks(n: usize) -> Vec<TaskSpec> {
        (0..n)
            .map(|i| TaskSpec {
                task_id: i + 1,
                work: TaskWork::Synthetic {
                    startup: Duration::ZERO,
                    per_item: Duration::ZERO,
                    items: 1,
                    launches: 1,
                },
            })
            .collect()
    }

    fn done(table: &mut JobTable, jid: JobId, idx: usize) -> Vec<(JobId, usize)> {
        table.on_task_done(
            jid,
            idx,
            TaskReport {
                task_id: idx + 1,
                ..Default::default()
            },
        )
    }

    #[test]
    fn admit_opens_independent_tasks() {
        let mut t = JobTable::new(2);
        let ready =
            t.admit(JobId(1), JobSpec::new("a", synth_tasks(3)), Instant::now());
        assert_eq!(ready, vec![(JobId(1), 0), (JobId(1), 1), (JobId(1), 2)]);
        assert!(t.is_live(JobId(1)));
    }

    #[test]
    fn barrier_holds_until_upstream_completes() {
        let mut t = JobTable::new(1);
        t.admit(JobId(1), JobSpec::new("map", synth_tasks(2)), Instant::now());
        let ready = t.admit(
            JobId(2),
            JobSpec::new("red", synth_tasks(1)).after(JobId(1)),
            Instant::now(),
        );
        assert!(ready.is_empty(), "barriered task is not dispatchable");
        assert!(done(&mut t, JobId(1), 0).is_empty());
        let released = done(&mut t, JobId(1), 1);
        assert_eq!(released, vec![(JobId(2), 0)]);
        assert!(matches!(t.outcome(JobId(1)), Outcome::Done(_)));
    }

    #[test]
    fn task_edges_release_eagerly() {
        let mut t = JobTable::new(1);
        t.admit(JobId(1), JobSpec::new("map", synth_tasks(2)), Instant::now());
        let ready = t.admit(
            JobId(2),
            JobSpec::new("partial", synth_tasks(2))
                .after_tasks(JobId(1), vec![(0, 0), (1, 1)]),
            Instant::now(),
        );
        assert!(ready.is_empty());
        // Task 1 of the upstream releases dependent task 1 only.
        let released = done(&mut t, JobId(1), 1);
        assert_eq!(released, vec![(JobId(2), 1)]);
    }

    #[test]
    fn failure_cascades_to_dependents() {
        let mut t = JobTable::new(1);
        t.admit(JobId(1), JobSpec::new("map", synth_tasks(1)), Instant::now());
        t.admit(
            JobId(2),
            JobSpec::new("red", synth_tasks(1)).after(JobId(1)),
            Instant::now(),
        );
        t.fail_job(JobId(1), "boom".into());
        match t.outcome(JobId(2)) {
            Outcome::Failed(m) => assert!(m.contains("dependency")),
            _ => panic!("dependent must fail"),
        }
    }

    #[test]
    fn zero_task_job_completes_immediately_without_dependency() {
        let mut t = JobTable::new(4);
        t.admit(JobId(1), JobSpec::new("empty", vec![]), Instant::now());
        match t.outcome(JobId(1)) {
            Outcome::Done(r) => assert_eq!(r.slots, 4),
            _ => panic!("zero-task job completes at admission"),
        }
    }

    #[test]
    fn stale_duplicate_completion_is_ignored() {
        let mut t = JobTable::new(1);
        t.admit(JobId(1), JobSpec::new("a", synth_tasks(2)), Instant::now());
        assert!(done(&mut t, JobId(1), 0).is_empty());
        // Duplicate (a reassigned task that raced its first completion).
        assert!(done(&mut t, JobId(1), 0).is_empty());
        assert!(t.is_live(JobId(1)), "double count must not complete");
        done(&mut t, JobId(1), 1);
        assert!(matches!(t.outcome(JobId(1)), Outcome::Done(_)));
    }

    #[test]
    fn stop_policy_fails_the_job_on_first_error() {
        let mut t = JobTable::new(1);
        t.admit(JobId(1), JobSpec::new("a", synth_tasks(2)), Instant::now());
        match t.on_task_error(JobId(1), 0, "exit status 1", None) {
            ErrorAction::FailJob => {}
            other => panic!("{other:?}"),
        }
        assert!(matches!(t.outcome(JobId(1)), Outcome::Failed(_)));
        // Post-failure error reports are stale.
        assert!(matches!(
            t.on_task_error(JobId(1), 1, "late", None),
            ErrorAction::Ignore
        ));
    }

    #[test]
    fn retry_policy_requeues_then_dead_letters() {
        let mut t = JobTable::new(1);
        let policy = ErrorPolicy {
            on_error: OnError::Retry,
            max_retries: 2,
            ..ErrorPolicy::default()
        };
        t.admit(
            JobId(1),
            JobSpec::new("a", synth_tasks(1)).error_policy(policy),
            Instant::now(),
        );
        for _ in 0..2 {
            assert!(matches!(
                t.on_task_error(JobId(1), 0, "boom", None),
                ErrorAction::Requeue
            ));
        }
        // Budget exhausted: the task completes as a dead-letter
        // placeholder and the (single-task) job finishes.
        match t.on_task_error(JobId(1), 0, "boom", None) {
            ErrorAction::Completed(_) => {}
            other => panic!("{other:?}"),
        }
        match t.outcome(JobId(1)) {
            Outcome::Done(r) => assert_eq!(r.dead_lettered(), 1),
            _ => panic!("job completes without the dead task"),
        }
    }

    #[test]
    fn skip_policy_completes_without_dead_letter() {
        let mut t = JobTable::new(1);
        let policy = ErrorPolicy {
            on_error: OnError::Skip,
            ..ErrorPolicy::default()
        };
        t.admit(
            JobId(1),
            JobSpec::new("a", synth_tasks(1)).error_policy(policy),
            Instant::now(),
        );
        assert!(matches!(
            t.on_task_error(JobId(1), 0, "boom", None),
            ErrorAction::Completed(_)
        ));
        match t.outcome(JobId(1)) {
            Outcome::Done(r) => assert_eq!(r.dead_lettered(), 0),
            _ => panic!("skip completes the job"),
        }
    }

    #[test]
    fn breaker_trips_past_the_error_fraction() {
        let mut t = JobTable::new(1);
        let policy = ErrorPolicy {
            on_error: OnError::Dlq,
            failure_threshold: 0.25,
            ..ErrorPolicy::default()
        };
        t.admit(
            JobId(1),
            JobSpec::new("a", synth_tasks(4)).error_policy(policy),
            Instant::now(),
        );
        // 1/4 == threshold: not past it yet.
        assert!(matches!(
            t.on_task_error(JobId(1), 0, "boom", None),
            ErrorAction::Completed(_)
        ));
        // 2/4 > 0.25: tripped.
        assert!(matches!(
            t.on_task_error(JobId(1), 1, "boom", None),
            ErrorAction::FailJob
        ));
        match t.outcome(JobId(1)) {
            Outcome::Failed(m) => {
                assert!(m.contains("circuit breaker"), "{m}")
            }
            _ => panic!("breaker fails the job"),
        }
    }

    #[test]
    fn view_and_attempts() {
        let mut t = JobTable::new(1);
        t.admit(JobId(1), JobSpec::new("a", synth_tasks(1)), Instant::now());
        assert_eq!(t.view(JobId(1), 0).unwrap().attempt, 0);
        assert!(t.bump_attempt(JobId(1), 0));
        assert_eq!(t.view(JobId(1), 0).unwrap().attempt, 1);
        done(&mut t, JobId(1), 0);
        assert!(t.view(JobId(1), 0).is_none(), "no view of finished jobs");
        assert!(!t.bump_attempt(JobId(1), 0));
    }
}
