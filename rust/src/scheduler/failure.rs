//! Failure injection shared by both engines.
//!
//! The simulator has always injected task failures (nodes die on real
//! clusters); the local engine historically did not, so the two engines
//! disagreed on retry behaviour.  [`FailurePolicy`] is the single
//! decision rule both now consult: whether attempt `a` of task `t` fails
//! is a **pure function of (seed, task_id, attempt)** — independent of
//! dispatch interleaving, worker count, or which engine asks — so a job
//! replayed on [`crate::scheduler::local::LocalEngine`] and
//! [`crate::scheduler::sim::SimEngine`] with the same policy produces
//! identical per-task retry counts (DESIGN.md §4).

use crate::util::rng::Rng;

/// Deterministic per-attempt failure injection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailurePolicy {
    /// Probability that any single attempt fails (0 disables injection).
    pub failure_rate: f64,
    /// Retry budget: attempts at index `max_retries` and beyond are never
    /// failed by injection, so a task cannot fail *terminally* through the
    /// policy alone (injection models transient faults).
    pub max_retries: usize,
    /// Seed: identical seeds replay identical failure patterns.
    pub seed: u64,
}

impl Default for FailurePolicy {
    fn default() -> Self {
        // Mirrors `ClusterConfig::default()` so local and sim agree out
        // of the box (with rate 0, injection is off).
        FailurePolicy {
            failure_rate: 0.0,
            max_retries: 2,
            seed: 0x5EED,
        }
    }
}

impl FailurePolicy {
    /// Does attempt `attempt` (0-based) of task `task_id` fail?
    ///
    /// Attempts at or past `max_retries` never fail — retry budget
    /// exhausted means the fault injector steps aside, exactly like the
    /// simulator's historical `retries < max_retries` guard.
    pub fn should_fail(&self, task_id: usize, attempt: usize) -> bool {
        if self.failure_rate <= 0.0 || attempt >= self.max_retries {
            return false;
        }
        // Independent stream per (task, attempt): mix both into the seed
        // with distinct odd constants so neighbouring tasks/attempts do
        // not correlate.
        let mut rng = Rng::new(
            self.seed
                ^ (task_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (attempt as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
        );
        rng.next_f64() < self.failure_rate
    }

    /// Retries a task with this id consumes before its first success.
    pub fn expected_retries(&self, task_id: usize) -> usize {
        (0usize..)
            .take_while(|&a| self.should_fail(task_id, a))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_zero_never_fails() {
        let p = FailurePolicy::default();
        for t in 0..100 {
            assert!(!p.should_fail(t, 0));
        }
    }

    #[test]
    fn rate_one_fails_until_budget_exhausted() {
        let p = FailurePolicy {
            failure_rate: 1.0,
            max_retries: 3,
            seed: 1,
        };
        for t in 1..10 {
            assert!(p.should_fail(t, 0));
            assert!(p.should_fail(t, 1));
            assert!(p.should_fail(t, 2));
            // The attempt after the last retry always succeeds.
            assert!(!p.should_fail(t, 3));
            assert_eq!(p.expected_retries(t), 3);
        }
    }

    #[test]
    fn decision_is_deterministic_and_seed_sensitive() {
        let a = FailurePolicy {
            failure_rate: 0.5,
            max_retries: 8,
            seed: 11,
        };
        let b = FailurePolicy { seed: 12, ..a };
        let pattern = |p: &FailurePolicy| -> Vec<bool> {
            (1..64).map(|t| p.should_fail(t, 0)).collect()
        };
        assert_eq!(pattern(&a), pattern(&a), "pure function");
        assert_ne!(pattern(&a), pattern(&b), "seed changes the pattern");
    }

    #[test]
    fn observed_rate_near_requested() {
        let p = FailurePolicy {
            failure_rate: 0.3,
            max_retries: 1,
            seed: 99,
        };
        let fails = (1..=2000).filter(|&t| p.should_fail(t, 0)).count();
        let rate = fails as f64 / 2000.0;
        assert!((0.25..0.35).contains(&rate), "rate={rate}");
    }
}
