//! Scheduler substrate: the cluster LLMapReduce rides on.
//!
//! The paper runs on real Grid Engine / SLURM / LSF clusters.  This repo
//! substitutes (DESIGN.md §3):
//!
//! * [`dialect`] — faithful submission-script *dialects* for all three
//!   schedulers (what `.MAPRED.PID/submit.sh` looks like per scheduler);
//! * [`local`]  — an execution engine that really runs tasks on worker
//!   threads with an `np`-slot cap (real wall-clock measurements);
//! * [`sim`]    — a discrete-event cluster simulator with virtual time,
//!   nodes × slots, dispatch latency, dependencies and failure injection
//!   (scaling studies beyond this container's single core);
//! * [`remote`] — a distributed coordinator/worker engine: tasks ship
//!   over TCP to `llmapreduce worker` daemons, with heartbeat-based
//!   death detection and fault-tolerant reassignment (DESIGN.md §6);
//! * [`journal`] — the crash-safe job journal: every `JobTable`
//!   transition appends an fsync'd JSON line so `llmapreduce resume`
//!   can reconstruct in-flight state after coordinator death, plus the
//!   dead-letter queue and failure circuit breaker (DESIGN.md §8);
//! * [`cost`]   — the calibrated cost model bridging the engines.

pub mod cost;
pub mod dialect;
pub mod exec;
pub mod failure;
pub mod journal;
pub mod local;
pub mod remote;
pub mod sim;
pub(crate) mod table;

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use crate::apps::{MapApp, ReduceApp};
use crate::error::{Error, Result};
use crate::options::AppType;

/// Opaque job identifier, unique per engine instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// The work inside one array task.
#[derive(Clone)]
pub enum TaskWork {
    /// Run the map application over `pairs` of (input, output).
    ///
    /// * `AppType::Siso`: one application start-up **per pair** (the
    ///   paper's DEFAULT / BLOCK behaviour — repeated launches).
    /// * `AppType::Mimo`: one start-up for the whole task, then stream
    ///   the pairs.
    /// * `AppType::Spmd`: one start-up for the whole task; the
    ///   persistent instance consumes the entire batch through
    ///   [`crate::apps::MapInstance::run_batch`] (the ganged morph —
    ///   batches are packed by the planner under `--spmd`).
    Map {
        app: Arc<dyn MapApp>,
        pairs: Vec<(PathBuf, PathBuf)>,
        mode: AppType,
    },
    /// Run the reduce application over the map output directory.
    Reduce {
        app: Arc<dyn ReduceApp>,
        input_dir: PathBuf,
        out_file: PathBuf,
    },
    /// Overlapped-reduce stage: fold one mapper task's completed output
    /// `files` into the partial file `out_file` via
    /// [`crate::apps::ReduceApp::reduce_partial`].  Submitted with a
    /// task-granularity dependency ([`JobSpec::after_tasks`]) so it runs
    /// as soon as *its* mapper task finishes instead of barriering on the
    /// whole map array job (DESIGN.md §4).
    ReducePartial {
        app: Arc<dyn ReduceApp>,
        files: Vec<PathBuf>,
        out_file: PathBuf,
    },
    /// Timing-only payload for simulator studies where the real data does
    /// not exist (e.g. the 43,580-file Table II trace): `launches`
    /// start-ups plus `items` per-file compute units.
    Synthetic {
        startup: Duration,
        per_item: Duration,
        items: usize,
        launches: usize,
    },
}

impl std::fmt::Debug for TaskWork {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TaskWork::Map { pairs, mode, .. } => f
                .debug_struct("Map")
                .field("pairs", &pairs.len())
                .field("mode", mode)
                .finish(),
            TaskWork::Reduce { input_dir, .. } => f
                .debug_struct("Reduce")
                .field("input_dir", input_dir)
                .finish(),
            TaskWork::ReducePartial { files, out_file, .. } => f
                .debug_struct("ReducePartial")
                .field("files", &files.len())
                .field("out_file", out_file)
                .finish(),
            TaskWork::Synthetic {
                items, launches, ..
            } => f
                .debug_struct("Synthetic")
                .field("items", items)
                .field("launches", launches)
                .finish(),
        }
    }
}

impl TaskWork {
    /// Number of application launches this work implies.
    pub fn launches(&self) -> usize {
        match self {
            TaskWork::Map { pairs, mode, .. } => match mode {
                AppType::Siso => pairs.len(),
                AppType::Mimo | AppType::Spmd => {
                    usize::from(!pairs.is_empty())
                }
            },
            TaskWork::Reduce { .. } => 1,
            TaskWork::ReducePartial { .. } => 1,
            TaskWork::Synthetic { launches, .. } => *launches,
        }
    }

    /// Number of data items processed.
    pub fn items(&self) -> usize {
        match self {
            TaskWork::Map { pairs, .. } => pairs.len(),
            TaskWork::Reduce { .. } => 1,
            TaskWork::ReducePartial { files, .. } => files.len(),
            TaskWork::Synthetic { items, .. } => *items,
        }
    }
}

/// One array task (1-based ids, like `$SGE_TASK_ID`).
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub task_id: usize,
    pub work: TaskWork,
}

/// An array job: the unit LLMapReduce submits (Fig 1 step 2).
#[derive(Clone)]
pub struct JobSpec {
    /// Job name (`-N` in Fig 8) — conventionally the mapper script name.
    pub name: String,
    pub tasks: Vec<TaskSpec>,
    /// Job dependency (Fig 1 step 3: the reduce task "will wait until all
    /// the mapper tasks are completed by setting a job dependency").
    pub depends_on: Option<JobId>,
    /// Task-granularity dependency edges into the `depends_on` job's task
    /// array: `(dependent_idx, upstream_idx)` means the task at index
    /// `dependent_idx` of **this** job becomes eligible as soon as the
    /// task at index `upstream_idx` of the dependency job completes —
    /// the overlapped-reduce mechanism (DESIGN.md §4).  Indices are
    /// positions in the respective `tasks` vectors, **not** task ids.
    /// Tasks with no edge keep the whole-job barrier.  Empty (the
    /// default) means the classic Fig 1 whole-job barrier.  Engines may
    /// conservatively widen task edges back to the job barrier (the
    /// simulator does); execution stays correct, only overlap is lost.
    pub task_deps: Vec<(usize, usize)>,
    /// Whole-node allocation (`--exclusive`).
    pub exclusive: bool,
    /// Crash-safety journal to append this job's transitions to
    /// (DESIGN.md §8).  Shared by every job of one invocation; `None`
    /// runs unjournaled (the historic behaviour).
    pub journal: Option<Arc<journal::Journal>>,
    /// What a task's terminal execution error does to the job:
    /// stop (default), retry, dead-letter, or skip — plus the
    /// failure-rate circuit breaker.
    pub error_policy: journal::ErrorPolicy,
    /// Telemetry bus this job's transitions are published to
    /// (DESIGN.md §9) — the same hook points the journal rides.
    /// `None` runs silent; publishing to a bus nobody subscribed to
    /// costs one atomic load per transition.
    pub telemetry: Option<Arc<crate::telemetry::EventBus>>,
    /// Record per-task span timings in the journal so `llmapreduce
    /// trace` can rebuild the job's timeline offline (DESIGN.md §12).
    /// On by default; `--trace=false` trims the journal back to the
    /// PR-8 shape.  No effect when the job is unjournaled.
    pub trace: bool,
}

impl std::fmt::Debug for JobSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobSpec")
            .field("name", &self.name)
            .field("tasks", &self.tasks.len())
            .field("depends_on", &self.depends_on)
            .field("task_deps", &self.task_deps.len())
            .field("exclusive", &self.exclusive)
            .field("journaled", &self.journal.is_some())
            .field("telemetry", &self.telemetry.is_some())
            .field("trace", &self.trace)
            .field("error_policy", &self.error_policy)
            .finish()
    }
}

impl JobSpec {
    pub fn new(name: impl Into<String>, tasks: Vec<TaskSpec>) -> Self {
        JobSpec {
            name: name.into(),
            tasks,
            depends_on: None,
            task_deps: Vec::new(),
            exclusive: false,
            journal: None,
            error_policy: journal::ErrorPolicy::default(),
            telemetry: None,
            trace: true,
        }
    }

    pub fn after(mut self, dep: JobId) -> Self {
        self.depends_on = Some(dep);
        self
    }

    /// Depend on `dep` at task granularity: each `(dependent_idx,
    /// upstream_idx)` edge releases one task of this job the moment the
    /// named upstream task finishes (see [`JobSpec::task_deps`]).
    pub fn after_tasks(
        mut self,
        dep: JobId,
        edges: Vec<(usize, usize)>,
    ) -> Self {
        self.depends_on = Some(dep);
        self.task_deps = edges;
        self
    }

    pub fn exclusive(mut self, on: bool) -> Self {
        self.exclusive = on;
        self
    }

    /// Attach the invocation's crash-safety journal.
    pub fn journal(mut self, j: Arc<journal::Journal>) -> Self {
        self.journal = Some(j);
        self
    }

    /// Set the task-error policy (see [`journal::ErrorPolicy`]).
    pub fn error_policy(mut self, p: journal::ErrorPolicy) -> Self {
        self.error_policy = p;
        self
    }

    /// Publish this job's transitions to a telemetry bus
    /// (see [`crate::telemetry`]).
    pub fn telemetry(mut self, bus: Arc<crate::telemetry::EventBus>) -> Self {
        self.telemetry = Some(bus);
        self
    }

    /// Toggle per-task span timings in the journal (see
    /// [`JobSpec::trace`]; on by default).
    pub fn trace(mut self, on: bool) -> Self {
        self.trace = on;
        self
    }
}

/// Timing decomposition for one finished task.
#[derive(Debug, Clone, Default)]
pub struct TaskReport {
    pub task_id: usize,
    /// Time from eligibility to dispatch (queue wait + dispatch latency).
    pub dispatch_wait: Duration,
    /// Total application start-up time across all launches in the task.
    pub startup: Duration,
    /// Total per-item compute time.
    pub compute: Duration,
    /// Number of application launches performed.
    pub launches: usize,
    /// Number of data items processed.
    pub items: usize,
    /// Task start time, relative to job submission.
    pub started_at: Duration,
    /// Task end time, relative to job submission.
    pub finished_at: Duration,
    /// Retries consumed before success (failure injection).
    pub retries: usize,
    /// Name of the worker daemon that ran the successful attempt
    /// (`None` on in-process engines — local, sim).
    pub worker: Option<String>,
    /// Wire-shipping overhead on the remote engine: assignment round-trip
    /// minus the time the worker held the task (receive to execution end,
    /// or just the measured execution for pre-PR-10 workers that don't
    /// stamp receive times).  Covers serialization, network, and
    /// coordinator-side dispatch; deliberately excludes worker-queue wait
    /// so batch-shipped tasks aren't charged for sitting behind their
    /// batch siblings.  Zero on in-process engines.
    pub shipped: Duration,
    /// Outbound slice of `shipped` — dispatch-send to worker-receive —
    /// resolved via the worker's clock-offset estimate.  `None` when
    /// the worker didn't stamp its completion frame (pre-PR-9 workers,
    /// in-process engines); the tracing layer then splits `shipped`
    /// symmetrically.
    pub ship_out: Option<Duration>,
    /// Times the task was shipped to a worker that died (connection drop
    /// or heartbeat lapse) before completing it, forcing reassignment to
    /// a surviving worker.  Distinct from `retries` (injected failures).
    pub reassigned: usize,
    /// True when this is a dead-letter placeholder: the task's execution
    /// errored past its budget under `--on-error=dlq|retry` and was
    /// counted complete with its inputs recorded in `dlq.jsonl` instead
    /// of failing the job (DESIGN.md §8).
    pub dead_lettered: bool,
}

impl TaskReport {
    /// Overhead = everything that is not item compute.  This is the y-axis
    /// of Fig 18 ("computational overhead cost ... per array task").
    pub fn overhead(&self) -> Duration {
        self.dispatch_wait + self.startup
    }
}

/// Integer-µs span decomposition of one finished task, derived from its
/// [`TaskReport`].  This is the persistent form: written to the journal
/// (the `"t"` object on done records) and carried on
/// [`crate::telemetry::Event::TaskDone`], so live event folds and
/// offline journal replays feed [`crate::telemetry::trace`] identical
/// numbers (DESIGN.md §12).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct TaskTiming {
    /// Task start, µs after job submission.
    pub started_us: u64,
    /// Task end, µs after job submission.
    pub finished_us: u64,
    /// Eligibility→dispatch wait.
    pub dispatch_us: u64,
    /// Application start-up time.
    pub startup_us: u64,
    /// Per-item compute time.
    pub compute_us: u64,
    /// Wire-shipping overhead (remote engine; 0 in-process).
    pub shipped_us: u64,
    /// Outbound slice of `shipped_us`, when the worker stamped its
    /// completion frame (see [`TaskReport::ship_out`]).
    pub ship_out_us: Option<u64>,
    /// Data items processed.
    pub items: usize,
    /// Worker daemon that ran the successful attempt, if remote.
    pub worker: Option<String>,
}

impl TaskTiming {
    pub fn from_report(r: &TaskReport) -> TaskTiming {
        TaskTiming {
            started_us: r.started_at.as_micros() as u64,
            finished_us: r.finished_at.as_micros() as u64,
            dispatch_us: r.dispatch_wait.as_micros() as u64,
            startup_us: r.startup.as_micros() as u64,
            compute_us: r.compute.as_micros() as u64,
            shipped_us: r.shipped.as_micros() as u64,
            ship_out_us: r.ship_out.map(|d| d.as_micros() as u64),
            items: r.items,
            worker: r.worker.clone(),
        }
    }
}

/// A finished job.
#[derive(Debug, Clone, Default)]
pub struct JobReport {
    pub job_id: u64,
    pub name: String,
    pub tasks: Vec<TaskReport>,
    /// End-to-end: submission to last task completion.
    pub makespan: Duration,
    /// Execution width (cluster slots / worker threads) the job ran on.
    pub slots: usize,
    /// Tasks satisfied from the journal by a `resume` run instead of
    /// being re-executed (zero on a fresh submission).
    pub replayed: usize,
}

impl JobReport {
    pub fn total_startup(&self) -> Duration {
        self.tasks.iter().map(|t| t.startup).sum()
    }

    pub fn total_compute(&self) -> Duration {
        self.tasks.iter().map(|t| t.compute).sum()
    }

    pub fn total_dispatch(&self) -> Duration {
        self.tasks.iter().map(|t| t.dispatch_wait).sum()
    }

    pub fn total_launches(&self) -> usize {
        self.tasks.iter().map(|t| t.launches).sum()
    }

    pub fn total_items(&self) -> usize {
        self.tasks.iter().map(|t| t.items).sum()
    }

    /// Fraction of slot-time spent in task work (startup + compute) over
    /// the makespan — the cluster-utilization view real schedulers report.
    pub fn utilization(&self) -> f64 {
        if self.slots == 0 || self.makespan.is_zero() {
            return 0.0;
        }
        let busy = (self.total_startup() + self.total_compute()).as_secs_f64();
        (busy / (self.makespan.as_secs_f64() * self.slots as f64)).min(1.0)
    }

    /// How many tasks finished as dead-letter placeholders (their
    /// inputs await `dlq reprocess`).
    pub fn dead_lettered(&self) -> usize {
        self.tasks.iter().filter(|t| t.dead_lettered).count()
    }

    /// Mean overhead per array task — Fig 18's metric.
    pub fn mean_overhead_per_task(&self) -> Duration {
        if self.tasks.is_empty() {
            return Duration::ZERO;
        }
        let total: Duration = self.tasks.iter().map(|t| t.overhead()).sum();
        total / self.tasks.len() as u32
    }
}

/// Submit-time validation shared by the engines: the dependency (if
/// any) must be known — `dep_ntasks` returns its task count, or `None`
/// when it was never submitted — and every task-granularity edge must
/// be in range.  Both engines enforce this even where edges are widened
/// to a job barrier, so specs stay portable across `--engine=local|sim`.
pub(crate) fn validate_submit(
    spec: &JobSpec,
    dep_ntasks: impl FnOnce(JobId) -> Option<usize>,
) -> Result<()> {
    if let Some(dep) = spec.depends_on {
        let Some(dep_ntasks) = dep_ntasks(dep) else {
            return Err(Error::Scheduler(format!(
                "dependency {dep} was never submitted"
            )));
        };
        for &(i, u) in &spec.task_deps {
            if i >= spec.tasks.len() || u >= dep_ntasks {
                return Err(Error::Scheduler(format!(
                    "task dependency edge ({i}, {u}) out of range \
                     ({} dependent / {} upstream tasks)",
                    spec.tasks.len(),
                    dep_ntasks
                )));
            }
        }
    } else if !spec.task_deps.is_empty() {
        return Err(Error::Scheduler(
            "task_deps given without depends_on".into(),
        ));
    }
    Ok(())
}

/// An execution engine: where submitted jobs actually run.
///
/// Implementations: [`local::LocalEngine`] (threads, wall-clock) and
/// [`sim::SimEngine`] (discrete-event, virtual clock).
///
/// # Sharing contract
///
/// Every method takes `&self`: one engine serves any number of
/// concurrent submitters (the cluster-scheduler model — `qsub` never
/// needed exclusive access to Grid Engine).  Implementations use
/// interior mutability, and `Send + Sync` is part of the trait bound so
/// a `&dyn Engine` can be handed to as many
/// [`crate::mapreduce::Session`]s and threads as the caller likes.
/// Submissions made from one thread are observed in order (a dependent
/// may always name a dependency submitted earlier on the same thread);
/// there is no ordering between threads.
pub trait Engine: Send + Sync {
    /// Engine name for reports ("local", "sim").
    fn name(&self) -> &'static str;

    /// Submit an array job; returns immediately with its id.
    fn submit(&self, spec: JobSpec) -> Result<JobId>;

    /// Block until the job (and its dependency chain) finishes.
    fn wait(&self, id: JobId) -> Result<JobReport>;

    /// Non-blocking completion probe: `Ok(Some(report))` once the job
    /// finished, `Ok(None)` while it is still queued or running, and
    /// `Err` when the job failed (or was never submitted).  Virtual-time
    /// engines that execute lazily (the simulator) report `Ok(None)`
    /// until something calls [`Engine::wait`] — probing never forces a
    /// simulation, so deterministic replay is preserved.
    fn try_wait(&self, id: JobId) -> Result<Option<JobReport>>;

    /// True when this engine reports virtual (simulated) time rather than
    /// wall-clock.  The pipeline uses this to pick how end-to-end elapsed
    /// time is aggregated: wall engines report the span covered by their
    /// (possibly overlapping) jobs, virtual engines sum their job
    /// makespans (the simulator serializes chained jobs).
    fn virtual_time(&self) -> bool {
        false
    }

    /// Submit and wait in one call.
    fn run(&self, spec: JobSpec) -> Result<JobReport> {
        let id = self.submit(spec)?;
        self.wait(id)
    }

    /// The engine's shared telemetry bus, when it has one.  Executing
    /// engines (local, remote) create a bus at construction and emit
    /// engine-scoped events (queue depth, worker lifecycle) on it;
    /// sessions subscribe their collectors here and thread the same
    /// bus into [`JobSpec::telemetry`] so table transitions land on
    /// it too.  Virtual-time engines keep the default `None` (a
    /// session attaches a standalone bus instead — job transitions
    /// are still observed, engine-scoped gauges are not).
    fn event_bus(&self) -> Option<Arc<crate::telemetry::EventBus>> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_work_launch_accounting() {
        let pairs = vec![
            (PathBuf::from("a"), PathBuf::from("a.out")),
            (PathBuf::from("b"), PathBuf::from("b.out")),
            (PathBuf::from("c"), PathBuf::from("c.out")),
        ];
        let siso = TaskWork::Synthetic {
            startup: Duration::from_millis(1),
            per_item: Duration::from_millis(1),
            items: pairs.len(),
            launches: pairs.len(),
        };
        assert_eq!(siso.launches(), 3);
        assert_eq!(siso.items(), 3);
    }

    #[test]
    fn report_overhead_is_dispatch_plus_startup() {
        let t = TaskReport {
            dispatch_wait: Duration::from_millis(10),
            startup: Duration::from_millis(90),
            compute: Duration::from_millis(500),
            ..Default::default()
        };
        assert_eq!(t.overhead(), Duration::from_millis(100));
    }

    #[test]
    fn job_report_aggregates() {
        let mk = |s, c, d| TaskReport {
            startup: Duration::from_millis(s),
            compute: Duration::from_millis(c),
            dispatch_wait: Duration::from_millis(d),
            launches: 1,
            items: 2,
            ..Default::default()
        };
        let r = JobReport {
            tasks: vec![mk(10, 100, 5), mk(20, 200, 5)],
            ..Default::default()
        };
        assert_eq!(r.total_startup(), Duration::from_millis(30));
        assert_eq!(r.total_compute(), Duration::from_millis(300));
        assert_eq!(r.total_dispatch(), Duration::from_millis(10));
        assert_eq!(r.total_launches(), 2);
        assert_eq!(r.total_items(), 4);
        assert_eq!(r.mean_overhead_per_task(), Duration::from_millis(20));
    }

    #[test]
    fn utilization_math() {
        let r = JobReport {
            slots: 2,
            makespan: Duration::from_millis(100),
            tasks: vec![TaskReport {
                startup: Duration::from_millis(40),
                compute: Duration::from_millis(120),
                ..Default::default()
            }],
            ..Default::default()
        };
        // busy 160ms over 2x100ms slot-time = 0.8.
        assert!((r.utilization() - 0.8).abs() < 1e-9);
        let idle = JobReport::default();
        assert_eq!(idle.utilization(), 0.0);
    }

    #[test]
    fn jobspec_builder() {
        let spec = JobSpec::new("MatlabCmd.sh", vec![])
            .after(JobId(3))
            .exclusive(true);
        assert_eq!(spec.depends_on, Some(JobId(3)));
        assert!(spec.task_deps.is_empty(), "after() keeps the job barrier");
        assert!(spec.exclusive);
    }

    #[test]
    fn jobspec_task_granular_dependency() {
        let spec = JobSpec::new("partial-reduce", vec![])
            .after_tasks(JobId(7), vec![(0, 0), (1, 1)]);
        assert_eq!(spec.depends_on, Some(JobId(7)));
        assert_eq!(spec.task_deps, vec![(0, 0), (1, 1)]);
    }

    #[test]
    fn reduce_partial_work_accounting() {
        use crate::apps::ReduceApp;
        struct Nop;
        impl ReduceApp for Nop {
            fn name(&self) -> &str {
                "nop"
            }
            fn reduce(
                &self,
                _dir: &std::path::Path,
                _out: &std::path::Path,
            ) -> Result<()> {
                Ok(())
            }
        }
        let w = TaskWork::ReducePartial {
            app: Arc::new(Nop),
            files: vec![PathBuf::from("a"), PathBuf::from("b")],
            out_file: PathBuf::from("part_1"),
        };
        assert_eq!(w.launches(), 1);
        assert_eq!(w.items(), 2);
        assert!(format!("{w:?}").contains("ReducePartial"));
    }
}
