//! The local execution engine: really runs tasks on worker threads.
//!
//! This is the measurement substrate for Table I and for calibrating the
//! simulator's cost model: wall-clock, real PJRT compiles, real file I/O.
//! Concurrency is capped by `slots` (the analogue of the cluster's width —
//! on this container effectively 1 core, which is why the scaling *curves*
//! come from the simulator; see DESIGN.md §3).
//!
//! # Architecture (DESIGN.md §4)
//!
//! The engine is a true background dispatcher, mirroring how Fig 1's
//! launcher hands jobs to a resident cluster scheduler:
//!
//! * [`LocalEngine::submit`] validates the dependency edge, drops the job
//!   in the dispatcher's inbox and **returns before anything executes**;
//! * a *dispatcher thread* admits inbox jobs into the engine-shared
//!   `JobTable` (the dependency/completion state machine also driving
//!   [`crate::scheduler::remote::RemoteCoordinator`]), which tracks job-
//!   and task-granularity dependency edges ([`JobSpec::task_deps`]) and
//!   promotes eligible tasks from **any** submitted job onto one shared
//!   ready queue — independent jobs interleave under the single `slots`
//!   cap instead of running one-at-a-time;
//! * a persistent pool of `slots` *worker threads* executes ready tasks
//!   and reports completions back to the dispatcher, which unlocks
//!   dependent tasks the moment their upstream finishes (the overlapped
//!   map→reduce path) and completes jobs when their last task lands;
//! * [`LocalEngine::wait`] just blocks on the job's outcome.
//!
//! Failure injection follows the same [`FailurePolicy`] rule as
//! [`crate::scheduler::sim::SimEngine`] and the remote coordinator, so
//! per-task retry counts are identical across engines for the same
//! (seed, task id) — one behavioral contract, multiple clocks.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::scheduler::exec::execute;
use crate::scheduler::failure::FailurePolicy;
use crate::scheduler::table::{ErrorAction, JobTable, Outcome};
use crate::scheduler::{Engine, JobId, JobReport, JobSpec, TaskReport};
use crate::telemetry::EventBus;

/// Completion messages from workers to the dispatcher.
enum Event {
    TaskDone {
        job: JobId,
        idx: usize,
        report: TaskReport,
    },
    /// A real (non-injected) task error; the job's `ErrorPolicy`
    /// (applied on the engine-shared table path) decides its fate.
    TaskFailed {
        job: JobId,
        idx: usize,
        msg: String,
    },
}

/// Everything behind the shared mutex.
struct Core {
    /// Submitted jobs awaiting dispatcher admission.
    inbox: VecDeque<(JobId, JobSpec, Instant)>,
    /// Completion events awaiting dispatcher processing.
    events: VecDeque<Event>,
    /// Dispatchable (job, task index) pairs, shared by all jobs.
    ready: VecDeque<(JobId, usize)>,
    /// The engine-shared dependency/completion state machine.
    table: JobTable,
    shutdown: bool,
}

struct Inner {
    state: Mutex<Core>,
    /// Wakes workers when `ready` grows (or on shutdown).
    work_cv: Condvar,
    /// Wakes the dispatcher when `inbox`/`events` grow (or on shutdown).
    event_cv: Condvar,
    /// Wakes `wait()`ers when any job reaches an outcome.
    done_cv: Condvar,
    policy: FailurePolicy,
    slots: usize,
    /// Engine-scoped telemetry bus ([`Engine::event_bus`]): jobs this
    /// engine runs publish their transitions here, plus the engine's own
    /// queue-depth samples.  Free when nobody subscribed.
    bus: Arc<EventBus>,
}

impl Inner {
    /// Poison-tolerant lock: a panicking worker must not wedge `wait()`.
    fn lock(&self) -> MutexGuard<'_, Core> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Thread-pool engine with array-job, dependency and failure-injection
/// semantics.  All [`Engine`] methods take `&self`, so one engine can be
/// shared by any number of concurrent submitters (sessions, threads) —
/// the id counter is atomic and everything else already lives behind the
/// dispatcher's mutex.
pub struct LocalEngine {
    inner: Arc<Inner>,
    next_id: AtomicU64,
    workers: Vec<JoinHandle<()>>,
    dispatcher: Option<JoinHandle<()>>,
}

impl LocalEngine {
    /// `slots`: maximum concurrently-running tasks (the `--np` width).
    pub fn new(slots: usize) -> Self {
        Self::with_policy(slots, FailurePolicy::default())
    }

    /// An engine whose workers inject task failures per `policy`
    /// (matching [`crate::scheduler::sim::SimEngine`] retry counts).
    pub fn with_policy(slots: usize, policy: FailurePolicy) -> Self {
        let slots = slots.max(1);
        let inner = Arc::new(Inner {
            state: Mutex::new(Core {
                inbox: VecDeque::new(),
                events: VecDeque::new(),
                ready: VecDeque::new(),
                table: JobTable::new(slots),
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            event_cv: Condvar::new(),
            done_cv: Condvar::new(),
            policy,
            slots,
            bus: Arc::new(EventBus::new()),
        });
        let workers = (0..slots)
            .map(|_| {
                let inner = inner.clone();
                std::thread::spawn(move || worker_loop(&inner))
            })
            .collect();
        let dispatcher = {
            let inner = inner.clone();
            Some(std::thread::spawn(move || dispatcher_loop(&inner)))
        };
        LocalEngine {
            inner,
            next_id: AtomicU64::new(1),
            workers,
            dispatcher,
        }
    }

    pub fn slots(&self) -> usize {
        self.inner.slots
    }
}

impl Engine for LocalEngine {
    fn name(&self) -> &'static str {
        "local"
    }

    fn event_bus(&self) -> Option<Arc<EventBus>> {
        Some(self.inner.bus.clone())
    }

    fn submit(&self, spec: JobSpec) -> Result<JobId> {
        let mut core = self.inner.lock();
        crate::scheduler::validate_submit(&spec, |dep| {
            // Table `ntasks`, not live task vectors: a completed job has
            // shed its specs, but late dependents still validate.
            core.table.ntasks(dep).or_else(|| {
                core.inbox
                    .iter()
                    .find(|(id, _, _)| *id == dep)
                    .map(|(_, s, _)| s.tasks.len())
            })
        })?;
        // Allocated under the state lock, so an id never becomes visible
        // out of submission order on one thread.
        let id = JobId(self.next_id.fetch_add(1, Ordering::Relaxed));
        core.inbox.push_back((id, spec, Instant::now()));
        drop(core);
        self.inner.event_cv.notify_one();
        Ok(id)
    }

    fn wait(&self, id: JobId) -> Result<JobReport> {
        let mut core = self.inner.lock();
        loop {
            match core.table.outcome(id) {
                Outcome::Done(r) => return Ok(r.clone()),
                Outcome::Failed(msg) => {
                    return Err(Error::Scheduler(msg.to_string()))
                }
                Outcome::Running => {}
                Outcome::Unknown => {
                    if !core.inbox.iter().any(|(jid, _, _)| *jid == id) {
                        return Err(Error::Scheduler(format!(
                            "unknown job {id}"
                        )));
                    }
                }
            }
            core = self
                .inner
                .done_cv
                .wait(core)
                .unwrap_or_else(|e| e.into_inner());
        }
    }

    fn try_wait(&self, id: JobId) -> Result<Option<JobReport>> {
        let core = self.inner.lock();
        match core.table.outcome(id) {
            Outcome::Done(r) => Ok(Some(r.clone())),
            Outcome::Failed(msg) => Err(Error::Scheduler(msg.to_string())),
            Outcome::Running => Ok(None),
            Outcome::Unknown => {
                if core.inbox.iter().any(|(jid, _, _)| *jid == id) {
                    Ok(None) // submitted, not yet admitted
                } else {
                    Err(Error::Scheduler(format!("unknown job {id}")))
                }
            }
        }
    }
}

impl Drop for LocalEngine {
    fn drop(&mut self) {
        self.inner.lock().shutdown = true;
        self.inner.work_cv.notify_all();
        self.inner.event_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatcher
// ---------------------------------------------------------------------------

fn dispatcher_loop(inner: &Inner) {
    loop {
        let mut core = inner.lock();
        while !core.shutdown
            && core.inbox.is_empty()
            && core.events.is_empty()
        {
            core = inner
                .event_cv
                .wait(core)
                .unwrap_or_else(|e| e.into_inner());
        }
        if core.shutdown {
            return;
        }
        let ready_before = core.ready.len();
        while let Some((jid, spec, submitted_at)) = core.inbox.pop_front() {
            let ready = core.table.admit(jid, spec, submitted_at);
            core.ready.extend(ready);
        }
        while let Some(ev) = core.events.pop_front() {
            match ev {
                Event::TaskDone { job, idx, report } => {
                    let ready = core.table.on_task_done(job, idx, report);
                    core.ready.extend(ready);
                }
                Event::TaskFailed { job, idx, msg } => {
                    match core.table.on_task_error(job, idx, &msg, None) {
                        ErrorAction::Requeue => {
                            core.ready.push_back((job, idx));
                        }
                        ErrorAction::Completed(ready) => {
                            core.ready.extend(ready);
                        }
                        ErrorAction::FailJob | ErrorAction::Ignore => {}
                    }
                }
            }
        }
        // Workers cannot pop `ready` while the dispatcher holds the
        // lock, so a length delta across this round means new
        // dispatchable work.  (The worker retry path also pushes to
        // `ready`, but it wakes a worker itself.)  Waiters are few
        // (wait() callers); waking them every round is cheap, waking
        // all `slots` workers is not.
        let new_work = core.ready.len() > ready_before;
        let depth = core.ready.len();
        drop(core);
        inner
            .bus
            .emit(crate::telemetry::Event::QueueDepth { depth });
        if new_work {
            inner.work_cv.notify_all();
        }
        inner.done_cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Workers
// ---------------------------------------------------------------------------

fn worker_loop(inner: &Inner) {
    loop {
        // Claim a ready task (or exit on shutdown).
        let mut core = inner.lock();
        let (jid, idx) = loop {
            if core.shutdown {
                return;
            }
            if let Some(pair) = core.ready.pop_front() {
                break pair;
            }
            core = inner
                .work_cv
                .wait(core)
                .unwrap_or_else(|e| e.into_inner());
        };
        // Snapshot what execution needs; skip tasks of dead jobs.
        let Some(view) = core.table.view(jid, idx) else { continue };
        core.table.note_assigned(jid, idx, None);
        let dispatch_wait = view
            .eligible_at
            .map(|t| t.elapsed())
            .unwrap_or_default();
        let depth = core.ready.len();
        drop(core);
        inner
            .bus
            .emit(crate::telemetry::Event::QueueDepth { depth });

        let task = &view.tasks[idx];

        // Failure injection: the attempt "crashes at launch" — consumed a
        // retry, re-enters the queue, no side effects (the simulator burns
        // half the virtual duration instead; counts match, clocks differ).
        if inner.policy.should_fail(task.task_id, view.attempt) {
            let mut core = inner.lock();
            if core.table.bump_attempt(jid, idx) {
                core.ready.push_back((jid, idx));
                drop(core);
                inner.work_cv.notify_one();
            }
            continue;
        }

        let started_at = view.submitted_at.elapsed();
        // Payloads are app code: a panic must fail the job (like any
        // task error), not silently kill this worker and hang wait().
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| execute(&task.work)),
        )
        .unwrap_or_else(|panic| {
            let msg = crate::scheduler::exec::panic_message(panic);
            Err(Error::Scheduler(format!("payload panicked: {msg}")))
        });
        let finished_at = view.submitted_at.elapsed();

        let mut core = inner.lock();
        match result {
            Ok(out) => {
                core.events.push_back(Event::TaskDone {
                    job: jid,
                    idx,
                    report: TaskReport {
                        task_id: task.task_id,
                        dispatch_wait,
                        startup: out.startup,
                        compute: out.compute,
                        launches: out.launches,
                        items: out.items,
                        started_at,
                        finished_at,
                        retries: view.attempt,
                        ..Default::default()
                    },
                });
            }
            Err(e) => {
                core.events.push_back(Event::TaskFailed {
                    job: jid,
                    idx,
                    msg: format!("task {} failed: {e}", task.task_id),
                });
            }
        }
        drop(core);
        inner.event_cv.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::testutil::{ConcatReducer, CountingApp};
    use crate::apps::{MapApp, MapInstance};
    use crate::options::AppType;
    use crate::scheduler::sim::{ClusterConfig, SimEngine};
    use crate::scheduler::{TaskSpec, TaskWork};
    use std::collections::HashMap;
    use std::fs;
    use std::path::{Path, PathBuf};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::Duration;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-local-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn map_tasks(
        dir: &PathBuf,
        app: Arc<CountingApp>,
        nfiles: usize,
        ntasks: usize,
        mode: AppType,
    ) -> Vec<TaskSpec> {
        let pairs: Vec<_> = (0..nfiles)
            .map(|i| {
                let inp = dir.join(format!("f{i}.dat"));
                fs::write(&inp, format!("{i}\n")).unwrap();
                (inp, dir.join(format!("f{i}.dat.out")))
            })
            .collect();
        pairs
            .chunks(nfiles.div_ceil(ntasks))
            .enumerate()
            .map(|(t, chunk)| TaskSpec {
                task_id: t + 1,
                work: TaskWork::Map {
                    app: app.clone(),
                    pairs: chunk.to_vec(),
                    mode,
                },
            })
            .collect()
    }

    fn synth_tasks(n: usize, micros: u64) -> Vec<TaskSpec> {
        (0..n)
            .map(|i| TaskSpec {
                task_id: i + 1,
                work: TaskWork::Synthetic {
                    startup: Duration::from_micros(micros),
                    per_item: Duration::from_micros(micros),
                    items: 1,
                    launches: 1,
                },
            })
            .collect()
    }

    #[test]
    fn runs_all_tasks_and_reports() {
        let d = tmp("basic");
        let app = Arc::new(CountingApp::new());
        let tasks = map_tasks(&d, app.clone(), 8, 4, AppType::Siso);
        let eng = LocalEngine::new(2);
        let report = eng.run(JobSpec::new("job", tasks)).unwrap();
        assert_eq!(report.tasks.len(), 4);
        assert_eq!(report.total_items(), 8);
        assert_eq!(report.total_launches(), 8); // SISO: launch per file
        assert_eq!(app.processed.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn mimo_launches_once_per_task() {
        let d = tmp("mimo");
        let app = Arc::new(CountingApp::new());
        let tasks = map_tasks(&d, app.clone(), 8, 4, AppType::Mimo);
        let eng = LocalEngine::new(2);
        let report = eng.run(JobSpec::new("job", tasks)).unwrap();
        assert_eq!(report.total_launches(), 4); // MIMO: launch per task
        assert_eq!(app.startups.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn dependency_runs_before_dependent() {
        let d = tmp("dep");
        let app = Arc::new(CountingApp::new());
        let map_tasks = map_tasks(&d, app.clone(), 4, 2, AppType::Mimo);
        let outdir = d.clone();
        let eng = LocalEngine::new(2);
        let map_id = eng.submit(JobSpec::new("map", map_tasks)).unwrap();
        let red_id = eng
            .submit(
                JobSpec::new(
                    "reduce",
                    vec![TaskSpec {
                        task_id: 1,
                        work: TaskWork::Reduce {
                            app: Arc::new(ConcatReducer),
                            input_dir: outdir.clone(),
                            out_file: d.join("llmapreduce.out"),
                        },
                    }],
                )
                .after(map_id),
            )
            .unwrap();
        let red = eng.wait(red_id).unwrap();
        assert_eq!(red.tasks.len(), 1);
        // Reducer saw the mapper outputs: merged content contains markers.
        let merged = fs::read_to_string(d.join("llmapreduce.out")).unwrap();
        assert_eq!(merged.matches("#mapped").count(), 4);
        // Map job's report is retrievable afterwards.
        let map_report = eng.wait(map_id).unwrap();
        assert_eq!(map_report.total_items(), 4);
    }

    #[test]
    fn unknown_dependency_rejected() {
        let eng = LocalEngine::new(1);
        let err = eng
            .submit(JobSpec::new("x", vec![]).after(JobId(99)))
            .unwrap_err();
        assert!(err.to_string().contains("never submitted"));
    }

    #[test]
    fn task_failure_propagates() {
        let d = tmp("fail");
        let mut app = CountingApp::new();
        app.poison = Some("f2".into());
        let tasks = map_tasks(&d, Arc::new(app), 4, 2, AppType::Siso);
        let eng = LocalEngine::new(2);
        let err = eng.run(JobSpec::new("job", tasks)).unwrap_err();
        assert!(err.to_string().contains("poisoned"));
    }

    #[test]
    fn failed_dependency_cascades_to_dependents() {
        let d = tmp("cascade");
        let mut app = CountingApp::new();
        app.poison = Some("f0".into());
        let tasks = map_tasks(&d, Arc::new(app), 2, 1, AppType::Siso);
        let eng = LocalEngine::new(2);
        let map_id = eng.submit(JobSpec::new("map", tasks)).unwrap();
        let red_id = eng
            .submit(
                JobSpec::new(
                    "reduce",
                    vec![TaskSpec {
                        task_id: 1,
                        work: TaskWork::Reduce {
                            app: Arc::new(ConcatReducer),
                            input_dir: d.clone(),
                            out_file: d.join("out"),
                        },
                    }],
                )
                .after(map_id),
            )
            .unwrap();
        let err = eng.wait(red_id).unwrap_err().to_string();
        assert!(err.contains("dependency"), "{err}");
        assert!(err.contains("poisoned"), "{err}");
        assert!(eng.wait(map_id).is_err());
    }

    #[test]
    fn single_slot_serializes() {
        let d = tmp("serial");
        let app = Arc::new(CountingApp::new());
        let tasks = map_tasks(&d, app.clone(), 6, 6, AppType::Siso);
        let eng = LocalEngine::new(1);
        let report = eng.run(JobSpec::new("job", tasks)).unwrap();
        // With one slot, task intervals must not overlap.
        let mut intervals: Vec<(Duration, Duration)> = report
            .tasks
            .iter()
            .map(|t| (t.started_at, t.finished_at))
            .collect();
        intervals.sort();
        for w in intervals.windows(2) {
            assert!(w[0].1 <= w[1].0 + Duration::from_millis(5));
        }
    }

    #[test]
    fn wait_twice_returns_same_report() {
        let d = tmp("twice");
        let app = Arc::new(CountingApp::new());
        let tasks = map_tasks(&d, app, 2, 1, AppType::Mimo);
        let eng = LocalEngine::new(1);
        let id = eng.submit(JobSpec::new("job", tasks)).unwrap();
        let a = eng.wait(id).unwrap();
        let b = eng.wait(id).unwrap();
        assert_eq!(a.job_id, b.job_id);
        assert_eq!(a.tasks.len(), b.tasks.len());
    }

    // -- background-dispatcher behaviour ------------------------------------

    /// A mapper that records whether its peer job was *running at the same
    /// time*: it raises `mine`, then spins until it sees `other` (or a
    /// deadline).  Two such jobs can only both observe each other if the
    /// engine dispatches tasks from independent jobs concurrently.
    struct HandshakeApp {
        mine: Arc<AtomicBool>,
        other: Arc<AtomicBool>,
        saw_other: Arc<AtomicBool>,
    }

    struct HandshakeInstance {
        mine: Arc<AtomicBool>,
        other: Arc<AtomicBool>,
        saw_other: Arc<AtomicBool>,
    }

    impl MapApp for HandshakeApp {
        fn name(&self) -> &str {
            "handshake"
        }
        fn startup(&self) -> Result<Box<dyn MapInstance>> {
            Ok(Box::new(HandshakeInstance {
                mine: self.mine.clone(),
                other: self.other.clone(),
                saw_other: self.saw_other.clone(),
            }))
        }
    }

    impl MapInstance for HandshakeInstance {
        fn process(&mut self, _input: &Path, output: &Path) -> Result<()> {
            self.mine.store(true, Ordering::SeqCst);
            let deadline = Instant::now() + Duration::from_secs(10);
            while Instant::now() < deadline {
                if self.other.load(Ordering::SeqCst) {
                    self.saw_other.store(true, Ordering::SeqCst);
                    break;
                }
                std::thread::yield_now();
            }
            fs::write(output, "done")
                .map_err(|e| Error::io(output.to_path_buf(), e))
        }
    }

    #[test]
    fn independent_jobs_interleave_within_slot_cap() {
        let d = tmp("interleave");
        let flag_a = Arc::new(AtomicBool::new(false));
        let flag_b = Arc::new(AtomicBool::new(false));
        let saw_a = Arc::new(AtomicBool::new(false));
        let saw_b = Arc::new(AtomicBool::new(false));
        let mk = |tag: &str,
                  mine: &Arc<AtomicBool>,
                  other: &Arc<AtomicBool>,
                  saw: &Arc<AtomicBool>| {
            let inp = d.join(format!("{tag}.dat"));
            fs::write(&inp, "x").unwrap();
            let app: Arc<dyn MapApp> = Arc::new(HandshakeApp {
                mine: mine.clone(),
                other: other.clone(),
                saw_other: saw.clone(),
            });
            JobSpec::new(
                tag,
                vec![TaskSpec {
                    task_id: 1,
                    work: TaskWork::Map {
                        app,
                        pairs: vec![(
                            inp,
                            d.join(format!("{tag}.out")),
                        )],
                        mode: AppType::Siso,
                    },
                }],
            )
        };
        let eng = LocalEngine::new(2);
        let ja = eng.submit(mk("a", &flag_a, &flag_b, &saw_a)).unwrap();
        let jb = eng.submit(mk("b", &flag_b, &flag_a, &saw_b)).unwrap();
        eng.wait(ja).unwrap();
        eng.wait(jb).unwrap();
        assert!(
            saw_a.load(Ordering::SeqCst) && saw_b.load(Ordering::SeqCst),
            "two independent jobs must run concurrently under one slot cap"
        );
    }

    #[test]
    fn independent_jobs_share_one_slot_without_deadlock() {
        let eng = LocalEngine::new(1);
        let a = eng.submit(JobSpec::new("a", synth_tasks(2, 100))).unwrap();
        let b = eng.submit(JobSpec::new("b", synth_tasks(2, 100))).unwrap();
        assert_eq!(eng.wait(b).unwrap().tasks.len(), 2);
        assert_eq!(eng.wait(a).unwrap().tasks.len(), 2);
    }

    #[test]
    fn task_granular_dependency_releases_eagerly_and_correctly() {
        let d = tmp("taskdep");
        let app = Arc::new(CountingApp::new());
        let tasks = map_tasks(&d, app, 6, 3, AppType::Mimo);
        // Rebuild each map task's output list for the partial stage.
        let outputs: Vec<Vec<PathBuf>> = tasks
            .iter()
            .map(|t| match &t.work {
                TaskWork::Map { pairs, .. } => {
                    pairs.iter().map(|(_, o)| o.clone()).collect()
                }
                _ => unreachable!(),
            })
            .collect();
        let eng = LocalEngine::new(2);
        let map_id = eng.submit(JobSpec::new("map", tasks)).unwrap();
        let partial_tasks: Vec<TaskSpec> = outputs
            .iter()
            .enumerate()
            .map(|(i, files)| TaskSpec {
                task_id: i + 1,
                work: TaskWork::ReducePartial {
                    app: Arc::new(ConcatReducer),
                    files: files.clone(),
                    out_file: d.join(format!("part_{i}")),
                },
            })
            .collect();
        let edges: Vec<(usize, usize)> =
            (0..partial_tasks.len()).map(|i| (i, i)).collect();
        let pid = eng
            .submit(
                JobSpec::new("partial", partial_tasks)
                    .after_tasks(map_id, edges),
            )
            .unwrap();
        let partial = eng.wait(pid).unwrap();
        assert_eq!(partial.tasks.len(), 3);
        // Each partial saw exactly its upstream task's 2 outputs.
        for i in 0..3 {
            let text =
                fs::read_to_string(d.join(format!("part_{i}"))).unwrap();
            assert_eq!(
                text.matches("#mapped").count(),
                2,
                "partial {i} consumed its own mapper task's outputs"
            );
        }
    }

    #[test]
    fn panicking_payload_fails_job_instead_of_hanging() {
        struct PanicApp;
        struct PanicInstance;
        impl MapApp for PanicApp {
            fn name(&self) -> &str {
                "panic-app"
            }
            fn startup(&self) -> Result<Box<dyn MapInstance>> {
                Ok(Box::new(PanicInstance))
            }
        }
        impl MapInstance for PanicInstance {
            fn process(&mut self, _i: &Path, _o: &Path) -> Result<()> {
                panic!("boom in app code");
            }
        }
        let d = tmp("panic");
        let inp = d.join("x.dat");
        fs::write(&inp, "x").unwrap();
        let eng = LocalEngine::new(1);
        let err = eng
            .run(JobSpec::new(
                "p",
                vec![TaskSpec {
                    task_id: 1,
                    work: TaskWork::Map {
                        app: Arc::new(PanicApp),
                        pairs: vec![(inp, d.join("x.out"))],
                        mode: AppType::Siso,
                    },
                }],
            ))
            .unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
        // The worker survived the unwind: the engine still runs jobs.
        let ok = eng.run(JobSpec::new("ok", synth_tasks(2, 50))).unwrap();
        assert_eq!(ok.tasks.len(), 2);
    }

    #[test]
    fn zero_task_dependent_waits_for_upstream_outcome() {
        // A zero-task barrier job must inherit its upstream's fate, not
        // complete vacuously at admission.
        let d = tmp("zerodep");
        let mut app = CountingApp::new();
        app.poison = Some("f0".into());
        let tasks = map_tasks(&d, Arc::new(app), 2, 1, AppType::Siso);
        let eng = LocalEngine::new(1);
        let a = eng.submit(JobSpec::new("map", tasks)).unwrap();
        let b = eng.submit(JobSpec::new("barrier", vec![]).after(a)).unwrap();
        let err = eng.wait(b).unwrap_err().to_string();
        assert!(err.contains("dependency"), "{err}");
        // And with a healthy upstream it completes fine.
        let c = eng.submit(JobSpec::new("ok", synth_tasks(1, 10))).unwrap();
        let e = eng.submit(JobSpec::new("barrier2", vec![]).after(c)).unwrap();
        assert!(eng.wait(e).unwrap().tasks.is_empty());
    }

    #[test]
    fn task_dep_edge_out_of_range_rejected() {
        let eng = LocalEngine::new(1);
        let a = eng.submit(JobSpec::new("a", synth_tasks(2, 10))).unwrap();
        let err = eng
            .submit(
                JobSpec::new("b", synth_tasks(2, 10))
                    .after_tasks(a, vec![(0, 5)]),
            )
            .unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        let err = eng
            .submit(JobSpec::new("c", synth_tasks(1, 10)).after_tasks(
                a,
                vec![(3, 0)],
            ))
            .unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    fn injected_retries_follow_the_policy_exactly() {
        let policy = FailurePolicy {
            failure_rate: 0.6,
            max_retries: 4,
            seed: 42,
        };
        let eng = LocalEngine::with_policy(2, policy);
        let report =
            eng.run(JobSpec::new("flaky", synth_tasks(8, 50))).unwrap();
        assert_eq!(report.tasks.len(), 8);
        for t in &report.tasks {
            assert_eq!(
                t.retries,
                policy.expected_retries(t.task_id),
                "task {}",
                t.task_id
            );
        }
        let total: usize = report.tasks.iter().map(|t| t.retries).sum();
        assert!(total > 0, "rate 0.6 over 8 tasks must retry some");
    }

    #[test]
    fn retry_counts_match_sim_engine() {
        let (rate, max_retries, seed) = (0.5, 5, 9);
        let local = LocalEngine::with_policy(
            2,
            FailurePolicy {
                failure_rate: rate,
                max_retries,
                seed,
            },
        );
        let local_report = local
            .run(JobSpec::new("flaky", synth_tasks(8, 50)))
            .unwrap();
        let sim = SimEngine::new(ClusterConfig {
            failure_rate: rate,
            max_retries,
            seed,
            dispatch_latency: Duration::from_millis(1),
            ..ClusterConfig::with_width(2)
        });
        let sim_report =
            sim.run(JobSpec::new("flaky", synth_tasks(8, 50))).unwrap();
        let by_id = |r: &JobReport| -> HashMap<usize, usize> {
            r.tasks.iter().map(|t| (t.task_id, t.retries)).collect()
        };
        assert_eq!(
            by_id(&local_report),
            by_id(&sim_report),
            "one failure-injection contract across engines"
        );
    }
}
