//! The local execution engine: really runs tasks on worker threads.
//!
//! This is the measurement substrate for Table I and for calibrating the
//! simulator's cost model: wall-clock, real PJRT compiles, real file I/O.
//! Concurrency is capped by `slots` (the analogue of the cluster's width —
//! on this container effectively 1 core, which is why the scaling *curves*
//! come from the simulator; see DESIGN.md §3).

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::scheduler::exec::execute;
use crate::scheduler::{Engine, JobId, JobReport, JobSpec, TaskReport};

/// Thread-pool engine with array-job and dependency semantics.
pub struct LocalEngine {
    slots: usize,
    next_id: u64,
    /// Finished jobs (including those waited on already).
    finished: HashMap<JobId, JobReport>,
    /// Jobs submitted but not yet run.  The local engine runs jobs at
    /// `wait()` time in dependency order — simpler than a background
    /// dispatcher and identical observable behaviour for a launcher that
    /// always waits (Fig 1: reduce waits on map).
    pending: Vec<(JobId, JobSpec)>,
}

impl LocalEngine {
    /// `slots`: maximum concurrently-running tasks (the `--np` width).
    pub fn new(slots: usize) -> Self {
        LocalEngine {
            slots: slots.max(1),
            next_id: 1,
            finished: HashMap::new(),
            pending: Vec::new(),
        }
    }

    fn run_job(&mut self, id: JobId, spec: JobSpec) -> Result<JobReport> {
        // Dependencies first (transitively).
        if let Some(dep) = spec.depends_on {
            if !self.finished.contains_key(&dep) {
                let dep_spec = self.take_pending(dep)?;
                let report = self.run_job(dep, dep_spec)?;
                self.finished.insert(dep, report);
            }
        }

        let submit_t = Instant::now();
        let n = spec.tasks.len();
        let reports: Arc<Mutex<Vec<Option<TaskReport>>>> =
            Arc::new(Mutex::new(vec![None; n]));
        let first_err: Arc<Mutex<Option<Error>>> = Arc::new(Mutex::new(None));

        // Simple work queue: channel of task indices, `slots` workers.
        let (tx, rx) = mpsc::channel::<usize>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..n {
            tx.send(i).expect("queue send");
        }
        drop(tx);

        let workers = self.slots.min(n.max(1));
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let rx = rx.clone();
                let reports = reports.clone();
                let first_err = first_err.clone();
                let tasks = &spec.tasks;
                scope.spawn(move || {
                    loop {
                        let idx = {
                            let guard = rx.lock().expect("rx lock");
                            match guard.recv() {
                                Ok(i) => i,
                                Err(_) => break,
                            }
                        };
                        let task = &tasks[idx];
                        let started_at = submit_t.elapsed();
                        let result = execute(&task.work);
                        let finished_at = submit_t.elapsed();
                        match result {
                            Ok(out) => {
                                let report = TaskReport {
                                    task_id: task.task_id,
                                    // No scheduler in the local engine.
                                    dispatch_wait: Duration::ZERO,
                                    startup: out.startup,
                                    compute: out.compute,
                                    launches: out.launches,
                                    items: out.items,
                                    started_at,
                                    finished_at,
                                    retries: 0,
                                };
                                reports.lock().expect("reports")[idx] =
                                    Some(report);
                            }
                            Err(e) => {
                                let mut slot =
                                    first_err.lock().expect("err lock");
                                if slot.is_none() {
                                    *slot = Some(e);
                                }
                            }
                        }
                    }
                });
            }
        });

        if let Some(e) = first_err.lock().expect("err lock").take() {
            return Err(e);
        }
        let tasks: Vec<TaskReport> = Arc::try_unwrap(reports)
            .expect("workers joined")
            .into_inner()
            .expect("reports lock")
            .into_iter()
            .map(|r| r.expect("every task reported"))
            .collect();
        Ok(JobReport {
            job_id: id.0,
            name: spec.name,
            makespan: submit_t.elapsed(),
            slots: self.slots,
            tasks,
        })
    }

    fn take_pending(&mut self, id: JobId) -> Result<JobSpec> {
        let pos = self
            .pending
            .iter()
            .position(|(jid, _)| *jid == id)
            .ok_or_else(|| {
                Error::Scheduler(format!("unknown dependency job {id}"))
            })?;
        Ok(self.pending.remove(pos).1)
    }
}

impl Engine for LocalEngine {
    fn name(&self) -> &'static str {
        "local"
    }

    fn submit(&mut self, spec: JobSpec) -> Result<JobId> {
        if let Some(dep) = spec.depends_on {
            let known = self.finished.contains_key(&dep)
                || self.pending.iter().any(|(jid, _)| *jid == dep);
            if !known {
                return Err(Error::Scheduler(format!(
                    "dependency {dep} was never submitted"
                )));
            }
        }
        let id = JobId(self.next_id);
        self.next_id += 1;
        self.pending.push((id, spec));
        Ok(id)
    }

    fn wait(&mut self, id: JobId) -> Result<JobReport> {
        if let Some(r) = self.finished.get(&id) {
            return Ok(r.clone());
        }
        let spec = self.take_pending(id)?;
        let report = self.run_job(id, spec)?;
        self.finished.insert(id, report.clone());
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::testutil::{ConcatReducer, CountingApp};
    use crate::options::AppType;
    use crate::scheduler::{TaskSpec, TaskWork};
    use std::fs;
    use std::path::PathBuf;
    use std::sync::atomic::Ordering;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-local-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn map_tasks(
        dir: &PathBuf,
        app: Arc<CountingApp>,
        nfiles: usize,
        ntasks: usize,
        mode: AppType,
    ) -> Vec<TaskSpec> {
        let pairs: Vec<_> = (0..nfiles)
            .map(|i| {
                let inp = dir.join(format!("f{i}.dat"));
                fs::write(&inp, format!("{i}\n")).unwrap();
                (inp, dir.join(format!("f{i}.dat.out")))
            })
            .collect();
        pairs
            .chunks(nfiles.div_ceil(ntasks))
            .enumerate()
            .map(|(t, chunk)| TaskSpec {
                task_id: t + 1,
                work: TaskWork::Map {
                    app: app.clone(),
                    pairs: chunk.to_vec(),
                    mode,
                },
            })
            .collect()
    }

    #[test]
    fn runs_all_tasks_and_reports() {
        let d = tmp("basic");
        let app = Arc::new(CountingApp::new());
        let tasks = map_tasks(&d, app.clone(), 8, 4, AppType::Siso);
        let mut eng = LocalEngine::new(2);
        let report = eng.run(JobSpec::new("job", tasks)).unwrap();
        assert_eq!(report.tasks.len(), 4);
        assert_eq!(report.total_items(), 8);
        assert_eq!(report.total_launches(), 8); // SISO: launch per file
        assert_eq!(app.processed.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn mimo_launches_once_per_task() {
        let d = tmp("mimo");
        let app = Arc::new(CountingApp::new());
        let tasks = map_tasks(&d, app.clone(), 8, 4, AppType::Mimo);
        let mut eng = LocalEngine::new(2);
        let report = eng.run(JobSpec::new("job", tasks)).unwrap();
        assert_eq!(report.total_launches(), 4); // MIMO: launch per task
        assert_eq!(app.startups.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn dependency_runs_before_dependent() {
        let d = tmp("dep");
        let app = Arc::new(CountingApp::new());
        let map_tasks = map_tasks(&d, app.clone(), 4, 2, AppType::Mimo);
        let outdir = d.clone();
        let mut eng = LocalEngine::new(2);
        let map_id = eng.submit(JobSpec::new("map", map_tasks)).unwrap();
        let red_id = eng
            .submit(
                JobSpec::new(
                    "reduce",
                    vec![TaskSpec {
                        task_id: 1,
                        work: TaskWork::Reduce {
                            app: Arc::new(ConcatReducer),
                            input_dir: outdir.clone(),
                            out_file: d.join("llmapreduce.out"),
                        },
                    }],
                )
                .after(map_id),
            )
            .unwrap();
        let red = eng.wait(red_id).unwrap();
        assert_eq!(red.tasks.len(), 1);
        // Reducer saw the mapper outputs: merged content contains markers.
        let merged = fs::read_to_string(d.join("llmapreduce.out")).unwrap();
        assert_eq!(merged.matches("#mapped").count(), 4);
        // Map job's report is retrievable afterwards.
        let map_report = eng.wait(map_id).unwrap();
        assert_eq!(map_report.total_items(), 4);
    }

    #[test]
    fn unknown_dependency_rejected() {
        let mut eng = LocalEngine::new(1);
        let err = eng
            .submit(JobSpec::new("x", vec![]).after(JobId(99)))
            .unwrap_err();
        assert!(err.to_string().contains("never submitted"));
    }

    #[test]
    fn task_failure_propagates() {
        let d = tmp("fail");
        let mut app = CountingApp::new();
        app.poison = Some("f2".into());
        let tasks = map_tasks(&d, Arc::new(app), 4, 2, AppType::Siso);
        let mut eng = LocalEngine::new(2);
        let err = eng.run(JobSpec::new("job", tasks)).unwrap_err();
        assert!(err.to_string().contains("poisoned"));
    }

    #[test]
    fn single_slot_serializes() {
        let d = tmp("serial");
        let app = Arc::new(CountingApp::new());
        let tasks = map_tasks(&d, app.clone(), 6, 6, AppType::Siso);
        let mut eng = LocalEngine::new(1);
        let report = eng.run(JobSpec::new("job", tasks)).unwrap();
        // With one slot, task intervals must not overlap.
        let mut intervals: Vec<(Duration, Duration)> = report
            .tasks
            .iter()
            .map(|t| (t.started_at, t.finished_at))
            .collect();
        intervals.sort();
        for w in intervals.windows(2) {
            assert!(w[0].1 <= w[1].0 + Duration::from_millis(5));
        }
    }

    #[test]
    fn wait_twice_returns_same_report() {
        let d = tmp("twice");
        let app = Arc::new(CountingApp::new());
        let tasks = map_tasks(&d, app, 2, 1, AppType::Mimo);
        let mut eng = LocalEngine::new(1);
        let id = eng.submit(JobSpec::new("job", tasks)).unwrap();
        let a = eng.wait(id).unwrap();
        let b = eng.wait(id).unwrap();
        assert_eq!(a.job_id, b.job_id);
        assert_eq!(a.tasks.len(), b.tasks.len());
    }
}
