//! Discrete-event cluster simulator.
//!
//! Substitutes the paper's MIT SuperCloud cluster (DESIGN.md §3): virtual
//! nodes × slots, a serialized dispatcher with per-task latency (the array
//! job launch mechanism whose overhead §II-B discusses), job dependencies,
//! optional duration jitter and failure injection.
//!
//! Two modes:
//!
//! * **pure timing** (default) — payload costs come from
//!   [`crate::scheduler::exec::virtual_cost`] (calibrated
//!   [`crate::apps::CostHint`]s); nothing touches the filesystem.  This is
//!   how the Fig 18/19 sweeps scale to 256 concurrent tasks on a
//!   single-core container, and how the 43,580-file Table II trace runs in
//!   milliseconds.
//! * **executing** (`execute_payloads(true)`) — payloads really run (real
//!   outputs on disk) while queueing/dispatch time stays virtual; used by
//!   integration tests to check that sim and local agree on results.
//!
//! Failure injection delegates to the engine-shared
//! [`crate::scheduler::failure::FailurePolicy`], so retry counts replay
//! identically on [`crate::scheduler::local::LocalEngine`].
//!
//! Task-granularity dependencies ([`JobSpec::task_deps`]) are honoured
//! *conservatively*: the simulator runs chained jobs one at a time, so a
//! task edge widens back to the whole-job barrier.  Results and ordering
//! stay correct; only the overlap is lost (the local engine models it —
//! DESIGN.md §4).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::scheduler::exec::{execute, virtual_cost};
use crate::scheduler::failure::FailurePolicy;
use crate::scheduler::{Engine, JobId, JobReport, JobSpec, TaskReport};
use crate::util::rng::Rng;

/// Virtual time in nanoseconds.
type VTime = u128;

fn vt(d: Duration) -> VTime {
    d.as_nanos()
}

fn dur(t: VTime) -> Duration {
    Duration::from_nanos(t.min(u64::MAX as u128) as u64)
}

/// Simulated cluster shape and behaviour.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of compute nodes.
    pub nodes: usize,
    /// Scheduler slots per node (cores).
    pub slots_per_node: usize,
    /// Dispatcher cost to launch one array task.  Array task launches are
    /// serialized at the scheduler — this is the "latency overhead
    /// associated with the scheduler job launch mechanism" (§II-B).
    pub dispatch_latency: Duration,
    /// Multiplicative duration jitter, e.g. 0.05 = ±5%.  0 disables.
    pub jitter: f64,
    /// Per-task failure probability (failure injection for tests).
    pub failure_rate: f64,
    /// Retries before a task failure fails the job.
    pub max_retries: usize,
    /// RNG seed: identical seeds replay identical schedules.
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            nodes: 16,
            slots_per_node: 16,
            dispatch_latency: Duration::from_millis(50),
            jitter: 0.0,
            failure_rate: 0.0,
            max_retries: 2,
            seed: 0x5EED,
        }
    }
}

impl ClusterConfig {
    /// Total slots in the cluster.
    pub fn total_slots(&self) -> usize {
        self.nodes * self.slots_per_node
    }

    /// A cluster sized to run exactly `np` concurrent tasks (the way the
    /// paper's study varies "the number of concurrent array tasks").
    pub fn with_width(np: usize) -> Self {
        ClusterConfig {
            nodes: np,
            slots_per_node: 1,
            ..Default::default()
        }
    }

    /// The failure-injection rule this cluster implies — the same
    /// [`FailurePolicy`] the local engine consumes, so the two engines
    /// replay identical retry patterns.
    pub fn failure_policy(&self) -> FailurePolicy {
        FailurePolicy {
            failure_rate: self.failure_rate,
            max_retries: self.max_retries,
            seed: self.seed,
        }
    }
}

/// Queue/result state behind the engine's mutex (interior mutability, so
/// one simulator serves concurrent submitters like the local engine).
struct SimState {
    next_id: u64,
    pending: Vec<(JobId, JobSpec)>,
    finished: HashMap<JobId, JobReport>,
}

/// The simulator engine.
pub struct SimEngine {
    config: ClusterConfig,
    execute_payloads: bool,
    state: Mutex<SimState>,
}

impl SimEngine {
    pub fn new(config: ClusterConfig) -> Self {
        SimEngine {
            config,
            execute_payloads: false,
            state: Mutex::new(SimState {
                next_id: 1,
                pending: Vec::new(),
                finished: HashMap::new(),
            }),
        }
    }

    /// Also execute payloads for real (virtual clock, real outputs).
    pub fn execute_payloads(mut self, on: bool) -> Self {
        self.execute_payloads = on;
        self
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.config
    }

    /// Poison-tolerant lock (mirrors the local engine's).
    fn lock(&self) -> MutexGuard<'_, SimState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Run every pending job whose dependency chain ends at `target`,
    /// in one coupled discrete-event simulation.  Runs under the state
    /// lock: concurrent `wait()`s serialize, and each chain simulates
    /// from its own zero clock with a fresh seeded RNG — determinism is
    /// per chain, independent of what else the engine is serving.
    fn simulate_chain(
        &self,
        state: &mut SimState,
        target: JobId,
    ) -> Result<()> {
        // Collect the dependency chain (target and all ancestors).
        let mut chain: Vec<(JobId, JobSpec)> = Vec::new();
        let mut cursor = Some(target);
        while let Some(id) = cursor {
            if state.finished.contains_key(&id) {
                break;
            }
            let pos = state
                .pending
                .iter()
                .position(|(jid, _)| *jid == id)
                .ok_or_else(|| {
                    Error::Scheduler(format!("unknown job {id}"))
                })?;
            let (jid, spec) = state.pending.remove(pos);
            cursor = spec.depends_on;
            chain.push((jid, spec));
        }
        chain.reverse(); // dependencies first

        let mut rng = Rng::new(self.config.seed);
        let mut clock: VTime = 0;
        // Per-node in-use slot counts (for --exclusive semantics), plus a
        // stack of node ids with at least one free slot so the common
        // dispatch case is O(1) instead of a scan (§Perf iteration 4).
        let mut node_used = vec![0usize; self.config.nodes];
        let mut free_hint: Vec<usize> = (0..self.config.nodes).rev().collect();
        // The dispatcher is a serial resource.
        let mut dispatcher_free_at: VTime = 0;

        for (jid, spec) in chain {
            // A job starts only after its dependency completed; since we
            // process in chain order and each sim drains fully, `clock`
            // already sits past the dependency's completion.
            let job_submit = clock;
            let mut reports: Vec<Option<TaskReport>> =
                vec![None; spec.tasks.len()];
            let mut ready: VecDeque<usize> = (0..spec.tasks.len()).collect();
            // Min-heap of (finish_time, node, slots_taken, task_index).
            let mut running: BinaryHeap<
                Reverse<(VTime, usize, usize, usize)>,
            > = BinaryHeap::new();
            // Remaining retries per task.
            let mut retries = vec![0usize; spec.tasks.len()];

            let slots_needed = |exclusive: bool| -> usize {
                if exclusive {
                    self.config.slots_per_node
                } else {
                    1
                }
            };

            loop {
                // Dispatch while there is a free node slot and ready work.
                while let Some(&idx) = ready.front() {
                    let need = slots_needed(spec.exclusive);
                    // Fast path: pop candidate nodes off the free stack;
                    // fall back to a scan for exclusive jobs (need > 1).
                    let node = if need == 1 {
                        loop {
                            match free_hint.pop() {
                                Some(n)
                                    if node_used[n]
                                        < self.config.slots_per_node =>
                                {
                                    break Some(n)
                                }
                                Some(_) => continue, // stale hint
                                None => break None,
                            }
                        }
                    } else {
                        node_used.iter().position(|&u| {
                            self.config.slots_per_node - u >= need
                        })
                    };
                    let Some(node) = node else { break };
                    ready.pop_front();
                    node_used[node] += need;
                    if need == 1
                        && node_used[node] < self.config.slots_per_node
                    {
                        free_hint.push(node); // still has capacity
                    }

                    // Serialized dispatcher: one launch per latency window.
                    let dispatch_start =
                        clock.max(dispatcher_free_at);
                    let dispatch_done =
                        dispatch_start + vt(self.config.dispatch_latency);
                    dispatcher_free_at = dispatch_done;

                    let task = &spec.tasks[idx];
                    let cost = if self.execute_payloads {
                        // Real side effects; virtual durations still come
                        // from the cost model so the clock is deterministic.
                        execute(&task.work)?;
                        virtual_cost(&task.work)
                    } else {
                        virtual_cost(&task.work)
                    };
                    let mut duration =
                        vt(cost.startup) + vt(cost.compute);
                    if self.config.jitter > 0.0 {
                        let f = 1.0
                            + self.config.jitter
                                * (2.0 * rng.next_f64() - 1.0);
                        duration = (duration as f64 * f) as VTime;
                    }

                    // Failure injection: failed attempts burn half the
                    // duration, then the task re-enters the ready queue.
                    // The decision comes from the engine-shared policy —
                    // a pure function of (seed, task id, attempt) — so
                    // local-engine runs retry identically.
                    let fails = self
                        .config
                        .failure_policy()
                        .should_fail(task.task_id, retries[idx]);
                    if fails {
                        retries[idx] += 1;
                        let finish = dispatch_done + duration / 2;
                        running.push(Reverse((
                            finish,
                            node,
                            need,
                            // Encode "retry" by pushing back to ready at
                            // completion; use a sentinel via items.
                            idx | RETRY_BIT,
                        )));
                    } else {
                        let finish = dispatch_done + duration;
                        running.push(Reverse((finish, node, need, idx)));
                        let report = TaskReport {
                            task_id: task.task_id,
                            // Dispatcher service time for this launch (the
                            // scheduler's per-task overhead); queueing is
                            // visible via started_at instead.
                            dispatch_wait: self.config.dispatch_latency,
                            startup: cost.startup,
                            compute: cost.compute,
                            launches: cost.launches,
                            items: cost.items,
                            started_at: dur(
                                dispatch_done.saturating_sub(job_submit),
                            ),
                            finished_at: dur(finish - job_submit),
                            retries: retries[idx],
                            ..Default::default()
                        };
                        reports[idx] = Some(report);
                    }
                }

                // Advance to the next completion.
                let Some(Reverse((t, node, need, tagged))) = running.pop()
                else {
                    break;
                };
                clock = t;
                node_used[node] -= need;
                free_hint.push(node);
                if tagged & RETRY_BIT != 0 {
                    ready.push_back(tagged & !RETRY_BIT);
                }
            }

            // Any task that exhausted retries without success?
            for (i, r) in reports.iter().enumerate() {
                if r.is_none() {
                    return Err(Error::Scheduler(format!(
                        "task {} failed after {} retries",
                        spec.tasks[i].task_id, self.config.max_retries
                    )));
                }
            }

            let report = JobReport {
                job_id: jid.0,
                name: spec.name.clone(),
                makespan: dur(clock.saturating_sub(job_submit)),
                slots: self.config.total_slots(),
                replayed: 0,
                tasks: reports.into_iter().map(|r| r.unwrap()).collect(),
            };
            state.finished.insert(jid, report);
        }
        Ok(())
    }
}

/// High bit tags a heap entry as a failed attempt needing retry.
const RETRY_BIT: usize = 1 << (usize::BITS - 1);

impl Engine for SimEngine {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn virtual_time(&self) -> bool {
        true
    }

    fn submit(&self, spec: JobSpec) -> Result<JobId> {
        let mut state = self.lock();
        // Same admission contract as the local engine (shared helper):
        // specs must stay portable across `--engine=local|sim` even
        // though this engine widens task edges to the job barrier.
        crate::scheduler::validate_submit(&spec, |dep| {
            state.finished.get(&dep).map(|r| r.tasks.len()).or_else(|| {
                state
                    .pending
                    .iter()
                    .find(|(jid, _)| *jid == dep)
                    .map(|(_, s)| s.tasks.len())
            })
        })?;
        let id = JobId(state.next_id);
        state.next_id += 1;
        state.pending.push((id, spec));
        Ok(id)
    }

    fn wait(&self, id: JobId) -> Result<JobReport> {
        let mut state = self.lock();
        if !state.finished.contains_key(&id) {
            self.simulate_chain(&mut state, id)?;
        }
        state
            .finished
            .get(&id)
            .cloned()
            .ok_or_else(|| Error::Scheduler(format!("job {id} vanished")))
    }

    fn try_wait(&self, id: JobId) -> Result<Option<JobReport>> {
        // Never forces — or waits on — a simulation: a lazily-executed
        // pending job reads as in-flight until someone `wait()`s its
        // chain, and while another thread holds the engine simulating
        // (possibly executing real payloads), everything probes as
        // in-flight rather than blocking behind the mutex.
        let state = match self.state.try_lock() {
            Ok(state) => state,
            Err(std::sync::TryLockError::WouldBlock) => return Ok(None),
            Err(std::sync::TryLockError::Poisoned(e)) => e.into_inner(),
        };
        if let Some(r) = state.finished.get(&id) {
            return Ok(Some(r.clone()));
        }
        if state.pending.iter().any(|(jid, _)| *jid == id) {
            return Ok(None);
        }
        Err(Error::Scheduler(format!("unknown job {id}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{TaskSpec, TaskWork};

    fn synth_tasks(
        n: usize,
        startup_ms: u64,
        per_item_ms: u64,
        items: usize,
        launches: usize,
    ) -> Vec<TaskSpec> {
        (0..n)
            .map(|i| TaskSpec {
                task_id: i + 1,
                work: TaskWork::Synthetic {
                    startup: Duration::from_millis(startup_ms),
                    per_item: Duration::from_millis(per_item_ms),
                    items,
                    launches,
                },
            })
            .collect()
    }

    fn cfg(np: usize) -> ClusterConfig {
        ClusterConfig {
            dispatch_latency: Duration::from_millis(1),
            ..ClusterConfig::with_width(np)
        }
    }

    #[test]
    fn single_task_timing_exact() {
        let eng = SimEngine::new(cfg(1));
        let r = eng
            .run(JobSpec::new("j", synth_tasks(1, 100, 10, 4, 4)))
            .unwrap();
        // dispatch 1ms + 4 launches x 100ms + 4 items x 10ms = 441ms.
        assert_eq!(r.makespan, Duration::from_millis(441));
        assert_eq!(r.tasks[0].launches, 4);
    }

    #[test]
    fn parallel_width_shrinks_makespan() {
        let tasks = |n| synth_tasks(n, 10, 10, 1, 1);
        let mk = |np: usize| {
            SimEngine::new(cfg(np))
                .run(JobSpec::new("j", tasks(64)))
                .unwrap()
                .makespan
        };
        let t1 = mk(1);
        let t8 = mk(8);
        let t64 = mk(64);
        assert!(t1 > t8 && t8 > t64, "{t1:?} {t8:?} {t64:?}");
        // Near-linear: 64 tasks at width 8 ≈ 8 rounds.
        let ratio = t1.as_secs_f64() / t8.as_secs_f64();
        assert!(ratio > 6.0 && ratio < 9.0, "ratio={ratio}");
    }

    #[test]
    fn dispatch_latency_serializes_launches() {
        // Wide cluster, tiny compute: makespan dominated by the serial
        // dispatcher, one latency unit per task.
        let eng = SimEngine::new(ClusterConfig {
            dispatch_latency: Duration::from_millis(10),
            ..ClusterConfig::with_width(512)
        });
        let r = eng
            .run(JobSpec::new("j", synth_tasks(100, 0, 0, 1, 1)))
            .unwrap();
        assert!(
            r.makespan >= Duration::from_millis(1000),
            "{:?}",
            r.makespan
        );
    }

    #[test]
    fn dependency_ordering_respected() {
        let eng = SimEngine::new(cfg(4));
        let a = eng
            .submit(JobSpec::new("map", synth_tasks(8, 5, 5, 1, 1)))
            .unwrap();
        let b = eng
            .submit(JobSpec::new("reduce", synth_tasks(1, 1, 1, 1, 1)).after(a))
            .unwrap();
        let rb = eng.wait(b).unwrap();
        let ra = eng.wait(a).unwrap();
        assert!(ra.makespan > Duration::ZERO);
        assert!(rb.makespan > Duration::ZERO);
    }

    #[test]
    fn deterministic_with_same_seed() {
        let run = || {
            let eng = SimEngine::new(ClusterConfig {
                jitter: 0.2,
                seed: 99,
                ..cfg(4)
            });
            eng.run(JobSpec::new("j", synth_tasks(32, 10, 5, 2, 2)))
                .unwrap()
                .makespan
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn jitter_changes_with_seed() {
        let run = |seed| {
            let eng = SimEngine::new(ClusterConfig {
                jitter: 0.2,
                seed,
                ..cfg(4)
            });
            eng.run(JobSpec::new("j", synth_tasks(32, 10, 5, 2, 2)))
                .unwrap()
                .makespan
        };
        assert_ne!(run(1), run(2));
    }

    #[test]
    fn task_dep_validation_matches_local_engine() {
        let eng = SimEngine::new(cfg(2));
        let a = eng
            .submit(JobSpec::new("a", synth_tasks(2, 1, 1, 1, 1)))
            .unwrap();
        let err = eng
            .submit(
                JobSpec::new("b", synth_tasks(2, 1, 1, 1, 1))
                    .after_tasks(a, vec![(0, 99)]),
            )
            .unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        let orphan = JobSpec {
            task_deps: vec![(0, 0)],
            ..JobSpec::new("orphan", synth_tasks(1, 1, 1, 1, 1))
        };
        let err = eng.submit(orphan).unwrap_err();
        assert!(err.to_string().contains("depends_on"), "{err}");
    }

    #[test]
    fn task_deps_widen_to_conservative_barrier() {
        // The simulator may ignore task-granularity edges, but ordering
        // and results must match the barriered semantics exactly.
        let eager = SimEngine::new(cfg(4));
        let m1 = eager
            .submit(JobSpec::new("map", synth_tasks(4, 5, 5, 1, 1)))
            .unwrap();
        let edges: Vec<(usize, usize)> = (0..4).map(|i| (i, i)).collect();
        let p1 = eager
            .submit(
                JobSpec::new("partial", synth_tasks(4, 1, 1, 1, 1))
                    .after_tasks(m1, edges),
            )
            .unwrap();
        let eager_partial = eager.wait(p1).unwrap();

        let barriered = SimEngine::new(cfg(4));
        let m2 = barriered
            .submit(JobSpec::new("map", synth_tasks(4, 5, 5, 1, 1)))
            .unwrap();
        let p2 = barriered
            .submit(
                JobSpec::new("partial", synth_tasks(4, 1, 1, 1, 1))
                    .after(m2),
            )
            .unwrap();
        let barriered_partial = barriered.wait(p2).unwrap();
        assert_eq!(eager_partial.makespan, barriered_partial.makespan);
        assert_eq!(eager_partial.tasks.len(), 4);
    }

    #[test]
    fn failure_injection_retries_and_succeeds() {
        let eng = SimEngine::new(ClusterConfig {
            failure_rate: 0.3,
            max_retries: 10,
            seed: 7,
            ..cfg(4)
        });
        let r = eng
            .run(JobSpec::new("j", synth_tasks(32, 1, 1, 1, 1)))
            .unwrap();
        assert_eq!(r.tasks.len(), 32);
        let total_retries: usize = r.tasks.iter().map(|t| t.retries).sum();
        assert!(total_retries > 0, "30% failure rate must retry some");
    }

    #[test]
    fn exclusive_takes_whole_node() {
        // 2 nodes x 4 slots; 4 exclusive tasks of 10ms must serialize
        // into 2 waves (2 at a time), not run 4-wide.
        let eng = SimEngine::new(ClusterConfig {
            nodes: 2,
            slots_per_node: 4,
            dispatch_latency: Duration::ZERO,
            ..Default::default()
        });
        let r = eng
            .run(JobSpec::new("j", synth_tasks(4, 0, 10, 1, 1)).exclusive(true))
            .unwrap();
        assert!(
            r.makespan >= Duration::from_millis(20),
            "{:?}",
            r.makespan
        );
        // Non-exclusive: all 8 slots available, 4 tasks run in one wave.
        let eng2 = SimEngine::new(ClusterConfig {
            nodes: 2,
            slots_per_node: 4,
            dispatch_latency: Duration::ZERO,
            ..Default::default()
        });
        let r2 = eng2
            .run(JobSpec::new("j", synth_tasks(4, 0, 10, 1, 1)))
            .unwrap();
        assert!(r2.makespan < Duration::from_millis(20));
    }

    #[test]
    fn mimo_vs_siso_shape_matches_paper() {
        // 512 files over np=8 tasks: SISO pays 64 startups per task,
        // MIMO pays 1 — the Fig 18 gap.
        let np = 8;
        let files_per_task = 64;
        let siso = synth_tasks(np, 100, 10, files_per_task, files_per_task);
        let mimo = synth_tasks(np, 100, 10, files_per_task, 1);
        let run = |tasks| {
            SimEngine::new(cfg(np))
                .run(JobSpec::new("j", tasks))
                .unwrap()
        };
        let rs = run(siso);
        let rm = run(mimo);
        let speedup =
            rs.makespan.as_secs_f64() / rm.makespan.as_secs_f64();
        // (64*100 + 64*10) / (100 + 64*10) ≈ 9.5
        assert!(speedup > 8.0 && speedup < 11.0, "speedup={speedup}");
        // MIMO overhead per task is flat (one startup), SISO scales with
        // files per task.
        assert!(rs.mean_overhead_per_task()
            > rm.mean_overhead_per_task() * 10);
    }
}
