//! Cost calibration: bridges the real (local) engine and the simulator.
//!
//! The paper measured on a real cluster.  We measure the two quantities
//! that drive every result in §IV — application start-up cost and per-file
//! compute cost — on the *real* local engine, then feed them to the
//! discrete-event simulator to produce the scaling sweeps this container's
//! single core cannot run in parallel.  EXPERIMENTS.md records the
//! calibrated constants next to each figure.

use std::path::PathBuf;
use std::time::Duration;

use crate::apps::{CostHint, MapApp};
use crate::error::Result;

/// A measured cost profile for one application.
#[derive(Debug, Clone, Copy)]
pub struct Calibration {
    pub hint: CostHint,
    /// How many launches/items the measurement averaged over.
    pub launches_measured: usize,
    pub items_measured: usize,
}

impl Calibration {
    /// Measure `app` by launching it `launches` times and processing the
    /// sample pairs through one instance.  The samples must be real files
    /// the app can process.
    pub fn measure(
        app: &dyn MapApp,
        sample_pairs: &[(PathBuf, PathBuf)],
        launches: usize,
    ) -> Result<Calibration> {
        assert!(!sample_pairs.is_empty(), "need at least one sample pair");
        assert!(launches >= 1);

        // Warm-up launch: fault in code paths, page caches, BLAS threads.
        let _ = app.startup()?;

        // Startup cost: average over `launches` fresh launches.
        let t0 = std::time::Instant::now();
        for _ in 0..launches {
            let _ = app.startup()?;
        }
        let startup = t0.elapsed() / launches as u32;

        // Per-item cost: one instance, stream all samples (MIMO-style so
        // startup does not contaminate the measurement).  The first call
        // on a fresh instance pays one-time lazy initialization (PJRT
        // buffer pools, page faults) that a steady-state mapper never
        // sees again — warm it untimed, then time the real passes twice.
        let mut inst = app.startup()?;
        let (w_in, w_out) = &sample_pairs[0];
        inst.process(w_in, w_out)?;
        let t1 = std::time::Instant::now();
        for _ in 0..2 {
            for (input, output) in sample_pairs {
                inst.process(input, output)?;
            }
        }
        let per_item = t1.elapsed() / (2 * sample_pairs.len()) as u32;

        Ok(Calibration {
            hint: CostHint { startup, per_item },
            launches_measured: launches,
            items_measured: sample_pairs.len(),
        })
    }

    /// The paper's central ratio: how expensive a launch is relative to
    /// one file of work.  MATLAB in the paper has a very large ratio;
    /// the MIMO speed-up ceiling for n files/launch is
    /// `(ratio + 1) / (ratio/n + 1)`.
    pub fn startup_ratio(&self) -> f64 {
        let s = self.hint.startup.as_secs_f64();
        let p = self.hint.per_item.as_secs_f64().max(1e-12);
        s / p
    }

    /// Predicted MIMO-over-SISO speed-up when each launch amortizes over
    /// `files_per_task` files (ignoring dispatch, the dominant term).
    pub fn predicted_mimo_speedup(&self, files_per_task: usize) -> f64 {
        let r = self.startup_ratio();
        let n = files_per_task as f64;
        (r + 1.0) / (r / n + 1.0)
    }
}

/// A hand-specified cost profile for simulator studies where the paper
/// gives us the regime but we have no binary to measure (e.g. "MATLAB
/// takes relatively significant time to launch", §IV Table II).
pub fn synthetic_hint(startup: Duration, per_item: Duration) -> CostHint {
    CostHint { startup, per_item }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::testutil::CountingApp;
    use std::fs;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-cost-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn measures_spinning_startup() {
        let d = tmp("spin");
        let mut app = CountingApp::new();
        app.startup_spin = Duration::from_millis(5);
        let pairs: Vec<_> = (0..3)
            .map(|i| {
                let p = d.join(format!("f{i}"));
                fs::write(&p, "x").unwrap();
                (p, d.join(format!("f{i}.out")))
            })
            .collect();
        let cal = Calibration::measure(&app, &pairs, 3).unwrap();
        assert!(
            cal.hint.startup >= Duration::from_millis(5),
            "{:?}",
            cal.hint.startup
        );
        assert!(cal.startup_ratio() > 1.0);
    }

    #[test]
    fn speedup_prediction_shape() {
        let cal = Calibration {
            hint: CostHint {
                startup: Duration::from_millis(1000),
                per_item: Duration::from_millis(100),
            },
            launches_measured: 1,
            items_measured: 1,
        };
        // ratio = 10; with 170 files/task the ceiling approaches 11.
        // (Table II: 43,580 files / 256 tasks ≈ 170 files per task,
        // speed-up 11.57 — consistent with a startup ratio near 11.)
        let s = cal.predicted_mimo_speedup(170);
        assert!(s > 9.0 && s < 11.0, "s={s}");
        // One file per task: no gain (the Fig 19 convergence point).
        let s1 = cal.predicted_mimo_speedup(1);
        assert!((s1 - 1.0).abs() < 1e-9);
        // Monotone in files per task.
        assert!(cal.predicted_mimo_speedup(10) < cal.predicted_mimo_speedup(100));
    }
}
