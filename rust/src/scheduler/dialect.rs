//! Submission-script dialects: Grid Engine, SLURM, LSF.
//!
//! "LLMapReduce hides the scheduler-specific job submission script from
//! users and, therefore, provides a scheduler-neutral API" (§III-A).
//! The same abstract plan lowers to each scheduler's directive language;
//! Fig 8 shows the Grid Engine form this module reproduces verbatim.
//!
//! The dialects also carry per-scheduler array-size limits: "the default
//! maximum number of array tasks for an array job is 75,000 for the open
//! source Grid Engine scheduler" (§III-A).  Exceeding the limit is exactly
//! the situation `--np` exists for.

use crate::options::SchedulerKind;

/// Everything a dialect needs to know to write a submission script.
#[derive(Debug, Clone)]
pub struct SubmitRequest<'a> {
    /// Job name (`-N` / `--job-name` / `-J`).
    pub job_name: &'a str,
    /// Number of array tasks (the `M` in `-t 1-M`).
    pub tasks: usize,
    /// `.MAPRED.<PID>` directory name (relative, like the paper's
    /// `.MAPRED.1120`).
    pub mapred_dir: &'a str,
    /// Whole-node allocation.
    pub exclusive: bool,
    /// Job id this one depends on (reducer jobs).
    pub depends_on: Option<u64>,
    /// Raw passthrough directives from `--options`.
    pub extra_options: &'a [String],
}

/// A scheduler dialect: script syntax + limits.
pub trait Dialect {
    fn kind(&self) -> SchedulerKind;

    /// Default maximum array-job size.
    fn max_array_tasks(&self) -> usize;

    /// Environment variable holding the array task id at run time.
    fn task_id_var(&self) -> &'static str;

    /// Render the job submission script (the file Fig 8 shows).
    fn submission_script(&self, req: &SubmitRequest<'_>) -> String;
}

/// Look up the dialect for a [`SchedulerKind`].
pub fn dialect_for(kind: SchedulerKind) -> Box<dyn Dialect + Send + Sync> {
    match kind {
        SchedulerKind::GridEngine => Box::new(GridEngine),
        SchedulerKind::Slurm => Box::new(Slurm),
        SchedulerKind::Lsf => Box::new(Lsf),
    }
}

// ---------------------------------------------------------------------------
// Grid Engine (the dialect of Fig 8)
// ---------------------------------------------------------------------------

pub struct GridEngine;

impl Dialect for GridEngine {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::GridEngine
    }

    fn max_array_tasks(&self) -> usize {
        75_000 // §III-A
    }

    fn task_id_var(&self) -> &'static str {
        "SGE_TASK_ID"
    }

    fn submission_script(&self, req: &SubmitRequest<'_>) -> String {
        // Fig 8, line for line:
        //   #!/bin/bash
        //   #$ -terse -cwd -V -j y -N MatlabCmd.sh
        //   #$ -l excl=false -t 1-M
        //   #$ -o .MAPRED.1120/llmap.log-$JOB_ID-$TASK_ID
        //   ./.MAPRED.1120/run_llmap_$SGE_TASK_ID
        let mut s = String::new();
        s.push_str("#!/bin/bash\n");
        s.push_str(&format!("#$ -terse -cwd -V -j y -N {}\n", req.job_name));
        s.push_str(&format!(
            "#$ -l excl={} -t 1-{}\n",
            req.exclusive, req.tasks
        ));
        s.push_str(&format!(
            "#$ -o {}/llmap.log-$JOB_ID-$TASK_ID\n",
            req.mapred_dir
        ));
        if let Some(dep) = req.depends_on {
            s.push_str(&format!("#$ -hold_jid {dep}\n"));
        }
        for opt in req.extra_options {
            s.push_str(&format!("#$ {opt}\n"));
        }
        s.push_str(&format!(
            "./{}/run_llmap_${}\n",
            req.mapred_dir,
            self.task_id_var()
        ));
        s
    }
}

// ---------------------------------------------------------------------------
// SLURM
// ---------------------------------------------------------------------------

pub struct Slurm;

impl Dialect for Slurm {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Slurm
    }

    fn max_array_tasks(&self) -> usize {
        // slurm.conf MaxArraySize default is 1001 (max index 1000).
        1_000
    }

    fn task_id_var(&self) -> &'static str {
        "SLURM_ARRAY_TASK_ID"
    }

    fn submission_script(&self, req: &SubmitRequest<'_>) -> String {
        let mut s = String::new();
        s.push_str("#!/bin/bash\n");
        s.push_str(&format!("#SBATCH --job-name={}\n", req.job_name));
        s.push_str(&format!("#SBATCH --array=1-{}\n", req.tasks));
        s.push_str(&format!(
            "#SBATCH --output={}/llmap.log-%A-%a\n",
            req.mapred_dir
        ));
        if req.exclusive {
            s.push_str("#SBATCH --exclusive\n");
        }
        if let Some(dep) = req.depends_on {
            s.push_str(&format!("#SBATCH --dependency=afterok:{dep}\n"));
        }
        for opt in req.extra_options {
            s.push_str(&format!("#SBATCH {opt}\n"));
        }
        s.push_str(&format!(
            "./{}/run_llmap_${}\n",
            req.mapred_dir,
            self.task_id_var()
        ));
        s
    }
}

// ---------------------------------------------------------------------------
// IBM Platform LSF
// ---------------------------------------------------------------------------

pub struct Lsf;

impl Dialect for Lsf {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Lsf
    }

    fn max_array_tasks(&self) -> usize {
        // LSF MAX_JOB_ARRAY_SIZE default.
        1_000
    }

    fn task_id_var(&self) -> &'static str {
        "LSB_JOBINDEX"
    }

    fn submission_script(&self, req: &SubmitRequest<'_>) -> String {
        let mut s = String::new();
        s.push_str("#!/bin/bash\n");
        s.push_str(&format!(
            "#BSUB -J \"{}[1-{}]\"\n",
            req.job_name, req.tasks
        ));
        s.push_str(&format!(
            "#BSUB -o {}/llmap.log-%J-%I\n",
            req.mapred_dir
        ));
        if req.exclusive {
            s.push_str("#BSUB -x\n");
        }
        if let Some(dep) = req.depends_on {
            s.push_str(&format!("#BSUB -w \"done({dep})\"\n"));
        }
        for opt in req.extra_options {
            s.push_str(&format!("#BSUB {opt}\n"));
        }
        s.push_str(&format!(
            "./{}/run_llmap_${}\n",
            req.mapred_dir,
            self.task_id_var()
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req<'a>(extra: &'a [String]) -> SubmitRequest<'a> {
        SubmitRequest {
            job_name: "MatlabCmd.sh",
            tasks: 6,
            mapred_dir: ".MAPRED.1120",
            exclusive: false,
            depends_on: None,
            extra_options: extra,
        }
    }

    #[test]
    fn gridengine_matches_fig8() {
        let script = GridEngine.submission_script(&req(&[]));
        let expected = "#!/bin/bash\n\
            #$ -terse -cwd -V -j y -N MatlabCmd.sh\n\
            #$ -l excl=false -t 1-6\n\
            #$ -o .MAPRED.1120/llmap.log-$JOB_ID-$TASK_ID\n\
            ./.MAPRED.1120/run_llmap_$SGE_TASK_ID\n";
        assert_eq!(script, expected);
    }

    #[test]
    fn gridengine_exclusive_and_dependency() {
        let mut r = req(&[]);
        r.exclusive = true;
        r.depends_on = Some(42);
        let script = GridEngine.submission_script(&r);
        assert!(script.contains("-l excl=true"));
        assert!(script.contains("#$ -hold_jid 42"));
    }

    #[test]
    fn extra_options_passthrough() {
        // §II: "--options ... is handy when some data processing requires
        // more memory than the standard allowance".
        let extra = vec!["-l mem=8G".to_string()];
        for kind in [
            SchedulerKind::GridEngine,
            SchedulerKind::Slurm,
            SchedulerKind::Lsf,
        ] {
            let d = dialect_for(kind);
            let script = d.submission_script(&req(&extra));
            assert!(script.contains("-l mem=8G"), "{kind:?}: {script}");
        }
    }

    #[test]
    fn slurm_directives() {
        let mut r = req(&[]);
        r.exclusive = true;
        r.depends_on = Some(7);
        let script = Slurm.submission_script(&r);
        assert!(script.contains("#SBATCH --job-name=MatlabCmd.sh"));
        assert!(script.contains("#SBATCH --array=1-6"));
        assert!(script.contains("#SBATCH --exclusive"));
        assert!(script.contains("--dependency=afterok:7"));
        assert!(script.contains("run_llmap_$SLURM_ARRAY_TASK_ID"));
    }

    #[test]
    fn lsf_directives() {
        let mut r = req(&[]);
        r.depends_on = Some(9);
        let script = Lsf.submission_script(&r);
        assert!(script.contains("#BSUB -J \"MatlabCmd.sh[1-6]\""));
        assert!(script.contains("#BSUB -w \"done(9)\""));
        assert!(script.contains("run_llmap_$LSB_JOBINDEX"));
    }

    #[test]
    fn array_limits() {
        assert_eq!(GridEngine.max_array_tasks(), 75_000);
        assert_eq!(Slurm.max_array_tasks(), 1_000);
        assert_eq!(Lsf.max_array_tasks(), 1_000);
    }

    #[test]
    fn every_dialect_references_its_task_id_var() {
        for kind in [
            SchedulerKind::GridEngine,
            SchedulerKind::Slurm,
            SchedulerKind::Lsf,
        ] {
            let d = dialect_for(kind);
            let script = d.submission_script(&req(&[]));
            assert!(
                script.contains(d.task_id_var()),
                "{kind:?} script must use {}",
                d.task_id_var()
            );
        }
    }
}
