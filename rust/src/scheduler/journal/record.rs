//! Journal record schema: one [`Record`] per engine-shared `JobTable`
//! transition, encoded as a single compact `util::json` line.
//!
//! Records are self-describing (`"rec"` tags the variant) so a journal
//! written by a newer build degrades gracefully: unknown tags decode as
//! [`Record::Unknown`] and replay skips them instead of refusing the
//! whole file.  Malformed lines decode to
//! [`Error::Format`]` { kind: "journal" }` — never a panic — matching
//! the wire protocol's discipline (DESIGN.md §6).

use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::{obj, Json};

/// One journaled `JobTable` transition.
#[derive(Debug, Clone, PartialEq)]
pub enum Record {
    /// Job-zero header: everything `resume` needs to re-plan the
    /// invocation deterministically (the serialized `Options`, the
    /// mapper/reducer wire specs, the planned map-task count, the pid).
    Invocation {
        pid: u32,
        mapper: String,
        reducer: Option<String>,
        ntasks: usize,
        options: Json,
    },
    /// A job was admitted to the table.
    JobSubmitted {
        job: u64,
        name: String,
        ntasks: usize,
        task_ids: Vec<usize>,
    },
    /// A task was claimed by / shipped to a worker.
    TaskAssigned {
        job: u64,
        idx: usize,
        task_id: usize,
        worker: Option<String>,
    },
    /// A task completed (possibly as a dead-lettered placeholder).
    TaskDone {
        job: u64,
        idx: usize,
        task_id: usize,
        retries: usize,
        dead_lettered: bool,
        /// Span decomposition for `llmapreduce trace`, nested as a
        /// compact `"t"` object.  Absent under `--trace=false` and on
        /// pre-PR-9 journals; replay tolerates both.
        timing: Option<crate::scheduler::TaskTiming>,
    },
    /// A task attempt was consumed and the task re-queued.
    TaskRetry {
        job: u64,
        idx: usize,
        task_id: usize,
        attempt: usize,
    },
    /// A task's execution errored (the policy verdict follows as a
    /// retry, a dead-letter completion, or a job failure).
    TaskFailed {
        job: u64,
        idx: usize,
        task_id: usize,
        msg: String,
    },
    /// A task was pulled off a dead worker and re-queued.
    TaskReassigned {
        job: u64,
        idx: usize,
        task_id: usize,
    },
    /// All of a job's tasks completed.
    JobDone { job: u64 },
    /// The job failed (scheduler error, stop policy, or breaker).
    JobFailed { job: u64, msg: String },
    /// The failure-rate circuit breaker tripped on this job.
    BreakerTripped {
        job: u64,
        errors: usize,
        ntasks: usize,
        threshold: f64,
    },
    /// A `resume` run appended to this journal from here on.
    Resumed { done: usize, total: usize },
    /// Forward-compat: a tag this build does not know; replay skips it.
    Unknown { tag: String },
}

impl Record {
    /// Encode as a compact single-line JSON document.
    pub fn to_json(&self) -> Json {
        match self {
            Record::Invocation {
                pid,
                mapper,
                reducer,
                ntasks,
                options,
            } => {
                let mut pairs = vec![
                    ("rec", "invocation".into()),
                    ("pid", (*pid as usize).into()),
                    ("mapper", mapper.as_str().into()),
                    ("ntasks", (*ntasks).into()),
                    ("options", options.clone()),
                ];
                if let Some(r) = reducer {
                    pairs.push(("reducer", r.as_str().into()));
                }
                obj(pairs)
            }
            Record::JobSubmitted {
                job,
                name,
                ntasks,
                task_ids,
            } => obj(vec![
                ("rec", "job".into()),
                ("job", (*job as usize).into()),
                ("name", name.as_str().into()),
                ("ntasks", (*ntasks).into()),
                (
                    "task_ids",
                    Json::Arr(
                        task_ids.iter().map(|&t| t.into()).collect(),
                    ),
                ),
            ]),
            Record::TaskAssigned {
                job,
                idx,
                task_id,
                worker,
            } => {
                let mut pairs = vec![
                    ("rec", "assign".into()),
                    ("job", (*job as usize).into()),
                    ("idx", (*idx).into()),
                    ("task_id", (*task_id).into()),
                ];
                if let Some(w) = worker {
                    pairs.push(("worker", w.as_str().into()));
                }
                obj(pairs)
            }
            Record::TaskDone {
                job,
                idx,
                task_id,
                retries,
                dead_lettered,
                timing,
            } => {
                let mut pairs = vec![
                    ("rec", "done".into()),
                    ("job", (*job as usize).into()),
                    ("idx", (*idx).into()),
                    ("task_id", (*task_id).into()),
                    ("retries", (*retries).into()),
                    ("dlq", (*dead_lettered).into()),
                ];
                if let Some(t) = timing {
                    let mut tf = vec![
                        ("start", (t.started_us as usize).into()),
                        ("finish", (t.finished_us as usize).into()),
                        ("dispatch", (t.dispatch_us as usize).into()),
                        ("startup", (t.startup_us as usize).into()),
                        ("compute", (t.compute_us as usize).into()),
                        ("shipped", (t.shipped_us as usize).into()),
                        ("items", t.items.into()),
                    ];
                    if let Some(so) = t.ship_out_us {
                        tf.push(("ship_out", (so as usize).into()));
                    }
                    if let Some(w) = &t.worker {
                        tf.push(("worker", w.as_str().into()));
                    }
                    pairs.push(("t", obj(tf)));
                }
                obj(pairs)
            }
            Record::TaskRetry {
                job,
                idx,
                task_id,
                attempt,
            } => obj(vec![
                ("rec", "retry".into()),
                ("job", (*job as usize).into()),
                ("idx", (*idx).into()),
                ("task_id", (*task_id).into()),
                ("attempt", (*attempt).into()),
            ]),
            Record::TaskFailed {
                job,
                idx,
                task_id,
                msg,
            } => obj(vec![
                ("rec", "task-failed".into()),
                ("job", (*job as usize).into()),
                ("idx", (*idx).into()),
                ("task_id", (*task_id).into()),
                ("msg", msg.as_str().into()),
            ]),
            Record::TaskReassigned { job, idx, task_id } => obj(vec![
                ("rec", "reassign".into()),
                ("job", (*job as usize).into()),
                ("idx", (*idx).into()),
                ("task_id", (*task_id).into()),
            ]),
            Record::JobDone { job } => obj(vec![
                ("rec", "job-done".into()),
                ("job", (*job as usize).into()),
            ]),
            Record::JobFailed { job, msg } => obj(vec![
                ("rec", "job-failed".into()),
                ("job", (*job as usize).into()),
                ("msg", msg.as_str().into()),
            ]),
            Record::BreakerTripped {
                job,
                errors,
                ntasks,
                threshold,
            } => obj(vec![
                ("rec", "breaker".into()),
                ("job", (*job as usize).into()),
                ("errors", (*errors).into()),
                ("ntasks", (*ntasks).into()),
                ("threshold", (*threshold).into()),
            ]),
            Record::Resumed { done, total } => obj(vec![
                ("rec", "resumed".into()),
                ("done", (*done).into()),
                ("total", (*total).into()),
            ]),
            Record::Unknown { tag } => {
                obj(vec![("rec", tag.as_str().into())])
            }
        }
    }

    /// Decode one journal line.  Any structural problem — bad JSON,
    /// missing fields, wrong types — is `Error::Format { kind:
    /// "journal" }`, never a panic.
    pub fn decode(line: &str, path: &Path) -> Result<Record> {
        let bad = |reason: String| Error::Format {
            kind: "journal",
            path: path.to_path_buf(),
            reason,
        };
        let doc = Json::parse(line)
            .map_err(|e| bad(format!("unparseable record: {e}")))?;
        let tag = doc
            .get("rec")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("record missing 'rec' tag".into()))?
            .to_string();
        let u = |key: &str| -> Result<usize> {
            doc.get(key).and_then(Json::as_usize).ok_or_else(|| {
                bad(format!("'{tag}' record missing usize '{key}'"))
            })
        };
        let s = |key: &str| -> Result<String> {
            doc.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| {
                    bad(format!("'{tag}' record missing string '{key}'"))
                })
        };
        Ok(match tag.as_str() {
            "invocation" => Record::Invocation {
                pid: u("pid")? as u32,
                mapper: s("mapper")?,
                reducer: doc
                    .get("reducer")
                    .and_then(Json::as_str)
                    .map(str::to_string),
                ntasks: u("ntasks")?,
                options: doc
                    .get("options")
                    .cloned()
                    .ok_or_else(|| {
                        bad("invocation record missing 'options'".into())
                    })?,
            },
            "job" => Record::JobSubmitted {
                job: u("job")? as u64,
                name: s("name")?,
                ntasks: u("ntasks")?,
                task_ids: doc
                    .get("task_ids")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| {
                        bad("job record missing 'task_ids'".into())
                    })?
                    .iter()
                    .map(|v| {
                        v.as_usize().ok_or_else(|| {
                            bad("non-integer task id".into())
                        })
                    })
                    .collect::<Result<Vec<_>>>()?,
            },
            "assign" => Record::TaskAssigned {
                job: u("job")? as u64,
                idx: u("idx")?,
                task_id: u("task_id")?,
                worker: doc
                    .get("worker")
                    .and_then(Json::as_str)
                    .map(str::to_string),
            },
            "done" => Record::TaskDone {
                job: u("job")? as u64,
                idx: u("idx")?,
                task_id: u("task_id")?,
                retries: u("retries")?,
                dead_lettered: doc
                    .get("dlq")
                    .and_then(Json::as_bool)
                    .unwrap_or(false),
                // Optional span object; a malformed one is dropped
                // rather than failing the record — replay must survive
                // any journal that PR-8 replay survived.
                timing: doc.get("t").map(|t| {
                    let tu = |key: &str| -> u64 {
                        t.get(key)
                            .and_then(Json::as_usize)
                            .unwrap_or_default()
                            as u64
                    };
                    crate::scheduler::TaskTiming {
                        started_us: tu("start"),
                        finished_us: tu("finish"),
                        dispatch_us: tu("dispatch"),
                        startup_us: tu("startup"),
                        compute_us: tu("compute"),
                        shipped_us: tu("shipped"),
                        ship_out_us: t
                            .get("ship_out")
                            .and_then(Json::as_usize)
                            .map(|n| n as u64),
                        items: t
                            .get("items")
                            .and_then(Json::as_usize)
                            .unwrap_or_default(),
                        worker: t
                            .get("worker")
                            .and_then(Json::as_str)
                            .map(str::to_string),
                    }
                }),
            },
            "retry" => Record::TaskRetry {
                job: u("job")? as u64,
                idx: u("idx")?,
                task_id: u("task_id")?,
                attempt: u("attempt")?,
            },
            "task-failed" => Record::TaskFailed {
                job: u("job")? as u64,
                idx: u("idx")?,
                task_id: u("task_id")?,
                msg: s("msg")?,
            },
            "reassign" => Record::TaskReassigned {
                job: u("job")? as u64,
                idx: u("idx")?,
                task_id: u("task_id")?,
            },
            "job-done" => Record::JobDone { job: u("job")? as u64 },
            "job-failed" => Record::JobFailed {
                job: u("job")? as u64,
                msg: s("msg")?,
            },
            "breaker" => Record::BreakerTripped {
                job: u("job")? as u64,
                errors: u("errors")?,
                ntasks: u("ntasks")?,
                threshold: doc
                    .get("threshold")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| {
                        bad("breaker record missing 'threshold'".into())
                    })?,
            },
            "resumed" => Record::Resumed {
                done: u("done")?,
                total: u("total")?,
            },
            _ => Record::Unknown { tag },
        })
    }
}

/// Cap stored error text at this many trailing bytes (the "stderr
/// tail" of the dead-letter entry) so a chatty mapper cannot bloat the
/// queue file.
pub const ERROR_TAIL_BYTES: usize = 1024;

/// One dead-lettered task: full attribution plus the input paths needed
/// to resubmit it through the normal planner path (`dlq reprocess`).
#[derive(Debug, Clone, PartialEq)]
pub struct DeadLetter {
    pub job: u64,
    pub task_id: usize,
    /// Error attempts consumed before landing here.
    pub attempts: usize,
    /// Worker attribution, when the failure came off the remote engine.
    pub worker: Option<String>,
    /// Tail of the task's error text (includes the command's exit
    /// status; capped at [`ERROR_TAIL_BYTES`]).
    pub error: String,
    /// Input files the task owned.
    pub inputs: Vec<String>,
}

impl DeadLetter {
    /// Truncate `error` to its last [`ERROR_TAIL_BYTES`] bytes on a
    /// char boundary.
    pub fn tail(error: &str) -> String {
        if error.len() <= ERROR_TAIL_BYTES {
            return error.to_string();
        }
        let mut start = error.len() - ERROR_TAIL_BYTES;
        while !error.is_char_boundary(start) {
            start += 1;
        }
        error[start..].to_string()
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("job", (self.job as usize).into()),
            ("task_id", self.task_id.into()),
            ("attempts", self.attempts.into()),
            ("error", self.error.as_str().into()),
            (
                "inputs",
                Json::Arr(
                    self.inputs
                        .iter()
                        .map(|i| i.as_str().into())
                        .collect(),
                ),
            ),
        ];
        if let Some(w) = &self.worker {
            pairs.push(("worker", w.as_str().into()));
        }
        obj(pairs)
    }

    /// Decode one `dlq.jsonl` line (same error discipline as
    /// [`Record::decode`]).
    pub fn decode(line: &str, path: &Path) -> Result<DeadLetter> {
        let bad = |reason: String| Error::Format {
            kind: "journal",
            path: path.to_path_buf(),
            reason,
        };
        let doc = Json::parse(line)
            .map_err(|e| bad(format!("unparseable dlq entry: {e}")))?;
        let u = |key: &str| -> Result<usize> {
            doc.get(key).and_then(Json::as_usize).ok_or_else(|| {
                bad(format!("dlq entry missing usize '{key}'"))
            })
        };
        Ok(DeadLetter {
            job: u("job")? as u64,
            task_id: u("task_id")?,
            attempts: u("attempts")?,
            worker: doc
                .get("worker")
                .and_then(Json::as_str)
                .map(str::to_string),
            error: doc
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            inputs: doc
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| bad("dlq entry missing 'inputs'".into()))?
                .iter()
                .map(|v| {
                    v.as_str().map(str::to_string).ok_or_else(|| {
                        bad("non-string dlq input path".into())
                    })
                })
                .collect::<Result<Vec<_>>>()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(r: Record) {
        let line = r.to_json().to_string_compact();
        let back = Record::decode(&line, Path::new("/j")).unwrap();
        assert_eq!(r, back, "{line}");
    }

    #[test]
    fn all_variants_roundtrip() {
        roundtrip(Record::Invocation {
            pid: 91001,
            mapper: "wordcount:/tmp/ign.txt".into(),
            reducer: Some("wordcount-reducer".into()),
            ntasks: 4,
            options: obj(vec![("input", "/in".into())]),
        });
        roundtrip(Record::JobSubmitted {
            job: 3,
            name: "wordcount".into(),
            ntasks: 2,
            task_ids: vec![1, 2],
        });
        roundtrip(Record::TaskAssigned {
            job: 3,
            idx: 0,
            task_id: 1,
            worker: Some("w0".into()),
        });
        roundtrip(Record::TaskAssigned {
            job: 3,
            idx: 1,
            task_id: 2,
            worker: None,
        });
        roundtrip(Record::TaskDone {
            job: 3,
            idx: 0,
            task_id: 1,
            retries: 2,
            dead_lettered: true,
            timing: None,
        });
        roundtrip(Record::TaskDone {
            job: 3,
            idx: 1,
            task_id: 2,
            retries: 0,
            dead_lettered: false,
            timing: Some(crate::scheduler::TaskTiming {
                started_us: 1000,
                finished_us: 9000,
                dispatch_us: 200,
                startup_us: 700,
                compute_us: 6500,
                shipped_us: 600,
                ship_out_us: Some(250),
                items: 3,
                worker: Some("w0".into()),
            }),
        });
        roundtrip(Record::TaskDone {
            job: 3,
            idx: 2,
            task_id: 3,
            retries: 0,
            dead_lettered: false,
            timing: Some(crate::scheduler::TaskTiming {
                started_us: 1000,
                finished_us: 9000,
                ..Default::default()
            }),
        });
        roundtrip(Record::TaskRetry {
            job: 3,
            idx: 0,
            task_id: 1,
            attempt: 1,
        });
        roundtrip(Record::TaskFailed {
            job: 3,
            idx: 0,
            task_id: 1,
            msg: "exit status 1".into(),
        });
        roundtrip(Record::TaskReassigned { job: 3, idx: 1, task_id: 2 });
        roundtrip(Record::JobDone { job: 3 });
        roundtrip(Record::JobFailed { job: 3, msg: "boom".into() });
        roundtrip(Record::BreakerTripped {
            job: 3,
            errors: 5,
            ntasks: 8,
            threshold: 0.25,
        });
        roundtrip(Record::Resumed { done: 2, total: 4 });
    }

    #[test]
    fn pre_pr9_done_lines_decode_without_timing() {
        // The exact shape PR-7/8 builds wrote: no "t" object.
        let r = Record::decode(
            r#"{"rec":"done","job":1,"idx":0,"task_id":1,"retries":0,"dlq":false}"#,
            Path::new("/j"),
        )
        .unwrap();
        assert_eq!(
            r,
            Record::TaskDone {
                job: 1,
                idx: 0,
                task_id: 1,
                retries: 0,
                dead_lettered: false,
                timing: None,
            }
        );
    }

    #[test]
    fn unknown_tag_decodes_as_unknown() {
        let r = Record::decode(
            "{\"rec\": \"hologram\", \"x\": 1}",
            Path::new("/j"),
        )
        .unwrap();
        assert_eq!(r, Record::Unknown { tag: "hologram".into() });
    }

    #[test]
    fn malformed_lines_are_format_errors() {
        for line in [
            "",
            "not json",
            "{\"rec\": \"done\"}",              // missing fields
            "{\"job\": 1}",                      // missing tag
            "{\"rec\": \"done\", \"job\": {}}", // wrong type
        ] {
            match Record::decode(line, Path::new("/j")) {
                Err(Error::Format { kind: "journal", .. }) => {}
                other => panic!("{line:?} -> {other:?}"),
            }
        }
    }

    #[test]
    fn dead_letter_roundtrips_and_truncates() {
        let d = DeadLetter {
            job: 1,
            task_id: 7,
            attempts: 3,
            worker: Some("w1".into()),
            error: DeadLetter::tail("exit status 1"),
            inputs: vec!["/in/a.txt".into(), "/in/b.txt".into()],
        };
        let line = d.to_json().to_string_compact();
        let back = DeadLetter::decode(&line, Path::new("/d")).unwrap();
        assert_eq!(d, back);

        let long = "x".repeat(4 * ERROR_TAIL_BYTES);
        assert_eq!(DeadLetter::tail(&long).len(), ERROR_TAIL_BYTES);
        assert!(DeadLetter::decode("nope", Path::new("/d")).is_err());
    }
}
