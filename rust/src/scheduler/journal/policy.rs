//! Error policy: what a task's *terminal execution error* does to its
//! job (DESIGN.md §8).
//!
//! Distinct from [`crate::scheduler::failure::FailurePolicy`], which
//! *injects* deterministic launch failures for testing: this policy
//! governs real application errors (non-zero exit, spawn failure,
//! panic).  The verdict runs on the engine-shared `JobTable` transition
//! path, so local and remote engines apply identical semantics.

use crate::error::{Error, Result};

/// What to do when a task's execution errors.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OnError {
    /// Fail the whole job on the first task error (historic behaviour).
    #[default]
    Stop,
    /// Re-queue the task up to `max_retries` times, then dead-letter it.
    Retry,
    /// Record the task in `dlq.jsonl` and count it complete; the job
    /// finishes without it (resubmit later via `dlq reprocess`).
    Dlq,
    /// Count the task complete and move on, recording nothing.
    Skip,
}

impl OnError {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "stop" => Ok(OnError::Stop),
            "retry" => Ok(OnError::Retry),
            "dlq" => Ok(OnError::Dlq),
            "skip" => Ok(OnError::Skip),
            other => Err(Error::opt(format!(
                "--on-error must be dlq|retry|skip|stop, got '{other}'"
            ))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            OnError::Stop => "stop",
            OnError::Retry => "retry",
            OnError::Dlq => "dlq",
            OnError::Skip => "skip",
        }
    }
}

/// Per-job error policy, attached via `JobSpec::error_policy`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ErrorPolicy {
    pub on_error: OnError,
    /// Failure-rate circuit breaker: the job is halted once more than
    /// this fraction of its tasks have terminally errored (dead-lettered
    /// or skipped).  The default `1.0` can never be exceeded, so the
    /// breaker is off unless configured.
    pub failure_threshold: f64,
    /// Error-retry budget per task under [`OnError::Retry`] (distinct
    /// from the injected-failure retry budget of `FailurePolicy`).
    pub max_retries: usize,
}

impl Default for ErrorPolicy {
    fn default() -> Self {
        ErrorPolicy {
            on_error: OnError::Stop,
            failure_threshold: 1.0,
            max_retries: 3,
        }
    }
}

impl ErrorPolicy {
    /// Has the breaker tripped with `errors` terminal errors out of
    /// `ntasks` tasks?
    pub fn breaker_tripped(&self, errors: usize, ntasks: usize) -> bool {
        ntasks > 0
            && errors as f64 / ntasks as f64 > self.failure_threshold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips() {
        for v in
            [OnError::Stop, OnError::Retry, OnError::Dlq, OnError::Skip]
        {
            assert_eq!(OnError::parse(v.as_str()).unwrap(), v);
        }
        assert_eq!(OnError::parse("DLQ").unwrap(), OnError::Dlq);
        assert!(OnError::parse("explode").is_err());
    }

    #[test]
    fn default_breaker_never_trips() {
        let p = ErrorPolicy::default();
        assert!(!p.breaker_tripped(8, 8), "errors never exceed ntasks");
        assert!(!p.breaker_tripped(0, 0));
    }

    #[test]
    fn configured_breaker_trips_past_the_fraction() {
        let p = ErrorPolicy {
            failure_threshold: 0.25,
            ..ErrorPolicy::default()
        };
        assert!(!p.breaker_tripped(2, 8), "2/8 == threshold: not past it");
        assert!(p.breaker_tripped(3, 8));
    }
}
