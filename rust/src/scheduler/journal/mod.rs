//! Crash-safe job journal (DESIGN.md §8).
//!
//! Every engine-shared `JobTable` transition — submit, task
//! assign/complete/fail/reassign, retry, job done/failed — appends one
//! fsync'd `util::json` line to `journal.jsonl` under the invocation's
//! `.MAPRED.<PID>` workdir.  Because the workdir's `Drop` cleanup never
//! runs when the coordinator process dies (SIGKILL, OOM, power loss),
//! the journal survives exactly when it is needed, and
//! `llmapreduce resume` replays it to re-run only the incomplete tasks.
//! Clean completion removes the workdir — and the journal with it.
//!
//! The writer sits *inside* the table (both `LocalEngine` and
//! `RemoteCoordinator` drive the same `JobTable`), so engines cannot
//! diverge on what gets journaled.  Append failures after creation are
//! deliberately swallowed: a full disk degrades crash *recovery*
//! (resume re-runs more tasks than strictly necessary), it must never
//! take down the live job.
//!
//! Sibling file `dlq.jsonl` is the per-job dead-letter queue: tasks
//! that exhaust their error budget under `--on-error=dlq|retry` land
//! there with full attribution instead of failing the job (see
//! [`policy::ErrorPolicy`]).

pub mod policy;
pub mod record;
pub mod replay;

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::error::{IoContext, Result};

pub use policy::{ErrorPolicy, OnError};
pub use record::{DeadLetter, Record};
pub use replay::Replay;

/// Default journal file name under the `.MAPRED.<PID>` workdir.
pub const JOURNAL_FILE: &str = "journal.jsonl";
/// Dead-letter queue file name, sibling to the journal.
pub const DLQ_FILE: &str = "dlq.jsonl";

/// Append-only, fsync'd journal writer.  Cheap to share: engines hold
/// it as `Arc<Journal>` via `JobSpec::journal`.
pub struct Journal {
    path: PathBuf,
    file: Mutex<File>,
    /// Lazily opened on the first dead-letter (most jobs never have one).
    dlq: Mutex<Option<File>>,
    fsync: bool,
}

impl std::fmt::Debug for Journal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Journal")
            .field("path", &self.path)
            .field("fsync", &self.fsync)
            .finish()
    }
}

impl Journal {
    /// Create (truncating) a fresh journal at `path`.
    pub fn create(path: impl Into<PathBuf>) -> Result<Journal> {
        let path = path.into();
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&path)
            .at(&path)?;
        Ok(Journal {
            path,
            file: Mutex::new(file),
            dlq: Mutex::new(None),
            fsync: true,
        })
    }

    /// Open an existing journal for appending (the `resume` path, which
    /// continues the same file so a resume-of-a-resume still replays).
    pub fn open_append(path: impl Into<PathBuf>) -> Result<Journal> {
        let path = path.into();
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .at(&path)?;
        Ok(Journal {
            path,
            file: Mutex::new(file),
            dlq: Mutex::new(None),
            fsync: true,
        })
    }

    /// Disable the per-record fsync (bench baseline; a crash may then
    /// lose the tail of the journal to the page cache).
    pub fn no_fsync(mut self) -> Self {
        self.fsync = false;
        self
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// `dlq.jsonl` next to the journal file.
    pub fn dlq_path(&self) -> PathBuf {
        self.path.with_file_name(DLQ_FILE)
    }

    /// Append one record: write the compact line, flush, fsync.  Errors
    /// after creation are swallowed (see module docs).
    pub fn record(&self, rec: &Record) {
        let line = rec.to_json().to_string_compact();
        let mut f = self.file.lock().unwrap_or_else(|e| e.into_inner());
        let _ = writeln!(f, "{line}");
        let _ = f.flush();
        if self.fsync {
            let _ = f.sync_data();
        }
    }

    /// Append one dead-letter entry to `dlq.jsonl` (fsync'd — the entry
    /// is the only surviving account of the failed work).
    pub fn dead_letter(&self, entry: &DeadLetter) {
        let line = entry.to_json().to_string_compact();
        let mut guard =
            self.dlq.lock().unwrap_or_else(|e| e.into_inner());
        if guard.is_none() {
            *guard = OpenOptions::new()
                .create(true)
                .append(true)
                .open(self.dlq_path())
                .ok();
        }
        if let Some(f) = guard.as_mut() {
            let _ = writeln!(f, "{line}");
            let _ = f.flush();
            let _ = f.sync_data();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-journal-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn appends_one_line_per_record() {
        let dir = tmp("append");
        let j = Journal::create(dir.join(JOURNAL_FILE)).unwrap();
        j.record(&Record::JobDone { job: 1 });
        j.record(&Record::JobDone { job: 2 });
        let text =
            std::fs::read_to_string(dir.join(JOURNAL_FILE)).unwrap();
        assert_eq!(text.lines().count(), 2);
        for line in text.lines() {
            Record::decode(line, &j.path).unwrap();
        }
    }

    #[test]
    fn open_append_continues_the_file() {
        let dir = tmp("reopen");
        let path = dir.join(JOURNAL_FILE);
        Journal::create(&path)
            .unwrap()
            .record(&Record::JobDone { job: 1 });
        Journal::open_append(&path)
            .unwrap()
            .record(&Record::Resumed { done: 1, total: 2 });
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2, "create truncates, append adds");
    }

    #[test]
    fn dead_letters_land_in_sibling_file() {
        let dir = tmp("dlq");
        let j = Journal::create(dir.join(JOURNAL_FILE)).unwrap();
        assert_eq!(j.dlq_path(), dir.join(DLQ_FILE));
        j.dead_letter(&DeadLetter {
            job: 1,
            task_id: 3,
            attempts: 2,
            worker: None,
            error: "exit status 1".into(),
            inputs: vec!["/in/a".into()],
        });
        let text = std::fs::read_to_string(j.dlq_path()).unwrap();
        let d = DeadLetter::decode(text.trim(), &j.dlq_path()).unwrap();
        assert_eq!(d.task_id, 3);
        assert_eq!(d.inputs, vec!["/in/a".to_string()]);
    }
}
