//! Journal replay: fold a record stream back into per-job completion
//! state (DESIGN.md §8).
//!
//! Replay is a pure left fold over [`Record`]s — any *prefix* of a
//! valid journal yields a consistent [`Replay`] (the property
//! `tests/properties.rs` checks).  The file loader tolerates a
//! truncated or garbage *tail* (the crash may have severed the last
//! line mid-write): decoding stops at the first undecodable line
//! provided nothing valid follows it; garbage in the *middle* of the
//! file, with valid records after it, is real corruption and surfaces
//! as `Error::Format { kind: "journal" }`.
//!
//! Completion is keyed by **task id**, not task index: a resumed run
//! re-submits only the incomplete tasks (with their original ids), so a
//! resume-of-a-resume must union completions across every `job` record
//! sharing a name.

use std::collections::{BTreeMap, HashSet};
use std::path::Path;

use crate::error::{Error, Result};
use crate::scheduler::journal::record::Record;
use crate::util::json::Json;

/// Replayed state of one journaled job.
#[derive(Debug, Clone, Default)]
pub struct ReplayedJob {
    pub name: String,
    pub ntasks: usize,
    /// Task ids this job was submitted with.
    pub task_ids: Vec<usize>,
    /// Task ids with a `done` record (includes dead-lettered ones).
    pub done: HashSet<usize>,
    /// Task ids completed as dead-letter placeholders.
    pub dead_lettered: HashSet<usize>,
    /// Retry records seen (injected + error retries).
    pub retries: usize,
    /// Task-error records seen.
    pub task_errors: usize,
    /// Reassignment records seen (remote engine only).
    pub reassigns: usize,
    /// A `job-done` record was seen.
    pub completed: bool,
    /// A `job-failed` record was seen.  Non-terminal for resume: an
    /// in-process engine drop fails live jobs on shutdown, but the
    /// per-task `done` set still tells resume what to skip.
    pub failed: Option<String>,
    /// The breaker tripped on this job.
    pub breaker: bool,
    /// Span timings off traced done records, keyed by task id with the
    /// record's retry count alongside — what `llmapreduce trace`
    /// rebuilds its offline timeline from.  Empty under `--trace=false`
    /// and on pre-PR-9 journals.  Last record wins per task id (a
    /// resume generation may re-complete a task).
    pub timings: BTreeMap<usize, (usize, crate::scheduler::TaskTiming)>,
}

/// The invocation header, when the journal has one.
#[derive(Debug, Clone)]
pub struct InvocationInfo {
    pub pid: u32,
    pub mapper: String,
    pub reducer: Option<String>,
    pub ntasks: usize,
    pub options: Json,
}

/// Folded journal state.
#[derive(Debug, Clone, Default)]
pub struct Replay {
    pub invocation: Option<InvocationInfo>,
    pub jobs: BTreeMap<u64, ReplayedJob>,
    /// Records folded (excluding skipped unknowns).
    pub records: usize,
    /// `resumed` markers seen (how many times this job was picked up).
    pub resumes: usize,
}

impl Replay {
    /// Fold one record.
    pub fn apply(&mut self, rec: Record) {
        match rec {
            Record::Unknown { .. } => return,
            Record::Invocation {
                pid,
                mapper,
                reducer,
                ntasks,
                options,
            } => {
                self.invocation = Some(InvocationInfo {
                    pid,
                    mapper,
                    reducer,
                    ntasks,
                    options,
                });
            }
            Record::JobSubmitted {
                job,
                name,
                ntasks,
                task_ids,
            } => {
                let j = self.jobs.entry(job).or_default();
                j.name = name;
                j.ntasks = ntasks;
                j.task_ids = task_ids;
            }
            Record::TaskAssigned { .. } => {}
            Record::TaskDone {
                job,
                task_id,
                dead_lettered,
                retries,
                timing,
                ..
            } => {
                let j = self.jobs.entry(job).or_default();
                j.done.insert(task_id);
                if dead_lettered {
                    j.dead_lettered.insert(task_id);
                }
                if let Some(t) = timing {
                    j.timings.insert(task_id, (retries, t));
                }
            }
            Record::TaskRetry { job, .. } => {
                self.jobs.entry(job).or_default().retries += 1;
            }
            Record::TaskFailed { job, .. } => {
                self.jobs.entry(job).or_default().task_errors += 1;
            }
            Record::TaskReassigned { job, .. } => {
                self.jobs.entry(job).or_default().reassigns += 1;
            }
            Record::JobDone { job } => {
                self.jobs.entry(job).or_default().completed = true;
            }
            Record::JobFailed { job, msg } => {
                self.jobs.entry(job).or_default().failed = Some(msg);
            }
            Record::BreakerTripped { job, .. } => {
                self.jobs.entry(job).or_default().breaker = true;
            }
            Record::Resumed { .. } => self.resumes += 1,
        }
        self.records += 1;
    }

    /// Fold journal text, tolerating a truncated/garbage tail (see
    /// module docs).  Mid-file corruption is an error.
    pub fn from_text(text: &str, path: &Path) -> Result<Replay> {
        let mut replay = Replay::default();
        let lines: Vec<&str> = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty())
            .collect();
        for (i, line) in lines.iter().enumerate() {
            match Record::decode(line, path) {
                Ok(rec) => replay.apply(rec),
                Err(e) => {
                    // A bad line is a tolerable crash artifact only if
                    // nothing decodable follows it.
                    let valid_follows = lines[i + 1..]
                        .iter()
                        .any(|l| Record::decode(l, path).is_ok());
                    if valid_follows {
                        return Err(Error::Format {
                            kind: "journal",
                            path: path.to_path_buf(),
                            reason: format!(
                                "corrupt record at line {} (valid \
                                 records follow it): {e}",
                                i + 1
                            ),
                        });
                    }
                    break;
                }
            }
        }
        Ok(replay)
    }

    /// Load and fold a journal file.
    pub fn load(path: &Path) -> Result<Replay> {
        let text =
            std::fs::read_to_string(path).map_err(|e| Error::Format {
                kind: "journal",
                path: path.to_path_buf(),
                reason: format!("unreadable journal: {e}"),
            })?;
        Replay::from_text(&text, path)
    }

    /// Union of completed task ids across every job named `name`
    /// (resume re-submits under the original job name, so a second
    /// resume sees both generations).
    pub fn done_task_ids(&self, name: &str) -> HashSet<usize> {
        self.jobs
            .values()
            .filter(|j| j.name == name)
            .flat_map(|j| j.done.iter().copied())
            .collect()
    }

    /// Union of dead-lettered task ids across every job named `name`.
    pub fn dead_lettered_task_ids(&self, name: &str) -> HashSet<usize> {
        self.jobs
            .values()
            .filter(|j| j.name == name)
            .flat_map(|j| j.dead_lettered.iter().copied())
            .collect()
    }

    /// Structural consistency — the invariant replay of *any* journal
    /// prefix must satisfy (property-tested).
    pub fn consistent(&self) -> bool {
        self.jobs.values().all(|j| {
            let ids: HashSet<usize> =
                j.task_ids.iter().copied().collect();
            // Completions stay within the submitted task-id set (when
            // the submit record made it into the prefix), never exceed
            // the task count, and dead letters are a subset of done.
            let within = j.task_ids.is_empty()
                || j.done.iter().all(|t| ids.contains(t));
            let bounded =
                j.task_ids.is_empty() || j.done.len() <= j.ntasks;
            let complete_means_full = !j.completed
                || j.task_ids.is_empty()
                || j.done.len() == j.ntasks;
            within
                && bounded
                && complete_means_full
                && j.dead_lettered.is_subset(&j.done)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lines(recs: &[Record]) -> String {
        recs.iter()
            .map(|r| r.to_json().to_string_compact())
            .collect::<Vec<_>>()
            .join("\n")
    }

    fn sample() -> Vec<Record> {
        vec![
            Record::JobSubmitted {
                job: 1,
                name: "wordcount".into(),
                ntasks: 3,
                task_ids: vec![1, 2, 3],
            },
            Record::TaskDone {
                job: 1,
                idx: 0,
                task_id: 1,
                retries: 0,
                dead_lettered: false,
                timing: Some(crate::scheduler::TaskTiming {
                    started_us: 100,
                    finished_us: 5100,
                    compute_us: 4000,
                    ..Default::default()
                }),
            },
            Record::TaskFailed {
                job: 1,
                idx: 1,
                task_id: 2,
                msg: "exit status 1".into(),
            },
            Record::TaskDone {
                job: 1,
                idx: 1,
                task_id: 2,
                retries: 0,
                dead_lettered: true,
                timing: None,
            },
            Record::TaskDone {
                job: 1,
                idx: 2,
                task_id: 3,
                retries: 1,
                dead_lettered: false,
                timing: None,
            },
            Record::JobDone { job: 1 },
        ]
    }

    #[test]
    fn full_replay_folds_done_sets() {
        let r =
            Replay::from_text(&lines(&sample()), Path::new("/j")).unwrap();
        assert!(r.consistent());
        let j = &r.jobs[&1];
        assert!(j.completed);
        assert_eq!(j.done.len(), 3);
        // Timings fold only off traced done records.
        assert_eq!(j.timings.len(), 1);
        assert_eq!(j.timings[&1].1.finished_us, 5100);
        assert_eq!(
            r.dead_lettered_task_ids("wordcount"),
            [2].into_iter().collect()
        );
        assert_eq!(
            r.done_task_ids("wordcount"),
            [1, 2, 3].into_iter().collect()
        );
    }

    #[test]
    fn every_prefix_is_consistent() {
        let recs = sample();
        for n in 0..=recs.len() {
            let r = Replay::from_text(&lines(&recs[..n]), Path::new("/j"))
                .unwrap();
            assert!(r.consistent(), "prefix of {n} records");
        }
    }

    #[test]
    fn garbage_tail_is_tolerated() {
        let text = lines(&sample()[..2]) + "\n{\"rec\": \"done\", \"jo";
        let r = Replay::from_text(&text, Path::new("/j")).unwrap();
        assert_eq!(r.records, 2, "stops at the severed line");
        assert_eq!(r.done_task_ids("wordcount").len(), 1);
    }

    #[test]
    fn mid_file_garbage_is_an_error() {
        let mut all = lines(&sample());
        let good_tail = all.split_off(all.find('\n').unwrap());
        let text = all + "\nTOTAL GARBAGE" + &good_tail;
        match Replay::from_text(&text, Path::new("/j")) {
            Err(Error::Format { kind: "journal", .. }) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_records_are_skipped() {
        let text = lines(&sample()[..1])
            + "\n{\"rec\": \"from-the-future\", \"x\": 9}";
        let r = Replay::from_text(&text, Path::new("/j")).unwrap();
        assert_eq!(r.records, 1);
        assert!(r.consistent());
    }
}
