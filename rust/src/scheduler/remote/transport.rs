//! Line-framed message transport over TCP.
//!
//! A connection is split into an owned reader half and an owned writer
//! half ([`split`]) so the coordinator can park the writer inside its
//! state mutex while a dedicated thread blocks on the reader — the two
//! halves are `TcpStream` clones of one socket.  Framing is one
//! [`Message`] per `\n`-terminated line (see
//! [`crate::scheduler::remote::protocol`]).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::scheduler::remote::protocol::{frame_err, Message};

/// Frames too long to be legitimate traffic (a runaway or hostile peer);
/// `recv` aborts the connection instead of buffering without bound.
/// Generous: a 75k-task MIMO pair list still fits.
const MAX_FRAME_BYTES: usize = 64 * 1024 * 1024;

fn wire_err(context: &str, e: std::io::Error) -> Error {
    Error::Scheduler(format!("wire {context}: {e}"))
}

/// Reading half of a connection.
pub struct LineReader {
    inner: BufReader<TcpStream>,
}

impl LineReader {
    /// Bound (or unbound, with `None`) how long `recv` may block.  The
    /// coordinator uses this during the registration handshake so a
    /// silent connection (port scanner, stray client) cannot pin its
    /// reader thread and socket forever.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) {
        let _ = self.inner.get_ref().set_read_timeout(timeout);
    }

    /// Block for the next frame.  `Ok(None)` on clean EOF (peer gone);
    /// protocol errors are [`Error::Format`], transport errors
    /// [`Error::Scheduler`].  Each read is capped by the frame budget,
    /// so a newline-less byte flood errors out instead of buffering
    /// without bound.
    pub fn recv(&mut self) -> Result<Option<Message>> {
        let mut bytes: Vec<u8> = Vec::new();
        loop {
            // Budget + 1 so an overflowing frame is detected (below)
            // rather than silently truncated at the boundary.
            let budget = (MAX_FRAME_BYTES + 1 - bytes.len()) as u64;
            let mut limited = std::io::Read::take(&mut self.inner, budget);
            match limited.read_until(b'\n', &mut bytes) {
                Ok(0) => {
                    // EOF — clean between frames, or mid-frame (peer
                    // death); either way the peer is gone.
                    return Ok(None);
                }
                Ok(_) => {
                    if bytes.len() > MAX_FRAME_BYTES {
                        return Err(frame_err(
                            "frame exceeds size limit",
                        ));
                    }
                    if bytes.last() != Some(&b'\n') {
                        // Budget boundary or transient short read
                        // without a delimiter: keep reading.
                        continue;
                    }
                    let line =
                        std::str::from_utf8(&bytes).map_err(|_| {
                            frame_err("frame is not utf-8")
                        })?;
                    if line.trim().is_empty() {
                        bytes.clear();
                        continue; // tolerate blank keep-alive lines
                    }
                    return Message::decode(line).map(Some);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                    continue
                }
                Err(e) => return Err(wire_err("read failed", e)),
            }
        }
    }
}

/// Writing half of a connection.
pub struct LineWriter {
    inner: TcpStream,
}

impl LineWriter {
    /// Send one frame (write + flush; the stream has `TCP_NODELAY` set,
    /// so small frames leave immediately).
    pub fn send(&mut self, msg: &Message) -> Result<()> {
        self.inner
            .write_all(msg.encode().as_bytes())
            .map_err(|e| wire_err("send failed", e))
    }

    /// Hard-close both halves of the connection (used by the worker's
    /// deterministic crash knob and dead-worker teardown).
    pub fn shutdown(&self) {
        let _ = self.inner.shutdown(std::net::Shutdown::Both);
    }

    /// Half-close: send FIN after any queued frames, keep reading.
    /// Coordinator shutdown uses this so the final `shutdown` frame is
    /// delivered in order — a full close could RST it away if a worker
    /// heartbeat is in flight.
    pub fn shutdown_write(&self) {
        let _ = self.inner.shutdown(std::net::Shutdown::Write);
    }
}

/// Split a stream into framed reader/writer halves, configuring the
/// socket for protocol traffic (`TCP_NODELAY`, bounded write stalls so a
/// wedged peer cannot block the coordinator forever).
pub fn split(stream: TcpStream) -> Result<(LineReader, LineWriter)> {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "<unknown>".into());
    // Streams accepted from the coordinator's nonblocking listener must
    // not inherit nonblocking mode (platform-dependent): the framing
    // below relies on blocking reads.
    stream
        .set_nonblocking(false)
        .map_err(|e| wire_err(&format!("blocking({peer})"), e))?;
    stream
        .set_nodelay(true)
        .map_err(|e| wire_err(&format!("nodelay({peer})"), e))?;
    stream
        .set_write_timeout(Some(Duration::from_secs(10)))
        .map_err(|e| wire_err(&format!("write-timeout({peer})"), e))?;
    let writer = stream
        .try_clone()
        .map_err(|e| wire_err(&format!("clone({peer})"), e))?;
    Ok((
        LineReader {
            inner: BufReader::new(stream),
        },
        LineWriter { inner: writer },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::remote::protocol::WireOutcome;
    use std::net::TcpListener;

    #[test]
    fn frames_roundtrip_over_a_real_socket() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let sender = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let (_r, mut w) = split(stream).unwrap();
            w.send(&Message::Heartbeat {
                worker_id: 1,
                sent_us: None,
                rtt_us: None,
            })
            .unwrap();
            w.send(&Message::Complete {
                job: 2,
                task_idx: 0,
                outcome: WireOutcome {
                    startup_us: 10,
                    compute_us: 20,
                    launches: 1,
                    items: 2,
                    ..Default::default()
                },
            })
            .unwrap();
            // Dropping the stream closes the connection -> clean EOF.
        });
        let (stream, _) = listener.accept().unwrap();
        let (mut r, _w) = split(stream).unwrap();
        assert_eq!(
            r.recv().unwrap(),
            Some(Message::Heartbeat {
                worker_id: 1,
                sent_us: None,
                rtt_us: None,
            })
        );
        assert!(matches!(
            r.recv().unwrap(),
            Some(Message::Complete { job: 2, .. })
        ));
        assert_eq!(r.recv().unwrap(), None, "clean EOF");
        sender.join().unwrap();
    }

    #[test]
    fn garbage_line_is_a_format_error_then_stream_continues() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let sender = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(b"this is not json\n").unwrap();
            stream
                .write_all(Message::Shutdown.encode().as_bytes())
                .unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let (mut r, _w) = split(stream).unwrap();
        let err = r.recv().unwrap_err();
        assert!(
            matches!(err, Error::Format { kind: "wire", .. }),
            "{err}"
        );
        // The framing survives a bad line: the next frame still parses.
        assert_eq!(r.recv().unwrap(), Some(Message::Shutdown));
        sender.join().unwrap();
    }
}
