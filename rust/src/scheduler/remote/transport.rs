//! Framed message transport over TCP.
//!
//! A connection is split into an owned reader half and an owned writer
//! half ([`split`]) so the coordinator can park the writer inside its
//! state mutex while a dedicated thread blocks on the reader — the two
//! halves are `TcpStream` clones of one socket.  Framing starts as one
//! [`Message`] per `\n`-terminated line (see
//! [`crate::scheduler::remote::protocol`]); after a successful
//! handshake both halves can be switched to the negotiated
//! length-prefixed binary framing with [`LineReader::set_mode`] /
//! [`LineWriter::set_mode`] (DESIGN.md §13).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::scheduler::remote::protocol::{frame_err, Message, WireMode};

/// Frames too long to be legitimate traffic (a runaway or hostile peer);
/// `recv` aborts the connection instead of buffering without bound.
/// Generous: a 75k-task MIMO pair list still fits.
const MAX_FRAME_BYTES: usize = 64 * 1024 * 1024;

fn wire_err(context: &str, e: std::io::Error) -> Error {
    Error::Scheduler(format!("wire {context}: {e}"))
}

/// Reading half of a connection.
pub struct LineReader {
    inner: BufReader<TcpStream>,
    mode: WireMode,
}

impl LineReader {
    /// Bound (or unbound, with `None`) how long `recv` may block.  The
    /// coordinator uses this during the registration handshake so a
    /// silent connection (port scanner, stray client) cannot pin its
    /// reader thread and socket forever.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) {
        let _ = self.inner.get_ref().set_read_timeout(timeout);
    }

    /// Switch framing after the (always line-JSON) handshake.
    pub fn set_mode(&mut self, mode: WireMode) {
        self.mode = mode;
    }

    /// Block for the next frame.  `Ok(None)` on clean EOF (peer gone);
    /// protocol errors are [`Error::Format`], transport errors
    /// [`Error::Scheduler`].  Each read is capped by the frame budget,
    /// so a newline-less byte flood (or an over-long binary length
    /// prefix) errors out instead of buffering without bound.
    pub fn recv(&mut self) -> Result<Option<Message>> {
        match self.mode {
            WireMode::Json => self.recv_line(),
            WireMode::Binary => self.recv_binary(),
        }
    }

    /// Binary framing: a 4-byte big-endian payload length, then the
    /// payload.  EOF before or inside a frame means the peer is gone
    /// (`Ok(None)`, matching the line framing's mid-frame EOF rule).
    fn recv_binary(&mut self) -> Result<Option<Message>> {
        let mut prefix = [0u8; 4];
        match read_exact_or_eof(&mut self.inner, &mut prefix)? {
            false => return Ok(None),
            true => {}
        }
        let len = u32::from_be_bytes(prefix) as usize;
        if len > MAX_FRAME_BYTES {
            return Err(frame_err("frame exceeds size limit"));
        }
        if len == 0 {
            return Err(frame_err("empty binary frame"));
        }
        let mut payload = vec![0u8; len];
        match read_exact_or_eof(&mut self.inner, &mut payload)? {
            false => return Ok(None),
            true => {}
        }
        Message::decode_binary(&payload).map(Some)
    }

    fn recv_line(&mut self) -> Result<Option<Message>> {
        let mut bytes: Vec<u8> = Vec::new();
        loop {
            // Budget + 1 so an overflowing frame is detected (below)
            // rather than silently truncated at the boundary.
            let budget = (MAX_FRAME_BYTES + 1 - bytes.len()) as u64;
            let mut limited = std::io::Read::take(&mut self.inner, budget);
            match limited.read_until(b'\n', &mut bytes) {
                Ok(0) => {
                    // EOF — clean between frames, or mid-frame (peer
                    // death); either way the peer is gone.
                    return Ok(None);
                }
                Ok(_) => {
                    if bytes.len() > MAX_FRAME_BYTES {
                        return Err(frame_err(
                            "frame exceeds size limit",
                        ));
                    }
                    if bytes.last() != Some(&b'\n') {
                        // Budget boundary or transient short read
                        // without a delimiter: keep reading.
                        continue;
                    }
                    let line =
                        std::str::from_utf8(&bytes).map_err(|_| {
                            frame_err("frame is not utf-8")
                        })?;
                    if line.trim().is_empty() {
                        bytes.clear();
                        continue; // tolerate blank keep-alive lines
                    }
                    return Message::decode(line).map(Some);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                    continue
                }
                Err(e) => return Err(wire_err("read failed", e)),
            }
        }
    }
}

/// Fill `buf` completely.  `Ok(false)` on EOF — clean between frames,
/// or mid-frame (peer death); either way the peer is gone, matching
/// the line framing's EOF handling.
fn read_exact_or_eof(
    r: &mut impl Read,
    buf: &mut [u8],
) -> Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => return Ok(false),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(wire_err("read failed", e)),
        }
    }
    Ok(true)
}

/// Writing half of a connection.
pub struct LineWriter {
    inner: TcpStream,
    mode: WireMode,
}

impl LineWriter {
    /// Switch framing after the (always line-JSON) handshake.
    pub fn set_mode(&mut self, mode: WireMode) {
        self.mode = mode;
    }

    /// Send one frame (write + flush; the stream has `TCP_NODELAY` set,
    /// so small frames leave immediately).
    pub fn send(&mut self, msg: &Message) -> Result<()> {
        match self.mode {
            WireMode::Json => self
                .inner
                .write_all(msg.encode().as_bytes())
                .map_err(|e| wire_err("send failed", e)),
            WireMode::Binary => {
                // One write_all for prefix + payload so a frame is a
                // single syscall on the hot path.
                let payload = msg.encode_binary();
                let mut frame =
                    Vec::with_capacity(4 + payload.len());
                frame.extend_from_slice(
                    &(payload.len() as u32).to_be_bytes(),
                );
                frame.extend_from_slice(&payload);
                self.inner
                    .write_all(&frame)
                    .map_err(|e| wire_err("send failed", e))
            }
        }
    }

    /// Hard-close both halves of the connection (used by the worker's
    /// deterministic crash knob and dead-worker teardown).
    pub fn shutdown(&self) {
        let _ = self.inner.shutdown(std::net::Shutdown::Both);
    }

    /// Half-close: send FIN after any queued frames, keep reading.
    /// Coordinator shutdown uses this so the final `shutdown` frame is
    /// delivered in order — a full close could RST it away if a worker
    /// heartbeat is in flight.
    pub fn shutdown_write(&self) {
        let _ = self.inner.shutdown(std::net::Shutdown::Write);
    }
}

/// Split a stream into framed reader/writer halves, configuring the
/// socket for protocol traffic (`TCP_NODELAY`, bounded write stalls so a
/// wedged peer cannot block the coordinator forever).
pub fn split(stream: TcpStream) -> Result<(LineReader, LineWriter)> {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "<unknown>".into());
    // Streams accepted from the coordinator's nonblocking listener must
    // not inherit nonblocking mode (platform-dependent): the framing
    // below relies on blocking reads.
    stream
        .set_nonblocking(false)
        .map_err(|e| wire_err(&format!("blocking({peer})"), e))?;
    stream
        .set_nodelay(true)
        .map_err(|e| wire_err(&format!("nodelay({peer})"), e))?;
    stream
        .set_write_timeout(Some(Duration::from_secs(10)))
        .map_err(|e| wire_err(&format!("write-timeout({peer})"), e))?;
    let writer = stream
        .try_clone()
        .map_err(|e| wire_err(&format!("clone({peer})"), e))?;
    Ok((
        LineReader {
            inner: BufReader::new(stream),
            mode: WireMode::Json,
        },
        LineWriter {
            inner: writer,
            mode: WireMode::Json,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::remote::protocol::WireOutcome;
    use std::net::TcpListener;

    #[test]
    fn frames_roundtrip_over_a_real_socket() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let sender = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let (_r, mut w) = split(stream).unwrap();
            w.send(&Message::Heartbeat {
                worker_id: 1,
                sent_us: None,
                rtt_us: None,
            })
            .unwrap();
            w.send(&Message::Complete {
                job: 2,
                task_idx: 0,
                outcome: WireOutcome {
                    startup_us: 10,
                    compute_us: 20,
                    launches: 1,
                    items: 2,
                    ..Default::default()
                },
            })
            .unwrap();
            // Dropping the stream closes the connection -> clean EOF.
        });
        let (stream, _) = listener.accept().unwrap();
        let (mut r, _w) = split(stream).unwrap();
        assert_eq!(
            r.recv().unwrap(),
            Some(Message::Heartbeat {
                worker_id: 1,
                sent_us: None,
                rtt_us: None,
            })
        );
        assert!(matches!(
            r.recv().unwrap(),
            Some(Message::Complete { job: 2, .. })
        ));
        assert_eq!(r.recv().unwrap(), None, "clean EOF");
        sender.join().unwrap();
    }

    #[test]
    fn garbage_line_is_a_format_error_then_stream_continues() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let sender = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(b"this is not json\n").unwrap();
            stream
                .write_all(Message::Shutdown.encode().as_bytes())
                .unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let (mut r, _w) = split(stream).unwrap();
        let err = r.recv().unwrap_err();
        assert!(
            matches!(err, Error::Format { kind: "wire", .. }),
            "{err}"
        );
        // The framing survives a bad line: the next frame still parses.
        assert_eq!(r.recv().unwrap(), Some(Message::Shutdown));
        sender.join().unwrap();
    }

    #[test]
    fn binary_frames_roundtrip_after_mode_switch() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let sender = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let (_r, mut w) = split(stream).unwrap();
            w.set_mode(WireMode::Binary);
            w.send(&Message::Heartbeat {
                worker_id: 9,
                sent_us: Some(123),
                rtt_us: None,
            })
            .unwrap();
            w.send(&Message::Shutdown).unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let (mut r, _w) = split(stream).unwrap();
        r.set_mode(WireMode::Binary);
        assert_eq!(
            r.recv().unwrap(),
            Some(Message::Heartbeat {
                worker_id: 9,
                sent_us: Some(123),
                rtt_us: None,
            })
        );
        assert_eq!(r.recv().unwrap(), Some(Message::Shutdown));
        assert_eq!(r.recv().unwrap(), None, "clean EOF");
        sender.join().unwrap();
    }

    #[test]
    fn overlong_binary_length_prefix_is_a_format_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let sender = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            // Claims a 4GiB-1 frame: over the budget, so the reader
            // must refuse it without trying to buffer.
            stream.write_all(&u32::MAX.to_be_bytes()).unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let (mut r, _w) = split(stream).unwrap();
        r.set_mode(WireMode::Binary);
        let err = r.recv().unwrap_err();
        assert!(
            matches!(err, Error::Format { kind: "wire", .. }),
            "{err}"
        );
        sender.join().unwrap();
    }

    #[test]
    fn truncated_binary_prefix_or_payload_is_peer_death_not_panic() {
        for partial in [
            vec![0x00u8],                    // 1 of 4 prefix bytes
            vec![0x00, 0x00, 0x00, 0x08, 1], // payload cut short
        ] {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let sender = std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                stream.write_all(&partial).unwrap();
                // Dropping the stream closes it mid-frame.
            });
            let (stream, _) = listener.accept().unwrap();
            let (mut r, _w) = split(stream).unwrap();
            r.set_mode(WireMode::Binary);
            assert_eq!(r.recv().unwrap(), None, "mid-frame EOF");
            sender.join().unwrap();
        }
    }
}
