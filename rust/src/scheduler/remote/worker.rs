//! The worker daemon: `llmapreduce worker --connect host:port --slots N`.
//!
//! A worker dials the coordinator, registers its slot count (plus its
//! preferred wire framing, see [`WireMode`]), and then executes
//! whatever [`Message::Assign`] / [`Message::AssignBatch`] frames
//! arrive: the shipped [`WireWork`] is materialized back into a real
//! [`crate::scheduler::TaskWork`] via [`crate::apps::registry`] and run
//! through the same [`crate::scheduler::exec::execute`] path the local
//! engine uses — one execution substrate, reached over two transports.
//! Completions stream back through an outbox that coalesces whatever
//! finished while the previous frame was being written into one
//! [`Message::CompleteBatch`]; a heartbeat thread beacons liveness on
//! an absolute-deadline grid in between.
//!
//! [`run_worker`] is a library function so tests and benches can host
//! workers on plain threads; the CLI subcommand is a thin wrapper.  The
//! [`WorkerConfig::fail_after`] chaos knob makes fault-tolerance tests
//! deterministic: the worker drops its connection cold upon *receiving*
//! its Nth assignment (never executing it), exactly like a machine lost
//! mid-job.

use std::collections::VecDeque;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::apps::registry::{resolve_mapper, resolve_reducer};
use crate::error::{Error, Result};
use crate::options::AppType;
use crate::scheduler::exec::execute;
use crate::scheduler::remote::protocol::{
    Message, TaskAssign, TaskComplete, WireMode, WireOutcome, WireWork,
    PROTOCOL_VERSION,
};
use crate::scheduler::remote::transport::split;
use crate::scheduler::TaskWork;

/// Everything a worker daemon needs to start.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Coordinator address (`host:port`).
    pub connect: String,
    /// Concurrent task capacity advertised to the coordinator.
    pub slots: usize,
    /// Name used for per-worker attribution in reports.
    pub name: String,
    /// Liveness beacon period (keep well under the coordinator's
    /// heartbeat timeout; the default pairing is 500ms vs 3s).
    pub heartbeat_interval: Duration,
    /// Chaos knob: drop the connection cold upon receiving the Nth
    /// assignment (1-based), which is then never executed — a
    /// deterministic stand-in for `kill -9` mid-job.  Assignments
    /// arriving inside a batch frame count individually.
    pub fail_after: Option<usize>,
    /// Preferred post-handshake framing, advertised at registration;
    /// the coordinator answers in kind (`--wire=json|binary`).
    pub wire: WireMode,
    /// Compatibility knob (tests): behave like a pre-PR-10 worker —
    /// no capability advertisement, so the coordinator sends one
    /// line-JSON frame per task and never batches or revokes.
    pub legacy: bool,
}

impl WorkerConfig {
    pub fn new(connect: impl Into<String>) -> Self {
        WorkerConfig {
            connect: connect.into(),
            slots: 1,
            name: format!("worker-{}", std::process::id()),
            heartbeat_interval: Duration::from_millis(500),
            fail_after: None,
            wire: WireMode::Json,
            legacy: false,
        }
    }

    pub fn slots(mut self, n: usize) -> Self {
        self.slots = n.max(1);
        self
    }

    pub fn name(mut self, name: impl Into<String>) -> Self {
        self.name = name.into();
        self
    }

    pub fn fail_after(mut self, n: usize) -> Self {
        self.fail_after = Some(n);
        self
    }

    pub fn wire(mut self, mode: WireMode) -> Self {
        self.wire = mode;
        self
    }

    pub fn legacy(mut self) -> Self {
        self.legacy = true;
        self
    }
}

/// Rebuild an executable [`TaskWork`] from its wire form, resolving app
/// specs through the registry.  Resolution failures surface as task
/// failures on the coordinator, naming the spec.
fn materialize(work: &WireWork) -> Result<TaskWork> {
    match work {
        WireWork::Map {
            mapper,
            pairs,
            mode,
        } => Ok(TaskWork::Map {
            app: resolve_mapper(mapper)?,
            pairs: pairs
                .iter()
                .map(|(i, o)| (i.into(), o.into()))
                .collect(),
            mode: AppType::parse(mode)?,
        }),
        WireWork::Reduce {
            reducer,
            input_dir,
            out_file,
        } => Ok(TaskWork::Reduce {
            app: resolve_reducer(reducer)?,
            input_dir: input_dir.into(),
            out_file: out_file.into(),
        }),
        WireWork::ReducePartial {
            reducer,
            files,
            out_file,
        } => Ok(TaskWork::ReducePartial {
            app: resolve_reducer(reducer)?,
            files: files.iter().map(|f| f.into()).collect(),
            out_file: out_file.into(),
        }),
        WireWork::Synthetic {
            startup_us,
            per_item_us,
            items,
            launches,
        } => Ok(TaskWork::Synthetic {
            startup: Duration::from_micros(*startup_us),
            per_item: Duration::from_micros(*per_item_us),
            items: *items,
            launches: *launches,
        }),
    }
}

/// One queued assignment: job, task index, payload, and the worker
/// clock (µs since connection epoch) when the frame was read off the
/// socket — the tracing layer's `recv_us` stamp.
type Assignment = (u64, usize, WireWork, u64);

/// Executor-pool feed: assignments queued by the read loop.
struct Queue {
    tasks: Mutex<(VecDeque<Assignment>, bool)>,
    cv: Condvar,
}

impl Queue {
    fn push(&self, item: Assignment) {
        let mut q = self.tasks.lock().unwrap_or_else(|e| e.into_inner());
        q.0.push_back(item);
        drop(q);
        self.cv.notify_one();
    }

    fn close(&self) {
        self.tasks.lock().unwrap_or_else(|e| e.into_inner()).1 = true;
        self.cv.notify_all();
    }

    /// Abrupt death: discard queued assignments too — a "killed" worker
    /// must not keep executing its backlog after dropping off the wire.
    fn abort(&self) {
        let mut q = self.tasks.lock().unwrap_or_else(|e| e.into_inner());
        q.0.clear();
        q.1 = true;
        drop(q);
        self.cv.notify_all();
    }

    fn pop(&self) -> Option<Assignment> {
        let mut q = self.tasks.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(item) = q.0.pop_front() {
                return Some(item);
            }
            if q.1 {
                return None;
            }
            q = self.cv.wait(q).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Drop a queued-but-unstarted assignment (an idle peer stole it
    /// and the coordinator revoked our copy); a no-op if a slot
    /// already picked it up — the coordinator's ownership gate drops
    /// whichever completion loses the race.
    fn remove(&self, job: u64, task_idx: usize) {
        let mut q = self.tasks.lock().unwrap_or_else(|e| e.into_inner());
        q.0.retain(|(j, t, _, _)| !(*j == job && *t == task_idx));
    }
}

/// Result outbox: executors park replies here and a dedicated sender
/// thread flushes them.  Whatever accumulated while the previous frame
/// was on the wire goes out as one [`Message::CompleteBatch`] (when
/// the coordinator negotiated the capability) — natural coalescing
/// under load with zero added latency when idle, since a lone result
/// is sent the moment it lands.
struct Outbox {
    items: Mutex<(Vec<Message>, bool)>,
    cv: Condvar,
}

impl Outbox {
    fn push(&self, m: Message) {
        let mut o = self.items.lock().unwrap_or_else(|e| e.into_inner());
        o.0.push(m);
        drop(o);
        self.cv.notify_one();
    }

    fn close(&self) {
        self.items.lock().unwrap_or_else(|e| e.into_inner()).1 = true;
        self.cv.notify_all();
    }

    /// Block until something is pending; `None` once closed and empty.
    fn drain(&self) -> Option<Vec<Message>> {
        let mut o = self.items.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if !o.0.is_empty() {
                return Some(std::mem::take(&mut o.0));
            }
            if o.1 {
                return None;
            }
            o = self.cv.wait(o).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// Fold one outbox flush: completions collapse into a single batch
/// frame when the coordinator understands them; failures (and lone
/// completions) always travel as their own frame.
fn coalesce(flush: Vec<Message>, batching: bool) -> Vec<Message> {
    if !batching || flush.len() < 2 {
        return flush;
    }
    let mut out = Vec::new();
    let mut done: Vec<TaskComplete> = Vec::new();
    for m in flush {
        match m {
            Message::Complete {
                job,
                task_idx,
                outcome,
            } => done.push(TaskComplete {
                job,
                task_idx,
                outcome,
            }),
            other => out.push(other),
        }
    }
    match done.len() {
        0 => {}
        1 => {
            let c = done.remove(0);
            out.push(Message::Complete {
                job: c.job,
                task_idx: c.task_idx,
                outcome: c.outcome,
            });
        }
        _ => out.push(Message::CompleteBatch { done }),
    }
    out
}

/// Next beacon deadline on the absolute grid anchored at the previous
/// one.  Work and lock waits inside a tick no longer stretch the
/// period (the old `sleep(interval)`-after-work loop drifted past the
/// configured rate under load), and a stall that blows through several
/// deadlines skips the missed ticks instead of bursting to catch up.
fn next_tick(
    prev: Instant,
    interval: Duration,
    now: Instant,
) -> Instant {
    let mut next = prev + interval;
    while next <= now {
        next += interval;
    }
    next
}

/// Execute one assignment and park the result in the outbox for the
/// sender thread to ship (batched with whatever else finished).
fn execute_assignment(
    outbox: &Outbox,
    epoch: Instant,
    job: u64,
    task_idx: usize,
    work: &WireWork,
    recv_us: u64,
) {
    let exec_start_us = epoch.elapsed().as_micros() as u64;
    let result = materialize(work).and_then(|w| {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            execute(&w)
        }))
        .unwrap_or_else(|panic| {
            let msg = crate::scheduler::exec::panic_message(panic);
            Err(Error::Scheduler(format!("payload panicked: {msg}")))
        })
    });
    let reply = match result {
        Ok(out) => Message::Complete {
            job,
            task_idx,
            outcome: WireOutcome {
                startup_us: out.startup.as_micros() as u64,
                compute_us: out.compute.as_micros() as u64,
                launches: out.launches,
                items: out.items,
                recv_us: Some(recv_us),
                exec_start_us: Some(exec_start_us),
                exec_end_us: Some(epoch.elapsed().as_micros() as u64),
            },
        },
        Err(e) => Message::Failed {
            job,
            task_idx,
            msg: e.to_string(),
        },
    };
    outbox.push(reply);
}

/// Dial the coordinator, retrying for a grace period — workers and the
/// coordinator are started concurrently (a CI script backgrounds the
/// daemons before `llmapreduce run --engine=remote` binds), so a
/// connection-refused right at boot is expected, not fatal.
fn connect_with_retry(addr: &str) -> Result<TcpStream> {
    let deadline = std::time::Instant::now() + Duration::from_secs(15);
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => return Ok(stream),
            Err(e) => {
                if std::time::Instant::now() >= deadline {
                    return Err(Error::Scheduler(format!(
                        "worker connect {addr}: {e}"
                    )));
                }
                std::thread::sleep(Duration::from_millis(200));
            }
        }
    }
}

/// Run a worker daemon until the coordinator shuts it down (or the
/// connection dies, or [`WorkerConfig::fail_after`] fires).  Blocking;
/// host it on a thread for in-process fleets.
pub fn run_worker(config: WorkerConfig) -> Result<()> {
    let stream = connect_with_retry(&config.connect)?;
    // Connection epoch: the zero point of every monotonic stamp this
    // worker puts on the wire (heartbeat `sent_us`, outcome `recv_us` /
    // `exec_start_us` / `exec_end_us`).  The coordinator aligns them to
    // its own clock via the heartbeat-RTT offset estimate (DESIGN.md
    // §12).
    let epoch = Instant::now();
    let (mut reader, writer) = split(stream)?;
    let writer = Arc::new(Mutex::new(writer));

    // Handshake — always line-JSON.  A non-legacy worker advertises
    // its preferred framing; the framing actually used is whatever the
    // coordinator echoes back (an old coordinator echoes nothing, so
    // we stay on per-task line-JSON and it never batches to us).
    writer
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .send(&Message::Register {
            name: config.name.clone(),
            slots: config.slots,
            version: PROTOCOL_VERSION,
            wire: (!config.legacy).then_some(config.wire),
        })?;
    let (worker_id, granted) = match reader.recv()? {
        Some(Message::Registered { worker_id, wire }) => {
            (worker_id, wire)
        }
        other => {
            return Err(Error::Scheduler(format!(
                "worker handshake: expected registered, got {other:?}"
            )))
        }
    };
    // A `wire` answer marks a batch-capable coordinator: completions
    // may coalesce into CompleteBatch frames.
    let batching = granted.is_some();
    if granted == Some(WireMode::Binary) {
        reader.set_mode(WireMode::Binary);
        writer
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .set_mode(WireMode::Binary);
    }

    // Heartbeat thread.  Each beacon carries its own send time and the
    // round-trip measured off the last ack (0 = none seen yet, sent as
    // absent); the read loop updates `rtt_us` when acks arrive.
    // Beacons tick on an absolute-deadline grid (`next_tick`) so send
    // and lock time cannot stretch the effective period past the
    // configured interval.
    let stop = Arc::new(AtomicBool::new(false));
    let rtt_us = Arc::new(AtomicU64::new(0));
    let beat = {
        let writer = writer.clone();
        let stop = stop.clone();
        let rtt_us = rtt_us.clone();
        let interval = config.heartbeat_interval;
        std::thread::spawn(move || {
            let mut deadline = Instant::now() + interval;
            loop {
                let now = Instant::now();
                if now < deadline {
                    std::thread::sleep(deadline - now);
                }
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                deadline =
                    next_tick(deadline, interval, Instant::now());
                let rtt = rtt_us.load(Ordering::Relaxed);
                let sent = writer
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .send(&Message::Heartbeat {
                        worker_id,
                        sent_us: Some(epoch.elapsed().as_micros() as u64),
                        rtt_us: (rtt > 0).then_some(rtt),
                    });
                if sent.is_err() {
                    break; // coordinator gone; read loop exits too
                }
            }
        })
    };

    // Executor pool + result outbox/sender.
    let queue = Arc::new(Queue {
        tasks: Mutex::new((VecDeque::new(), false)),
        cv: Condvar::new(),
    });
    let outbox = Arc::new(Outbox {
        items: Mutex::new((Vec::new(), false)),
        cv: Condvar::new(),
    });
    let executors: Vec<_> = (0..config.slots.max(1))
        .map(|_| {
            let queue = queue.clone();
            let outbox = outbox.clone();
            std::thread::spawn(move || {
                while let Some((job, task_idx, work, recv_us)) =
                    queue.pop()
                {
                    execute_assignment(
                        &outbox, epoch, job, task_idx, &work, recv_us,
                    );
                }
            })
        })
        .collect();
    let sender = {
        let outbox = outbox.clone();
        let writer = writer.clone();
        std::thread::spawn(move || {
            while let Some(flush) = outbox.drain() {
                for msg in coalesce(flush, batching) {
                    let sent = writer
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .send(&msg);
                    if sent.is_err() {
                        return; // coordinator gone; read loop notices
                    }
                }
            }
        })
    };

    // Read loop.  Chaos + enqueue for one or many assignments; returns
    // true when the fail_after knob tripped and the connection dropped.
    let mut received = 0usize;
    let enqueue = |tasks: Vec<TaskAssign>, received: &mut usize| {
        let recv_us = epoch.elapsed().as_micros() as u64;
        for t in tasks {
            *received += 1;
            if config.fail_after.is_some_and(|n| *received >= n) {
                // Chaos: vanish without executing this assignment (or
                // anything still queued).  The coordinator sees the
                // socket drop and reassigns.
                queue.abort();
                writer
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .shutdown();
                return true;
            }
            queue.push((t.job, t.task_idx, t.work, recv_us));
        }
        false
    };
    let outcome = loop {
        match reader.recv() {
            Ok(Some(Message::Assign {
                job,
                task_idx,
                task_id,
                work,
            })) => {
                let one = vec![TaskAssign {
                    job,
                    task_idx,
                    task_id,
                    work,
                }];
                if enqueue(one, &mut received) {
                    break Ok(());
                }
            }
            Ok(Some(Message::AssignBatch { tasks })) => {
                if enqueue(tasks, &mut received) {
                    break Ok(());
                }
            }
            Ok(Some(Message::Revoke { job, task_idx })) => {
                queue.remove(job, task_idx);
            }
            Ok(Some(Message::HeartbeatAck { echo_us })) => {
                // Round trip = now minus the beacon's send stamp; the
                // next heartbeat reports it to the offset estimator.
                let now_us = epoch.elapsed().as_micros() as u64;
                rtt_us.store(
                    now_us.saturating_sub(echo_us).max(1),
                    Ordering::Relaxed,
                );
            }
            Ok(Some(Message::Shutdown)) | Ok(None) => break Ok(()),
            Ok(Some(_)) => {} // nothing else is worker-bound; ignore
            Err(e) => break Err(e),
        }
    };

    // Wind down: stop the beacon, drain executors, flush the outbox,
    // close the socket.
    stop.store(true, Ordering::Relaxed);
    queue.close();
    for h in executors {
        let _ = h.join();
    }
    outbox.close();
    let _ = sender.join();
    writer.lock().unwrap_or_else(|e| e.into_inner()).shutdown();
    let _ = beat.join();
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn materialize_roundtrips_builtin_specs() {
        let w = materialize(&WireWork::Map {
            mapper: "wordcount".into(),
            pairs: vec![("a".into(), "a.out".into())],
            mode: "mimo".into(),
        })
        .unwrap();
        match w {
            TaskWork::Map { app, pairs, mode } => {
                assert_eq!(app.name(), "wordcount");
                assert_eq!(pairs.len(), 1);
                assert_eq!(mode, AppType::Mimo);
            }
            other => panic!("wrong work: {other:?}"),
        }
        // Ganged map tasks keep their mode across the wire, and an
        // unknown mode is an error, not a silent SISO downgrade.
        let g = materialize(&WireWork::Map {
            mapper: "stream:cat".into(),
            pairs: vec![("a".into(), "a.out".into())],
            mode: "spmd".into(),
        })
        .unwrap();
        match g {
            TaskWork::Map { app, mode, .. } => {
                assert_eq!(app.wire_spec(), "stream:cat");
                assert_eq!(mode, AppType::Spmd);
            }
            other => panic!("wrong work: {other:?}"),
        }
        assert!(materialize(&WireWork::Map {
            mapper: "cat".into(),
            pairs: vec![],
            mode: "warp".into(),
        })
        .is_err());
        let s = materialize(&WireWork::Synthetic {
            startup_us: 1000,
            per_item_us: 10,
            items: 4,
            launches: 2,
        })
        .unwrap();
        match s {
            TaskWork::Synthetic {
                startup, launches, ..
            } => {
                assert_eq!(startup, Duration::from_millis(1));
                assert_eq!(launches, 2);
            }
            other => panic!("wrong work: {other:?}"),
        }
    }

    #[test]
    fn unresolvable_spec_is_an_error_not_a_panic() {
        // Empty spec cannot resolve even as an external command.
        assert!(materialize(&WireWork::Reduce {
            reducer: "".into(),
            input_dir: "d".into(),
            out_file: "f".into(),
        })
        .is_err());
    }

    #[test]
    fn config_builder() {
        let c = WorkerConfig::new("127.0.0.1:7171")
            .slots(0)
            .name("w0")
            .fail_after(2)
            .wire(WireMode::Binary);
        assert_eq!(c.slots, 1, "slots clamp to >= 1");
        assert_eq!(c.name, "w0");
        assert_eq!(c.fail_after, Some(2));
        assert_eq!(c.wire, WireMode::Binary);
        assert!(!c.legacy);
        assert!(WorkerConfig::new("x").legacy().legacy);
    }

    #[test]
    fn heartbeat_deadlines_stay_on_the_absolute_grid() {
        let t0 = Instant::now();
        let iv = Duration::from_millis(500);
        // Work inside a tick does not stretch the period: the next
        // deadline is still exactly one interval past the previous
        // one, not one interval past "now".
        assert_eq!(
            next_tick(t0, iv, t0 + Duration::from_millis(137)),
            t0 + iv
        );
        // No cumulative drift either: after N busy ticks the deadline
        // sits exactly N intervals from the anchor.
        let mut d = t0;
        for k in 1..=10u32 {
            d = next_tick(d, iv, d + Duration::from_millis(320));
            assert_eq!(d, t0 + iv * k);
        }
        // A stall that blows through several deadlines skips the
        // missed ticks (stays on the grid) instead of bursting.
        assert_eq!(
            next_tick(t0, iv, t0 + Duration::from_millis(1730)),
            t0 + iv * 4
        );
    }

    fn done(job: u64, task_idx: usize) -> Message {
        Message::Complete {
            job,
            task_idx,
            outcome: WireOutcome::default(),
        }
    }

    #[test]
    fn outbox_flushes_coalesce_completions_only_when_negotiated() {
        let failed = Message::Failed {
            job: 1,
            task_idx: 2,
            msg: "x".into(),
        };
        // Capability on: several completions fold into one batch
        // frame; failures still travel alone.
        let out = coalesce(
            vec![done(1, 0), failed.clone(), done(1, 1)],
            true,
        );
        assert_eq!(
            out,
            vec![
                failed.clone(),
                Message::CompleteBatch {
                    done: vec![
                        TaskComplete {
                            job: 1,
                            task_idx: 0,
                            outcome: WireOutcome::default(),
                        },
                        TaskComplete {
                            job: 1,
                            task_idx: 1,
                            outcome: WireOutcome::default(),
                        },
                    ],
                },
            ]
        );
        // A lone completion never pays the batch envelope.
        assert_eq!(
            coalesce(vec![done(1, 0), failed.clone()], true),
            vec![failed.clone(), done(1, 0)]
        );
        // Capability off (legacy coordinator): frames pass through
        // untouched, in order.
        assert_eq!(
            coalesce(vec![done(1, 0), done(1, 1)], false),
            vec![done(1, 0), done(1, 1)]
        );
    }
}
