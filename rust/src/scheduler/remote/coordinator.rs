//! The coordinator: a network-fronted [`Engine`].
//!
//! `RemoteCoordinator` binds a TCP listener, accepts worker-daemon
//! registrations ([`crate::scheduler::remote::worker`]), and schedules
//! submitted jobs over the fleet.  Because it implements the same
//! [`Engine`] trait as the local engine — `&self` submit, blocking
//! `wait`, non-blocking `try_wait` — everything above it (`Session`,
//! `pipeline::run`, overlap dispatch, nested multi-level fan-out) works
//! over the network unchanged.
//!
//! # Scheduling
//!
//! Dependency semantics live in the engine-shared
//! `scheduler::table::JobTable`; this module adds placement:
//! eligible tasks queue in `ready`, and each `try_assign` round drains
//! them into per-worker buffers — least-loaded worker first (lowest id
//! on ties), with an affinity bonus for a worker already holding the
//! task's job siblings or input shard — then flushes each worker's
//! buffer as one `AssignBatch` frame (one write+flush per worker per
//! round instead of per task; DESIGN.md §13 has the drain rule).
//! Batch-capable workers are intentionally overcommitted, so when the
//! central queue runs dry an idle worker *steals* queued-but-unstarted
//! tasks back from the most-backlogged peer (the victim gets a
//! `Revoke` per stolen task).  Legacy workers that never advertised
//! the capability keep the one-line-JSON-frame-per-task protocol.
//! Failure injection runs **coordinator-side** against the
//! engine-shared [`FailurePolicy`] *before* a task ships, so per-task
//! retry counts replay identically across `--engine=local|sim|remote`.
//!
//! # Fault tolerance
//!
//! Every shipped task is tracked in `assigned`.  A worker is declared
//! dead on connection EOF/error or when its heartbeat lapses past
//! `heartbeat_timeout`; its in-flight tasks go back to the *front* of
//! the ready queue (they have waited longest) and their
//! [`TaskReport::reassigned`] count increments.  Task payloads re-execute
//! idempotently — mappers and reducers rewrite their output files — so a
//! task that was half-finished on a dead worker simply runs again
//! elsewhere.  A completion racing in from a worker already declared
//! dead is accepted (the job table de-duplicates), never double-counted.
//! Losing the *whole* fleet fails every live job with a clear error
//! rather than blocking `wait()` on capacity that may never return.
//!
//! # Known limitation
//!
//! Assignment frames are sent while holding the state mutex, so one
//! wedged worker socket can stall the coordinator for up to the
//! transport's 10s write timeout per frame (after which the worker is
//! declared dead).  Fine for the localhost fleets this targets; a
//! per-worker outbox thread is the fix if WAN-scale workers arrive.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::scheduler::failure::FailurePolicy;
use crate::scheduler::remote::protocol::{
    Message, TaskAssign, WireMode, WireWork, PROTOCOL_VERSION,
};
use crate::scheduler::remote::transport::{split, LineWriter};
use crate::scheduler::table::{ErrorAction, JobTable, Outcome, TaskView};
use crate::scheduler::{Engine, JobId, JobReport, JobSpec, TaskReport};
use crate::telemetry::{Collector, Event, EventBus, MetricsListener};

/// Tuning knobs of the coordinator (defaults suit localhost fleets).
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// A worker silent for longer than this is declared dead and its
    /// in-flight tasks reassigned.  Workers beacon at ~1/4 this rate.
    pub heartbeat_timeout: Duration,
    /// Failure injection (engine-shared semantics; see module docs).
    pub policy: FailurePolicy,
    /// `host:port` to serve `/metrics` (Prometheus text) and `/status`
    /// (JSON) on while the coordinator lives (`--metrics-listen`).
    /// `None` (the default) serves nothing.
    pub metrics_listen: Option<String>,
    /// Ship multiple ready tasks to a batch-capable worker in one
    /// `AssignBatch` frame, overcommitting its queue (`--batch-frames`).
    /// Off, every worker gets one frame per task and never more tasks
    /// than slots.
    pub batch_frames: bool,
    /// Let an idle worker pull queued-but-unstarted tasks from the
    /// most-backlogged peer when the central queue is dry (`--steal`).
    pub steal: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            heartbeat_timeout: Duration::from_secs(3),
            policy: FailurePolicy::default(),
            metrics_listen: None,
            batch_frames: true,
            steal: true,
        }
    }
}

/// One shipped task.
struct Assigned {
    worker: u64,
    sent_at: Instant,
    dispatch_wait: Duration,
    attempt: usize,
    /// Slots charged on the worker (1, or all of them for exclusive
    /// whole-node tasks — the sim's `--exclusive` semantics).
    need: usize,
}

/// Coordinator-side state of one registered worker.
struct WorkerState {
    name: String,
    slots: usize,
    writer: LineWriter,
    in_flight: Vec<(JobId, usize)>,
    /// Slots currently charged.  Exclusive tasks charge the whole
    /// worker; batch shipping overcommits capable workers, so this can
    /// exceed `slots` (the excess is the worker-local backlog).
    used: usize,
    /// Peer advertised `Register.wire` — understands `AssignBatch`,
    /// `CompleteBatch` and `Revoke`.  Legacy peers stay frame-per-task.
    capable: bool,
    /// An exclusive task is in flight: the node is reserved whole, no
    /// other work may be co-resident until it finishes.
    reserved: bool,
    /// Recently assigned affinity keys (job + input shard), bounded;
    /// placement prefers a near-least-loaded worker that already holds
    /// a task's key (warm per-task app instances, warm input shards).
    affinity: Vec<u64>,
    last_seen: Instant,
    alive: bool,
    /// NTP-style clock-offset estimate: add this to a worker-clock
    /// stamp (µs since the worker's connection epoch) to land on the
    /// coordinator's epoch timeline.  Refined from heartbeat RTTs with
    /// a min-RTT filter (least queuing noise wins); `None` until the
    /// first stamped beacon arrives (pre-PR-9 workers never stamp).
    offset_us: Option<i64>,
    /// Smallest heartbeat round-trip seen, the filter for `offset_us`.
    min_rtt_us: u64,
}

struct Core {
    table: JobTable,
    ready: VecDeque<(JobId, usize)>,
    workers: HashMap<u64, WorkerState>,
    assigned: HashMap<(JobId, usize), Assigned>,
    /// Reassignment counts for in-flight tasks (moved into the report).
    reassigns: HashMap<(JobId, usize), usize>,
    next_worker_id: u64,
    shutdown: bool,
    /// Zero point of the coordinator's µs timeline; worker stamps are
    /// aligned onto it via each worker's `offset_us`.
    epoch: Instant,
    /// Engine-scoped telemetry bus ([`Engine::event_bus`]): jobs this
    /// coordinator runs publish their transitions here, plus worker
    /// lifecycle and queue-depth samples.  Free when nobody subscribed.
    bus: Arc<EventBus>,
    /// Last published queue depth (samples only on change).
    last_depth: usize,
}

impl Core {
    fn alive_slots(&self) -> usize {
        self.workers
            .values()
            .filter(|w| w.alive)
            .map(|w| w.slots)
            .sum()
    }

    fn alive_workers(&self) -> usize {
        self.workers.values().filter(|w| w.alive).count()
    }

    /// Publish the ready-queue depth when it changed since the last
    /// sample (placement rounds leave it untouched most of the time).
    fn sample_queue_depth(&mut self) {
        let depth = self.ready.len();
        if depth != self.last_depth {
            self.last_depth = depth;
            self.bus.emit(Event::QueueDepth { depth });
        }
    }
}

struct Inner {
    state: Mutex<Core>,
    /// Wakes `wait()`ers when any job reaches an outcome.
    done_cv: Condvar,
    /// Wakes `wait_for_workers` (and the monitor's shutdown poll).
    workers_cv: Condvar,
    config: CoordinatorConfig,
}

impl Inner {
    fn lock(&self) -> MutexGuard<'_, Core> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// The distributed engine front (see module docs).
pub struct RemoteCoordinator {
    inner: Arc<Inner>,
    next_id: AtomicU64,
    local_addr: SocketAddr,
    accept_thread: Option<JoinHandle<()>>,
    monitor_thread: Option<JoinHandle<()>>,
    /// The engine's telemetry bus (shared with `Core`).
    bus: Arc<EventBus>,
    /// `--metrics-listen` endpoint; the collector behind it stays
    /// subscribed to `bus` for the coordinator's lifetime.
    metrics: Option<MetricsListener>,
}

impl RemoteCoordinator {
    /// Bind the listener (e.g. `"127.0.0.1:0"` for an ephemeral port)
    /// and start accepting workers.  Jobs may be submitted immediately;
    /// their tasks wait in queue until capacity registers.
    pub fn bind(addr: &str, config: CoordinatorConfig) -> Result<Self> {
        let listener = TcpListener::bind(addr).map_err(|e| {
            Error::Scheduler(format!("coordinator bind {addr}: {e}"))
        })?;
        let local_addr = listener.local_addr().map_err(|e| {
            Error::Scheduler(format!("coordinator addr: {e}"))
        })?;
        listener.set_nonblocking(true).map_err(|e| {
            Error::Scheduler(format!("coordinator nonblocking: {e}"))
        })?;
        let bus = Arc::new(EventBus::new());
        // `--metrics-listen`: a collector folds the bus into a registry
        // the endpoint serves.  Bound before any worker can register so
        // no lifecycle event is missed.
        let metrics = match &config.metrics_listen {
            Some(listen) => {
                let collector = Arc::new(Collector::new());
                bus.subscribe(collector.clone());
                Some(MetricsListener::bind(listen, collector)?)
            }
            None => None,
        };
        let inner = Arc::new(Inner {
            state: Mutex::new(Core {
                table: JobTable::new(1),
                ready: VecDeque::new(),
                workers: HashMap::new(),
                assigned: HashMap::new(),
                reassigns: HashMap::new(),
                next_worker_id: 1,
                shutdown: false,
                epoch: Instant::now(),
                bus: bus.clone(),
                last_depth: 0,
            }),
            done_cv: Condvar::new(),
            workers_cv: Condvar::new(),
            config,
        });
        let accept_thread = {
            let inner = inner.clone();
            Some(std::thread::spawn(move || accept_loop(&inner, listener)))
        };
        let monitor_thread = {
            let inner = inner.clone();
            Some(std::thread::spawn(move || monitor_loop(&inner)))
        };
        Ok(RemoteCoordinator {
            inner,
            next_id: AtomicU64::new(1),
            local_addr,
            accept_thread,
            monitor_thread,
            bus,
            metrics,
        })
    }

    /// The bound address (useful with an ephemeral `:0` bind).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Where `/metrics` and `/status` are served, when
    /// [`CoordinatorConfig::metrics_listen`] was set.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics.as_ref().map(|m| m.local_addr())
    }

    /// Currently-alive worker count.
    pub fn workers(&self) -> usize {
        self.inner.lock().alive_workers()
    }

    /// Block until at least `n` workers are registered and alive, or
    /// `timeout` elapses (error).  Spawn workers first or concurrently.
    pub fn wait_for_workers(
        &self,
        n: usize,
        timeout: Duration,
    ) -> Result<usize> {
        let deadline = Instant::now() + timeout;
        let mut core = self.inner.lock();
        loop {
            let alive = core.alive_workers();
            if alive >= n {
                return Ok(alive);
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(Error::Scheduler(format!(
                    "only {alive}/{n} workers registered within \
                     {timeout:?} (is `llmapreduce worker --connect {}` \
                     running?)",
                    self.local_addr
                )));
            }
            let (guard, _) = self
                .inner
                .workers_cv
                .wait_timeout(core, left)
                .unwrap_or_else(|e| e.into_inner());
            core = guard;
        }
    }
}

impl Engine for RemoteCoordinator {
    fn name(&self) -> &'static str {
        "remote"
    }

    fn event_bus(&self) -> Option<Arc<EventBus>> {
        Some(self.bus.clone())
    }

    fn submit(&self, spec: JobSpec) -> Result<JobId> {
        let mut core = self.inner.lock();
        crate::scheduler::validate_submit(&spec, |dep| {
            core.table.ntasks(dep)
        })?;
        let id = JobId(self.next_id.fetch_add(1, Ordering::Relaxed));
        let ready = core.table.admit(id, spec, Instant::now());
        core.ready.extend(ready);
        try_assign(&mut core, &self.inner.config);
        core.sample_queue_depth();
        drop(core);
        // Admission may complete zero-task jobs outright.
        self.inner.done_cv.notify_all();
        Ok(id)
    }

    fn wait(&self, id: JobId) -> Result<JobReport> {
        let mut core = self.inner.lock();
        loop {
            match core.table.outcome(id) {
                Outcome::Done(r) => return Ok(r.clone()),
                Outcome::Failed(msg) => {
                    return Err(Error::Scheduler(msg.to_string()))
                }
                Outcome::Running => {}
                Outcome::Unknown => {
                    return Err(Error::Scheduler(format!(
                        "unknown job {id}"
                    )))
                }
            }
            core = self
                .inner
                .done_cv
                .wait(core)
                .unwrap_or_else(|e| e.into_inner());
        }
    }

    fn try_wait(&self, id: JobId) -> Result<Option<JobReport>> {
        let core = self.inner.lock();
        match core.table.outcome(id) {
            Outcome::Done(r) => Ok(Some(r.clone())),
            Outcome::Failed(msg) => Err(Error::Scheduler(msg.to_string())),
            Outcome::Running => Ok(None),
            Outcome::Unknown => {
                Err(Error::Scheduler(format!("unknown job {id}")))
            }
        }
    }
}

impl Drop for RemoteCoordinator {
    fn drop(&mut self) {
        {
            let mut core = self.inner.lock();
            core.shutdown = true;
            for w in core.workers.values_mut() {
                let _ = w.writer.send(&Message::Shutdown);
                // Half-close so the shutdown frame is delivered in
                // order; the worker closes its side on receipt, which
                // unblocks our reader thread with a clean EOF.
                w.writer.shutdown_write();
            }
        }
        self.inner.workers_cv.notify_all();
        self.inner.done_cv.notify_all();
        if let Some(h) = self.monitor_thread.take() {
            let _ = h.join();
        }
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Placement
// ---------------------------------------------------------------------------

/// Affinity keys of a task: the job it belongs to (SPMD gang siblings
/// warm the same persistent per-task app instances) and, when the work
/// names input files, the input shard they live in (directory
/// locality).  Keys are opaque u64s matched for equality only.
fn affinity_keys(jid: JobId, view: &TaskView, idx: usize) -> Vec<u64> {
    // Golden-ratio spread so small job ids don't collide with the
    // FNV-space shard hashes.
    let mut keys = vec![jid.0.wrapping_mul(0x9E37_79B9_7F4A_7C15)];
    if let Some(k) = view.shard_key(idx) {
        keys.push(k);
    }
    keys
}

/// Remember keys a worker now holds (bounded, oldest evicted first).
fn note_affinity(worker: &mut WorkerState, keys: &[u64]) {
    const CAP: usize = 128;
    for &k in keys {
        if !worker.affinity.contains(&k) {
            if worker.affinity.len() >= CAP {
                worker.affinity.remove(0);
            }
            worker.affinity.push(k);
        }
    }
}

/// Pick a worker for one task: among eligible workers whose load is
/// within one task of the minimum, prefer an affinity hit, then least
/// loaded, then lowest id (deterministic spread across equal workers).
/// Returns `(worker_id, slots_to_charge)`.
///
/// Eligibility: exclusive tasks need an idle, unreserved worker and
/// charge all of its slots (the whole-node `--exclusive` semantics the
/// simulator models).  Plain tasks need a free slot — unless batch
/// framing is on and the peer is batch-capable, in which case it may be
/// overcommitted (the excess queues worker-side and is steal-able).
fn pick_worker(
    core: &Core,
    exclusive: bool,
    keys: &[u64],
    batching: bool,
) -> Option<(u64, usize)> {
    let eligible = |w: &WorkerState| {
        w.alive
            && !w.reserved
            && if exclusive {
                w.used == 0
            } else {
                (batching && w.capable) || w.used < w.slots
            }
    };
    let min_used = core
        .workers
        .values()
        .filter(|w| eligible(w))
        .map(|w| w.used)
        .min()?;
    core.workers
        .iter()
        .filter(|(_, w)| eligible(w) && w.used <= min_used + 1)
        .min_by_key(|(id, w)| {
            let hit = keys.iter().any(|k| w.affinity.contains(k));
            (!hit, w.used, **id)
        })
        .map(|(id, w)| (*id, if exclusive { w.slots } else { 1 }))
}

/// Ship ready tasks to free capacity until one side runs dry.  Runs
/// under the core lock (writers live inside it; sends are bounded by
/// the transport's write timeout).
///
/// Each round has three phases: (1) drain the ready queue into
/// per-worker buffers, registering coordinator state immediately; (2)
/// if the queue ran dry, let idle workers steal from backlogged peers;
/// (3) flush each worker's buffer — one `AssignBatch` frame for a
/// batch-capable worker (one write+flush instead of N), frame-per-task
/// for legacy peers.  A send failure marks that worker dead, which
/// requeues everything it held (buffered tasks included, since state
/// was registered up front), and the round restarts.
fn try_assign(core: &mut Core, config: &CoordinatorConfig) {
    let policy = &config.policy;
    loop {
        let mut pending: BTreeMap<u64, Vec<TaskAssign>> = BTreeMap::new();
        let mut revokes: Vec<(u64, u64, usize)> = Vec::new();

        // Phase 1: drain.
        loop {
            let Some((jid, idx)) = core.ready.pop_front() else { break };
            // Stale entries (job already failed/completed) drop here.
            let Some(view) = core.table.view(jid, idx) else { continue };
            let task = &view.tasks[idx];

            // Engine-shared failure injection: the attempt "crashes at
            // launch" before it ever ships — consumed a retry,
            // re-enters the queue; identical (seed, task, attempt)
            // accounting to the local engine and the simulator.
            if policy.should_fail(task.task_id, view.attempt) {
                if core.table.bump_attempt(jid, idx) {
                    core.ready.push_back((jid, idx));
                }
                continue;
            }

            let keys = affinity_keys(jid, &view, idx);
            let picked =
                pick_worker(core, view.exclusive, &keys, config.batch_frames);
            let Some((wid, need)) = picked else {
                // No capacity for the queue head: put it back and wait
                // for a completion, a registration, or a death sweep
                // (FIFO, like a cluster array job).
                core.ready.push_front((jid, idx));
                break;
            };

            let now = Instant::now();
            let dispatch_wait = view
                .eligible_at
                .map(|t| now.saturating_duration_since(t))
                .unwrap_or_default();
            let worker = core.workers.get_mut(&wid).expect("picked above");
            worker.in_flight.push((jid, idx));
            worker.used += need;
            if view.exclusive {
                worker.reserved = true;
            }
            note_affinity(worker, &keys);
            let worker_name = worker.name.clone();
            pending.entry(wid).or_default().push(TaskAssign {
                job: jid.0,
                task_idx: idx,
                task_id: task.task_id,
                work: WireWork::from_work(&task.work),
            });
            core.assigned.insert(
                (jid, idx),
                Assigned {
                    worker: wid,
                    sent_at: now,
                    dispatch_wait,
                    attempt: view.attempt,
                    need,
                },
            );
            core.table.note_assigned(jid, idx, Some(&worker_name));
        }

        // Phase 2: steal (only when there is nothing central left).
        if config.steal && core.ready.is_empty() {
            steal_backlog(core, &mut pending, &mut revokes);
        }

        if pending.is_empty() && revokes.is_empty() {
            return;
        }

        // Phase 3: flush.
        let mut dead: Vec<u64> = Vec::new();
        for &(vid, job, task_idx) in &revokes {
            if let Some(w) = core.workers.get_mut(&vid) {
                if w.alive
                    && w.writer.send(&Message::Revoke { job, task_idx }).is_err()
                {
                    dead.push(vid);
                }
            }
        }
        for (wid, tasks) in pending {
            let Some(w) = core.workers.get_mut(&wid) else { continue };
            if !w.alive {
                continue; // died during revoke flush; mark_dead requeues
            }
            let batched =
                w.capable && config.batch_frames && tasks.len() > 1;
            let failed = if batched {
                w.writer.send(&Message::AssignBatch { tasks }).is_err()
            } else {
                tasks.into_iter().any(|t| {
                    w.writer
                        .send(&Message::Assign {
                            job: t.job,
                            task_idx: t.task_idx,
                            task_id: t.task_id,
                            work: t.work,
                        })
                        .is_err()
                })
            };
            if failed {
                dead.push(wid);
            }
        }
        if dead.is_empty() {
            return;
        }
        dead.dedup();
        for wid in dead {
            // Send failure = dead worker; everything it held (including
            // tasks buffered this round — state was registered in phase
            // 1) goes back to the queue front, and the round restarts.
            mark_dead(core, wid);
        }
    }
}

/// Rebalance a dry queue: an idle worker pulls queued-but-unstarted
/// tasks from the most-backlogged peer (batch shipping overcommits
/// workers, so a straggler's local backlog would otherwise pin the
/// makespan while other workers idle).  Steals from the *end* of the
/// victim's in-flight list — newest-queued, least likely to have
/// started — and buffers a `Revoke` per stolen task; a revoke that
/// loses the race to the victim's executor is harmless (the completion
/// ownership gate keeps exactly one result).  Stolen tasks are *moves*,
/// not failures: [`TaskReport::reassigned`] stays untouched.
fn steal_backlog(
    core: &mut Core,
    pending: &mut BTreeMap<u64, Vec<TaskAssign>>,
    revokes: &mut Vec<(u64, u64, usize)>,
) {
    loop {
        let thief = core
            .workers
            .iter()
            .filter(|(_, w)| w.alive && !w.reserved && w.used < w.slots)
            .min_by_key(|(id, w)| (w.used, **id))
            .map(|(id, _)| *id);
        let Some(tid) = thief else { return };
        let victim = core
            .workers
            .iter()
            .filter(|(id, w)| {
                // Never steal from a worker with unflushed buffered
                // tasks this round — the frame hasn't even been sent.
                **id != tid
                    && w.alive
                    && w.in_flight.len() > w.slots
                    && !pending.contains_key(*id)
            })
            .max_by_key(|(id, w)| {
                (w.in_flight.len() - w.slots, std::cmp::Reverse(**id))
            })
            .map(|(id, _)| *id);
        let Some(vid) = victim else { return };
        let (free, backlog) = {
            let t = &core.workers[&tid];
            let v = &core.workers[&vid];
            (t.slots - t.used, v.in_flight.len() - v.slots)
        };
        // Half the backlog, but never more than the thief can *run*:
        // stealing into a fresh backlog would just ping-pong tasks.
        let take = free.min(backlog.div_ceil(2)).max(1);
        let mut moved = 0usize;
        for _ in 0..take {
            let Some(key) =
                core.workers.get_mut(&vid).and_then(|v| v.in_flight.pop())
            else {
                break;
            };
            let (jid, idx) = key;
            // Only move tasks the victim still owns; anything else is a
            // stale entry and just gets dropped from its list.
            if core.assigned.get(&key).map(|a| a.worker) != Some(vid) {
                continue;
            }
            let v = core.workers.get_mut(&vid).expect("victim exists");
            v.used = v.used.saturating_sub(1);
            let live = core.table.is_live(jid);
            let view = if live { core.table.view(jid, idx) } else { None };
            let Some(view) = view else {
                core.assigned.remove(&key);
                continue;
            };
            let keys = affinity_keys(jid, &view, idx);
            let now = Instant::now();
            let t = core.workers.get_mut(&tid).expect("thief exists");
            t.in_flight.push(key);
            t.used += 1;
            note_affinity(t, &keys);
            let thief_name = t.name.clone();
            pending.entry(tid).or_default().push(TaskAssign {
                job: jid.0,
                task_idx: idx,
                task_id: view.tasks[idx].task_id,
                work: WireWork::from_work(&view.tasks[idx].work),
            });
            revokes.push((vid, jid.0, idx));
            if let Some(a) = core.assigned.get_mut(&key) {
                a.worker = tid;
                a.sent_at = now;
            }
            core.table.note_assigned(jid, idx, Some(&thief_name));
            moved += 1;
        }
        if moved == 0 {
            return;
        }
    }
}

/// Declare a worker dead: requeue its in-flight tasks at the *front* of
/// the ready queue with bumped reassignment counts, and drop its
/// capacity from the reported width.  Idempotent.
fn mark_dead(core: &mut Core, wid: u64) {
    let Some(worker) = core.workers.get_mut(&wid) else { return };
    if !worker.alive {
        return;
    }
    worker.alive = false;
    worker.used = 0;
    worker.reserved = false;
    worker.affinity.clear();
    worker.writer.shutdown();
    let name = worker.name.clone();
    let orphans = std::mem::take(&mut worker.in_flight);
    if core.bus.active() {
        core.bus.emit(Event::WorkerDead {
            worker: name.clone(),
        });
    }
    for key in orphans {
        // Only requeue tasks this worker still owns (a reassignment may
        // already have moved one).
        if core.assigned.get(&key).map(|a| a.worker) != Some(wid) {
            continue;
        }
        core.assigned.remove(&key);
        if core.table.is_live(key.0) {
            *core.reassigns.entry(key).or_insert(0) += 1;
            core.table.note_reassigned(key.0, key.1);
            core.ready.push_front(key);
        }
    }
    core.table.set_slots(core.alive_slots().max(1));
    if core.alive_workers() == 0 {
        // Whole fleet lost: fail every live job with a clear error
        // instead of letting `wait()` hang forever on capacity that may
        // never return (new workers would have to re-run from a fresh
        // submission anyway — partial map output is re-created
        // idempotently on retry, not resumed).
        for jid in core.table.live_jobs() {
            core.table.fail_job(
                jid,
                format!("all workers lost (worker '{name}' was the last)"),
            );
        }
        core.ready.clear();
        core.reassigns.clear();
        core.assigned.clear();
    }
}

// ---------------------------------------------------------------------------
// Connection handling
// ---------------------------------------------------------------------------

fn accept_loop(inner: &Arc<Inner>, listener: TcpListener) {
    loop {
        if inner.lock().shutdown {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let inner = inner.clone();
                // Reader threads are detached: they exit on EOF, and
                // coordinator Drop force-closes every worker socket.
                std::thread::spawn(move || serve_worker(&inner, stream));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(25)),
        }
    }
}

/// Per-connection reader: handshake, then pump messages until the
/// worker disappears.
fn serve_worker(inner: &Arc<Inner>, stream: TcpStream) {
    let Ok((mut reader, mut writer)) = split(stream) else { return };

    // Handshake: first frame must be a compatible Register, and it must
    // arrive promptly — a silent connection (port scanner, stray
    // client) must not pin this thread and socket forever.
    reader.set_read_timeout(Some(Duration::from_secs(10)));
    let (name, slots, advertised) = match reader.recv() {
        Ok(Some(Message::Register {
            name,
            slots,
            version,
            wire,
        })) if version == PROTOCOL_VERSION => (name, slots.max(1), wire),
        _ => return, // wrong/late first frame or version: drop it
    };
    reader.set_read_timeout(None);
    // `wire` present = a PR-10 peer that understands batch/revoke
    // frames and may ask for binary framing; absent = legacy peer that
    // must keep getting one line-JSON frame per task.  The handshake
    // itself is always line-JSON; the negotiated framing starts with
    // the first post-`Registered` frame in each direction.
    let capable = advertised.is_some();
    let mode = advertised.unwrap_or(WireMode::Json);
    let wid = {
        let mut core = inner.lock();
        if core.shutdown {
            return;
        }
        let wid = core.next_worker_id;
        core.next_worker_id += 1;
        let reply = Message::Registered {
            worker_id: wid,
            wire: capable.then_some(mode),
        };
        if writer.send(&reply).is_err() {
            return;
        }
        // Switch the writer *before* it is parked in WorkerState —
        // `try_assign` below may ship frames immediately.
        if mode == WireMode::Binary {
            writer.set_mode(WireMode::Binary);
        }
        if core.bus.active() {
            core.bus.emit(Event::WorkerRegistered {
                worker: name.clone(),
                slots,
            });
        }
        core.workers.insert(
            wid,
            WorkerState {
                name,
                slots,
                writer,
                in_flight: Vec::new(),
                used: 0,
                capable,
                reserved: false,
                affinity: Vec::new(),
                last_seen: Instant::now(),
                alive: true,
                offset_us: None,
                min_rtt_us: u64::MAX,
            },
        );
        core.table.set_slots(core.alive_slots().max(1));
        try_assign(&mut core, &inner.config);
        core.sample_queue_depth();
        wid
    };
    if mode == WireMode::Binary {
        reader.set_mode(WireMode::Binary);
    }
    inner.workers_cv.notify_all();

    loop {
        match reader.recv() {
            Ok(Some(msg)) => {
                let mut core = inner.lock();
                if core.shutdown {
                    return;
                }
                if let Some(w) = core.workers.get_mut(&wid) {
                    w.last_seen = Instant::now();
                }
                match msg {
                    Message::Heartbeat {
                        sent_us, rtt_us, ..
                    } => {
                        if let Some(s) = sent_us {
                            let now_us =
                                core.epoch.elapsed().as_micros() as u64;
                            if let Some(w) = core.workers.get_mut(&wid) {
                                // NTP-style midpoint: the beacon left
                                // the worker ~rtt/2 before we read it,
                                // so its stamp maps to `now − rtt/2` on
                                // our timeline.  Keep the estimate from
                                // the smallest round trip seen — least
                                // queuing noise (DESIGN.md §12).
                                match rtt_us {
                                    Some(rtt) if rtt <= w.min_rtt_us => {
                                        w.min_rtt_us = rtt;
                                        w.offset_us = Some(
                                            now_us as i64
                                                - (rtt / 2) as i64
                                                - s as i64,
                                        );
                                    }
                                    // First beacons carry no RTT (no
                                    // ack echoed yet): seed with a
                                    // zero-delay estimate so traces
                                    // align even on short jobs.
                                    None if w.offset_us.is_none() => {
                                        w.offset_us =
                                            Some(now_us as i64 - s as i64);
                                    }
                                    _ => {}
                                }
                                // Echo so the worker can measure the
                                // round trip.  Gated on `sent_us`: an
                                // unknown frame type breaks a pre-PR-9
                                // worker's read loop, and stamping its
                                // beacons is how a worker advertises it
                                // understands acks.  A failed send is
                                // ignored — the reader notices death.
                                let _ = w.writer.send(
                                    &Message::HeartbeatAck { echo_us: s },
                                );
                            }
                        }
                        if core.bus.active() {
                            if let Some(w) = core.workers.get(&wid) {
                                core.bus.emit(Event::WorkerHeartbeat {
                                    worker: w.name.clone(),
                                });
                            }
                        }
                    }
                    Message::Complete {
                        job,
                        task_idx,
                        outcome,
                    } => {
                        on_complete(
                            &mut core, wid, JobId(job), task_idx, outcome,
                        );
                        try_assign(&mut core, &inner.config);
                        core.sample_queue_depth();
                        drop(core);
                        inner.done_cv.notify_all();
                    }
                    Message::CompleteBatch { done } => {
                        // Coalesced replies: fold every completion, then
                        // run one placement round for the freed slots.
                        for c in done {
                            on_complete(
                                &mut core,
                                wid,
                                JobId(c.job),
                                c.task_idx,
                                c.outcome,
                            );
                        }
                        try_assign(&mut core, &inner.config);
                        core.sample_queue_depth();
                        drop(core);
                        inner.done_cv.notify_all();
                    }
                    Message::Failed { job, task_idx, msg } => {
                        let key = (JobId(job), task_idx);
                        if let Some(w) = core.workers.get_mut(&wid) {
                            w.in_flight.retain(|k| *k != key);
                        }
                        // Same ownership gate as completions: a stale
                        // failure from a worker whose task was already
                        // reassigned must neither fail the job (the
                        // rightful run may yet succeed) nor clobber the
                        // new owner's assignment.
                        let owned = core
                            .assigned
                            .get(&key)
                            .map(|a| a.worker)
                            == Some(wid);
                        if owned {
                            let need = core
                                .assigned
                                .remove(&key)
                                .map(|a| a.need)
                                .unwrap_or(1);
                            if let Some(w) = core.workers.get_mut(&wid)
                            {
                                w.used = w.used.saturating_sub(need);
                                if need > 1 {
                                    // Exclusive attempt over: release
                                    // the whole-node reservation.
                                    w.reserved = false;
                                }
                            }
                            // The engine-shared error policy decides
                            // the task's fate (stop/retry/dlq/skip +
                            // circuit breaker) — identical semantics
                            // to the local engine.
                            let worker_name = core
                                .workers
                                .get(&wid)
                                .map(|w| w.name.clone());
                            match core.table.on_task_error(
                                JobId(job),
                                task_idx,
                                &msg,
                                worker_name.as_deref(),
                            ) {
                                ErrorAction::Requeue => {
                                    core.ready.push_back(key);
                                }
                                ErrorAction::Completed(ready) => {
                                    core.ready.extend(ready);
                                }
                                ErrorAction::FailJob => {
                                    // Drop queue entries / counters of
                                    // dead jobs.
                                    let c: &mut Core = &mut core;
                                    let (ready, reassigns, table) = (
                                        &mut c.ready,
                                        &mut c.reassigns,
                                        &c.table,
                                    );
                                    ready.retain(|(j, _)| {
                                        table.is_live(*j)
                                    });
                                    reassigns.retain(|(j, _), _| {
                                        table.is_live(*j)
                                    });
                                }
                                ErrorAction::Ignore => {}
                            }
                        }
                        try_assign(&mut core, &inner.config);
                        core.sample_queue_depth();
                        drop(core);
                        inner.done_cv.notify_all();
                    }
                    // Workers never send coordinator-bound frames other
                    // than the above; ignore anything else.
                    _ => {}
                }
            }
            // Protocol garbage from this worker: treat like death
            // (kill the connection) rather than poisoning the fleet.
            Ok(None) | Err(_) => {
                let mut core = inner.lock();
                if !core.shutdown {
                    mark_dead(&mut core, wid);
                    try_assign(&mut core, &inner.config);
                    core.sample_queue_depth();
                }
                drop(core);
                inner.done_cv.notify_all();
                inner.workers_cv.notify_all();
                return;
            }
        }
    }
}

/// Fold one successful completion into the job table, stamping the
/// report with coordinator-clock timings and remote attribution.
fn on_complete(
    core: &mut Core,
    wid: u64,
    jid: JobId,
    idx: usize,
    outcome: crate::scheduler::remote::protocol::WireOutcome,
) {
    // A completion can arrive from a worker that was declared dead (its
    // socket outlived the heartbeat verdict) after the task was already
    // reassigned; accept it — the table de-duplicates — but only clear
    // the assignment if this worker still owns it.
    let owned = core.assigned.get(&(jid, idx)).map(|a| a.worker)
        == Some(wid);
    let assignment = if owned {
        core.assigned.remove(&(jid, idx))
    } else {
        None
    };
    if let Some(w) = core.workers.get_mut(&wid) {
        w.in_flight.retain(|k| *k != (jid, idx));
        if let Some(a) = &assignment {
            w.used = w.used.saturating_sub(a.need);
            if a.need > 1 {
                // Exclusive task over: release the whole-node
                // reservation.
                w.reserved = false;
            }
        }
    }
    let Some(view) = core.table.view(jid, idx) else {
        return; // job already over (failed, or duplicate completion)
    };
    let now = Instant::now();
    let task_id = view.tasks[idx].task_id;
    let (sent_at, dispatch_wait, attempt) = match &assignment {
        Some(a) => (a.sent_at, a.dispatch_wait, a.attempt),
        None => (now, Duration::ZERO, view.attempt),
    };
    let exec = outcome.startup() + outcome.compute();
    let roundtrip = now.saturating_duration_since(sent_at);
    // Wire overhead = round trip minus the *hold*: the span the worker
    // measured between receiving the frame and finishing execution.
    // The hold subsumes worker-local queue wait, so a batch-shipped
    // task that sat in a worker's backlog doesn't book that wait as
    // shipping cost.  Legacy unstamped frames fall back to subtracting
    // bare execution time (hold floor), matching pre-batching math.
    let hold = match (outcome.recv_us, outcome.exec_end_us) {
        (Some(r), Some(e)) => {
            Duration::from_micros(e.saturating_sub(r)).max(exec)
        }
        _ => exec,
    };
    let shipped = roundtrip.saturating_sub(hold);
    // Outbound wire time, resolvable only when the worker stamped its
    // frame.  Preferred path: map the worker's `recv_us` onto our
    // timeline via the heartbeat-derived clock offset and subtract the
    // send instant.  Fallback (offset not yet estimated): split the
    // total wire time symmetrically, like the offset estimator itself
    // assumes.  Clamped into the shipped budget either way, so span
    // tiling stays consistent under clock-estimate error.
    let ship_out = outcome.recv_us.map(|recv| {
        let offset =
            core.workers.get(&wid).and_then(|w| w.offset_us);
        let out_us = match offset {
            Some(off) => {
                let sent_at_us = sent_at
                    .saturating_duration_since(core.epoch)
                    .as_micros() as i64;
                (recv as i64 + off - sent_at_us).max(0) as u64
            }
            None => {
                let hold = outcome
                    .exec_end_us
                    .unwrap_or(recv)
                    .saturating_sub(recv);
                (roundtrip.as_micros() as u64).saturating_sub(hold) / 2
            }
        };
        Duration::from_micros(out_us).min(shipped)
    });
    let report = TaskReport {
        task_id,
        dispatch_wait,
        startup: outcome.startup(),
        compute: outcome.compute(),
        launches: outcome.launches,
        items: outcome.items,
        started_at: sent_at.saturating_duration_since(view.submitted_at),
        finished_at: now.saturating_duration_since(view.submitted_at),
        retries: attempt,
        worker: Some(
            core.workers
                .get(&wid)
                .map(|w| w.name.clone())
                .unwrap_or_else(|| format!("worker-{wid}")),
        ),
        shipped,
        ship_out,
        reassigned: core.reassigns.remove(&(jid, idx)).unwrap_or(0),
        dead_lettered: false,
    };
    let ready = core.table.on_task_done(jid, idx, report);
    core.ready.extend(ready);
}

// ---------------------------------------------------------------------------
// Liveness monitor
// ---------------------------------------------------------------------------

/// Periodically sweep for heartbeat-lapsed workers.  Connection drops
/// are caught faster by the reader threads; this catches wedged-but-
/// connected workers.
fn monitor_loop(inner: &Arc<Inner>) {
    let timeout = inner.config.heartbeat_timeout;
    let tick = (timeout / 4).max(Duration::from_millis(50));
    let mut core = inner.lock();
    loop {
        if core.shutdown {
            return;
        }
        let lapsed: Vec<u64> = core
            .workers
            .iter()
            .filter(|(_, w)| w.alive && w.last_seen.elapsed() > timeout)
            .map(|(id, _)| *id)
            .collect();
        if !lapsed.is_empty() {
            for wid in &lapsed {
                mark_dead(&mut core, *wid);
            }
            try_assign(&mut core, &inner.config);
            core.sample_queue_depth();
            inner.done_cv.notify_all();
        }
        // Sleep on the condvar so coordinator shutdown wakes us
        // immediately instead of after a tick.
        let (guard, _) = inner
            .workers_cv
            .wait_timeout(core, tick)
            .unwrap_or_else(|e| e.into_inner());
        core = guard;
    }
}
