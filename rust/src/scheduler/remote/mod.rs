//! The distributed engine: a coordinator/worker fleet over TCP.
//!
//! The paper's whole premise is map-reduce *across cluster nodes*
//! ("LLMapReduce provides the familiar map-reduce parallel programming
//! model to big data users running on a supercomputer", §I); this module
//! is the reproduction's real multi-process substrate, complementing the
//! in-process thread pool ([`crate::scheduler::local`]) and the
//! discrete-event simulator ([`crate::scheduler::sim`]).  DESIGN.md §6
//! documents the topology, message lifecycle and reassignment rules.
//!
//! * [`protocol`] — newline-delimited JSON wire messages (register /
//!   heartbeat / assign / complete / failed / shutdown) built on
//!   [`crate::util::json`]: zero new dependencies, debuggable with `nc`;
//! * [`transport`] — line framing over `TcpStream`, split reader/writer;
//! * [`coordinator`] — [`RemoteCoordinator`], an [`Engine`] whose tasks
//!   ship to registered workers, with heartbeat-based death detection
//!   and automatic reassignment of a dead worker's in-flight tasks;
//! * [`worker`] — the daemon behind `llmapreduce worker`: registers,
//!   executes shipped work via [`crate::scheduler::exec`] (the same
//!   execution path as the local engine), streams reports back.
//!
//! Because `RemoteCoordinator` sits behind the shared [`Engine`] trait,
//! `Session`, `pipeline::run`, overlapped dispatch and the nested
//! multi-level fan-out all run over the network unchanged — the
//! acceptance bar is byte-identical wordcount output against
//! [`crate::scheduler::local::LocalEngine`].
//!
//! [`Engine`]: crate::scheduler::Engine

pub mod coordinator;
pub mod protocol;
pub mod transport;
pub mod worker;

pub use coordinator::{CoordinatorConfig, RemoteCoordinator};
pub use protocol::WireMode;
pub use worker::{run_worker, WorkerConfig};
