//! The coordinator↔worker wire protocol.
//!
//! One [`Message`] per line, encoded as a compact JSON object over
//! [`crate::util::json`] — no external dependencies, human-readable in a
//! packet capture, and trivially framed: a `BufRead::read_line` loop is
//! the whole parser (DESIGN.md §6 discusses why line-delimited JSON over
//! a binary format).  Malformed frames surface as [`Error::Format`] with
//! `kind = "wire"`, never a panic — a coordinator must survive a
//! garbage-spewing peer.
//!
//! [`WireWork`] is the serializable mirror of
//! [`crate::scheduler::TaskWork`]: app identity travels as a
//! [`crate::apps::MapApp::wire_spec`] string the worker re-resolves via
//! [`crate::apps::registry`], and paths travel as strings (coordinator
//! and workers share a filesystem — the paper's central-storage model,
//! §I's "central storage" assumption).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::scheduler::TaskWork;
use crate::util::json::{obj, Json};

/// Protocol revision, checked at registration.
pub const PROTOCOL_VERSION: usize = 1;

/// A malformed-frame error (the only error shape this module emits;
/// the transport layer reuses it for oversize / non-UTF8 frames).
pub(crate) fn frame_err(reason: impl Into<String>) -> Error {
    Error::Format {
        kind: "wire",
        path: PathBuf::from("<frame>"),
        reason: reason.into(),
    }
}

/// Serializable task payload: [`TaskWork`] minus the in-process `Arc`s.
#[derive(Debug, Clone, PartialEq)]
pub enum WireWork {
    /// A map task; `mode` is the [`crate::options::AppType`] spelling
    /// (`"siso"`, `"mimo"`, or `"spmd"`), so batched SPMD tasks gang on
    /// the worker exactly as they would locally.  Decoding accepts the
    /// protocol-v1 boolean `mimo` field as a fallback.
    Map {
        mapper: String,
        pairs: Vec<(String, String)>,
        mode: String,
    },
    /// The final reduce over a directory.
    Reduce {
        reducer: String,
        input_dir: String,
        out_file: String,
    },
    /// An overlapped partial fold over one mapper task's outputs.
    ReducePartial {
        reducer: String,
        files: Vec<String>,
        out_file: String,
    },
    /// Timing-only payload (benchmarks, simulator parity tests).
    Synthetic {
        startup_us: u64,
        per_item_us: u64,
        items: usize,
        launches: usize,
    },
}

impl WireWork {
    /// Serialize an in-process payload for shipping.  App identity is
    /// the app's [`crate::apps::MapApp::wire_spec`]; the worker-side
    /// registry resolves it back (or fails the task with a clear error
    /// for in-process-only apps).  Relative paths are absolutized
    /// against the coordinator's working directory before shipping —
    /// workers share the filesystem but not necessarily the cwd.
    pub fn from_work(work: &TaskWork) -> WireWork {
        // One cwd lookup per serialization, not per path — this runs
        // under the coordinator's state lock.
        let cwd = std::env::current_dir().ok();
        let s = |p: &std::path::Path| -> String {
            crate::util::absolutize_in(cwd.as_deref(), p)
                .to_string_lossy()
                .into_owned()
        };
        match work {
            TaskWork::Map { app, pairs, mode } => WireWork::Map {
                mapper: app.wire_spec(),
                pairs: pairs
                    .iter()
                    .map(|(i, o)| (s(i), s(o)))
                    .collect(),
                mode: mode.as_str().to_string(),
            },
            TaskWork::Reduce {
                app,
                input_dir,
                out_file,
            } => WireWork::Reduce {
                reducer: app.wire_spec(),
                input_dir: s(input_dir),
                out_file: s(out_file),
            },
            TaskWork::ReducePartial {
                app,
                files,
                out_file,
            } => WireWork::ReducePartial {
                reducer: app.wire_spec(),
                files: files.iter().map(|f| s(f)).collect(),
                out_file: s(out_file),
            },
            TaskWork::Synthetic {
                startup,
                per_item,
                items,
                launches,
            } => WireWork::Synthetic {
                startup_us: startup.as_micros() as u64,
                per_item_us: per_item.as_micros() as u64,
                items: *items,
                launches: *launches,
            },
        }
    }

    fn to_json(&self) -> Json {
        match self {
            WireWork::Map {
                mapper,
                pairs,
                mode,
            } => obj(vec![
                ("kind", "map".into()),
                ("mapper", mapper.as_str().into()),
                (
                    "pairs",
                    Json::Arr(
                        pairs
                            .iter()
                            .map(|(i, o)| {
                                Json::Arr(vec![
                                    i.as_str().into(),
                                    o.as_str().into(),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("mode", mode.as_str().into()),
            ]),
            WireWork::Reduce {
                reducer,
                input_dir,
                out_file,
            } => obj(vec![
                ("kind", "reduce".into()),
                ("reducer", reducer.as_str().into()),
                ("input_dir", input_dir.as_str().into()),
                ("out_file", out_file.as_str().into()),
            ]),
            WireWork::ReducePartial {
                reducer,
                files,
                out_file,
            } => obj(vec![
                ("kind", "reduce_partial".into()),
                ("reducer", reducer.as_str().into()),
                (
                    "files",
                    Json::Arr(
                        files.iter().map(|f| f.as_str().into()).collect(),
                    ),
                ),
                ("out_file", out_file.as_str().into()),
            ]),
            WireWork::Synthetic {
                startup_us,
                per_item_us,
                items,
                launches,
            } => obj(vec![
                ("kind", "synthetic".into()),
                ("startup_us", (*startup_us as usize).into()),
                ("per_item_us", (*per_item_us as usize).into()),
                ("items", (*items).into()),
                ("launches", (*launches).into()),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<WireWork> {
        match str_field(v, "kind")? {
            "map" => Ok(WireWork::Map {
                mapper: str_field(v, "mapper")?.to_string(),
                pairs: arr_field(v, "pairs")?
                    .iter()
                    .map(|p| {
                        let pair = p.as_arr().ok_or_else(|| {
                            frame_err("pair is not an array")
                        })?;
                        match pair {
                            [Json::Str(i), Json::Str(o)] => {
                                Ok((i.clone(), o.clone()))
                            }
                            _ => Err(frame_err(
                                "pair is not [input, output]",
                            )),
                        }
                    })
                    .collect::<Result<_>>()?,
                // Protocol v1 peers send a boolean `mimo`; newer peers
                // send the AppType spelling in `mode`.
                mode: match str_field(v, "mode") {
                    Ok(m) => m.to_string(),
                    Err(_) => if bool_field(v, "mimo")? {
                        "mimo"
                    } else {
                        "siso"
                    }
                    .to_string(),
                },
            }),
            "reduce" => Ok(WireWork::Reduce {
                reducer: str_field(v, "reducer")?.to_string(),
                input_dir: str_field(v, "input_dir")?.to_string(),
                out_file: str_field(v, "out_file")?.to_string(),
            }),
            "reduce_partial" => Ok(WireWork::ReducePartial {
                reducer: str_field(v, "reducer")?.to_string(),
                files: arr_field(v, "files")?
                    .iter()
                    .map(|f| {
                        f.as_str().map(str::to_string).ok_or_else(|| {
                            frame_err("file entry is not a string")
                        })
                    })
                    .collect::<Result<_>>()?,
                out_file: str_field(v, "out_file")?.to_string(),
            }),
            "synthetic" => Ok(WireWork::Synthetic {
                startup_us: usize_field(v, "startup_us")? as u64,
                per_item_us: usize_field(v, "per_item_us")? as u64,
                items: usize_field(v, "items")?,
                launches: usize_field(v, "launches")?,
            }),
            other => Err(frame_err(format!("unknown work kind '{other}'"))),
        }
    }
}

/// Worker-measured execution outcome, mirrored from
/// [`crate::scheduler::exec::ExecOutcome`] in integer microseconds.
///
/// The three `*_us` timestamps are worker-side monotonic readings
/// relative to the worker's *connection epoch* (the instant it dialed
/// the coordinator): when the assignment was read off the socket, when
/// execution started, and when it finished.  They exist so the tracing
/// layer can split the coordinator-observed round trip into ship-out /
/// queue / execute / ship-back segments on one timeline (DESIGN.md
/// §12).  They are optional on the wire — pre-PR-9 peers omit them and
/// both sides still interoperate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct WireOutcome {
    pub startup_us: u64,
    pub compute_us: u64,
    pub launches: usize,
    pub items: usize,
    /// Worker clock (µs since its connection epoch) when the assign
    /// frame was received.
    pub recv_us: Option<u64>,
    /// Worker clock when a slot picked the task up and began executing.
    pub exec_start_us: Option<u64>,
    /// Worker clock when execution finished, just before the complete
    /// frame was written.
    pub exec_end_us: Option<u64>,
}

impl WireOutcome {
    pub fn startup(&self) -> Duration {
        Duration::from_micros(self.startup_us)
    }

    pub fn compute(&self) -> Duration {
        Duration::from_micros(self.compute_us)
    }
}

/// Everything that crosses the wire, in both directions.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker → coordinator, first frame of a connection.
    Register {
        name: String,
        slots: usize,
        version: usize,
    },
    /// Coordinator → worker, the registration reply.
    Registered { worker_id: u64 },
    /// Worker → coordinator liveness beacon; a lapse triggers
    /// reassignment of the worker's in-flight tasks.  Newer workers
    /// also stamp the beacon with their monotonic send time (µs since
    /// connection epoch) and the round-trip they measured off the last
    /// [`Message::HeartbeatAck`], which is what the coordinator's
    /// clock-offset estimator consumes; both fields are absent from
    /// pre-PR-9 beacons.
    Heartbeat {
        worker_id: u64,
        sent_us: Option<u64>,
        rtt_us: Option<u64>,
    },
    /// Coordinator → worker: echo of a heartbeat's `sent_us`, letting
    /// the worker measure the round trip.  Sent *only* to workers whose
    /// beacons carry `sent_us` — an unknown frame type breaks an old
    /// worker's read loop, so the capability is advertised first.
    HeartbeatAck { echo_us: u64 },
    /// Coordinator → worker: run this task.
    Assign {
        job: u64,
        task_idx: usize,
        task_id: usize,
        work: WireWork,
    },
    /// Worker → coordinator: the task succeeded.
    Complete {
        job: u64,
        task_idx: usize,
        outcome: WireOutcome,
    },
    /// Worker → coordinator: the task raised a real (non-injected)
    /// error; the coordinator fails the job and cascades.
    Failed {
        job: u64,
        task_idx: usize,
        msg: String,
    },
    /// Coordinator → worker: drain and exit.
    Shutdown,
}

impl Message {
    /// One frame: compact JSON plus the terminating newline.
    pub fn encode(&self) -> String {
        let mut s = self.to_json().to_string_compact();
        s.push('\n');
        s
    }

    /// Parse one frame (without or with its trailing newline).  All
    /// failure modes return [`Error::Format`]; none panic.
    pub fn decode(line: &str) -> Result<Message> {
        let v = Json::parse(line.trim_end_matches(['\r', '\n']))
            .map_err(|e| frame_err(format!("bad frame json: {e}")))?;
        Message::from_json(&v)
    }

    pub fn to_json(&self) -> Json {
        match self {
            Message::Register {
                name,
                slots,
                version,
            } => obj(vec![
                ("type", "register".into()),
                ("name", name.as_str().into()),
                ("slots", (*slots).into()),
                ("version", (*version).into()),
            ]),
            Message::Registered { worker_id } => obj(vec![
                ("type", "registered".into()),
                ("worker_id", (*worker_id as usize).into()),
            ]),
            Message::Heartbeat {
                worker_id,
                sent_us,
                rtt_us,
            } => {
                let mut f = vec![
                    ("type", "heartbeat".into()),
                    ("worker_id", (*worker_id as usize).into()),
                ];
                if let Some(us) = sent_us {
                    f.push(("sent_us", (*us as usize).into()));
                }
                if let Some(us) = rtt_us {
                    f.push(("rtt_us", (*us as usize).into()));
                }
                obj(f)
            }
            Message::HeartbeatAck { echo_us } => obj(vec![
                ("type", "heartbeat_ack".into()),
                ("echo_us", (*echo_us as usize).into()),
            ]),
            Message::Assign {
                job,
                task_idx,
                task_id,
                work,
            } => obj(vec![
                ("type", "assign".into()),
                ("job", (*job as usize).into()),
                ("task_idx", (*task_idx).into()),
                ("task_id", (*task_id).into()),
                ("work", work.to_json()),
            ]),
            Message::Complete {
                job,
                task_idx,
                outcome,
            } => obj(vec![
                ("type", "complete".into()),
                ("job", (*job as usize).into()),
                ("task_idx", (*task_idx).into()),
                (
                    "outcome",
                    obj(vec![
                        (
                            "startup_us",
                            (outcome.startup_us as usize).into(),
                        ),
                        (
                            "compute_us",
                            (outcome.compute_us as usize).into(),
                        ),
                        ("launches", outcome.launches.into()),
                        ("items", outcome.items.into()),
                    ]
                    .into_iter()
                    .chain(
                        [
                            ("recv_us", outcome.recv_us),
                            ("exec_start_us", outcome.exec_start_us),
                            ("exec_end_us", outcome.exec_end_us),
                        ]
                        .into_iter()
                        .filter_map(|(k, us)| {
                            us.map(|us| (k, (us as usize).into()))
                        }),
                    )
                    .collect()),
                ),
            ]),
            Message::Failed {
                job,
                task_idx,
                msg,
            } => obj(vec![
                ("type", "failed".into()),
                ("job", (*job as usize).into()),
                ("task_idx", (*task_idx).into()),
                ("msg", msg.as_str().into()),
            ]),
            Message::Shutdown => obj(vec![("type", "shutdown".into())]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Message> {
        match str_field(v, "type")? {
            "register" => Ok(Message::Register {
                name: str_field(v, "name")?.to_string(),
                slots: usize_field(v, "slots")?,
                version: usize_field(v, "version")?,
            }),
            "registered" => Ok(Message::Registered {
                worker_id: usize_field(v, "worker_id")? as u64,
            }),
            "heartbeat" => Ok(Message::Heartbeat {
                worker_id: usize_field(v, "worker_id")? as u64,
                sent_us: opt_us_field(v, "sent_us"),
                rtt_us: opt_us_field(v, "rtt_us"),
            }),
            "heartbeat_ack" => Ok(Message::HeartbeatAck {
                echo_us: usize_field(v, "echo_us")? as u64,
            }),
            "assign" => Ok(Message::Assign {
                job: usize_field(v, "job")? as u64,
                task_idx: usize_field(v, "task_idx")?,
                task_id: usize_field(v, "task_id")?,
                work: WireWork::from_json(
                    v.get("work")
                        .ok_or_else(|| frame_err("assign without work"))?,
                )?,
            }),
            "complete" => {
                let o = v
                    .get("outcome")
                    .ok_or_else(|| frame_err("complete without outcome"))?;
                Ok(Message::Complete {
                    job: usize_field(v, "job")? as u64,
                    task_idx: usize_field(v, "task_idx")?,
                    outcome: WireOutcome {
                        startup_us: usize_field(o, "startup_us")? as u64,
                        compute_us: usize_field(o, "compute_us")? as u64,
                        launches: usize_field(o, "launches")?,
                        items: usize_field(o, "items")?,
                        // Optional on the wire: pre-PR-9 workers don't
                        // stamp their frames.
                        recv_us: opt_us_field(o, "recv_us"),
                        exec_start_us: opt_us_field(o, "exec_start_us"),
                        exec_end_us: opt_us_field(o, "exec_end_us"),
                    },
                })
            }
            "failed" => Ok(Message::Failed {
                job: usize_field(v, "job")? as u64,
                task_idx: usize_field(v, "task_idx")?,
                msg: str_field(v, "msg")?.to_string(),
            }),
            "shutdown" => Ok(Message::Shutdown),
            other => {
                Err(frame_err(format!("unknown message type '{other}'")))
            }
        }
    }
}

// -- field accessors that turn shape errors into Error::Format ------------

fn fields(v: &Json) -> Result<&BTreeMap<String, Json>> {
    v.as_obj()
        .ok_or_else(|| frame_err("frame is not a JSON object"))
}

fn str_field<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    fields(v)?
        .get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| frame_err(format!("missing string field '{key}'")))
}

fn usize_field(v: &Json, key: &str) -> Result<usize> {
    fields(v)?
        .get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| {
            frame_err(format!("missing non-negative int field '{key}'"))
        })
}

/// An optional microsecond field: `None` when absent or non-numeric
/// (older peers simply omit these keys).
fn opt_us_field(v: &Json, key: &str) -> Option<u64> {
    v.as_obj()?.get(key).and_then(Json::as_usize).map(|n| n as u64)
}

fn bool_field(v: &Json, key: &str) -> Result<bool> {
    fields(v)?
        .get(key)
        .and_then(Json::as_bool)
        .ok_or_else(|| frame_err(format!("missing bool field '{key}'")))
}

fn arr_field<'a>(v: &'a Json, key: &str) -> Result<&'a [Json]> {
    fields(v)?
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| frame_err(format!("missing array field '{key}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Message) {
        let line = msg.encode();
        assert!(line.ends_with('\n'), "framed");
        assert_eq!(Message::decode(&line).unwrap(), msg, "{line}");
    }

    #[test]
    fn all_message_shapes_roundtrip() {
        roundtrip(Message::Register {
            name: "worker-1".into(),
            slots: 4,
            version: PROTOCOL_VERSION,
        });
        roundtrip(Message::Registered { worker_id: 7 });
        roundtrip(Message::Heartbeat {
            worker_id: 7,
            sent_us: None,
            rtt_us: None,
        });
        roundtrip(Message::Heartbeat {
            worker_id: 7,
            sent_us: Some(1_000_123),
            rtt_us: Some(850),
        });
        roundtrip(Message::HeartbeatAck { echo_us: 1_000_123 });
        roundtrip(Message::Assign {
            job: 3,
            task_idx: 0,
            task_id: 1,
            work: WireWork::Map {
                mapper: "wordcount:ign.txt".into(),
                pairs: vec![("in/a.txt".into(), "out/a.txt.out".into())],
                mode: "mimo".into(),
            },
        });
        roundtrip(Message::Assign {
            job: 3,
            task_idx: 1,
            task_id: 2,
            work: WireWork::Map {
                mapper: "stream:./mapper.sh ref.txt".into(),
                pairs: vec![("in/b.txt".into(), "out/b.txt.out".into())],
                mode: "spmd".into(),
            },
        });
        roundtrip(Message::Assign {
            job: 4,
            task_idx: 2,
            task_id: 3,
            work: WireWork::Reduce {
                reducer: "wordcount-reducer".into(),
                input_dir: "out".into(),
                out_file: "out/llmapreduce.out".into(),
            },
        });
        roundtrip(Message::Assign {
            job: 5,
            task_idx: 1,
            task_id: 2,
            work: WireWork::ReducePartial {
                reducer: "wordcount-reducer".into(),
                files: vec!["a.out".into(), "b.out".into()],
                out_file: ".partials.9/part_00001".into(),
            },
        });
        roundtrip(Message::Assign {
            job: 6,
            task_idx: 0,
            task_id: 1,
            work: WireWork::Synthetic {
                startup_us: 1500,
                per_item_us: 10,
                items: 8,
                launches: 1,
            },
        });
        roundtrip(Message::Complete {
            job: 3,
            task_idx: 0,
            outcome: WireOutcome {
                startup_us: 1200,
                compute_us: 3400,
                launches: 1,
                items: 5,
                recv_us: None,
                exec_start_us: None,
                exec_end_us: None,
            },
        });
        roundtrip(Message::Complete {
            job: 3,
            task_idx: 1,
            outcome: WireOutcome {
                startup_us: 1200,
                compute_us: 3400,
                launches: 1,
                items: 5,
                recv_us: Some(50_000),
                exec_start_us: Some(50_400),
                exec_end_us: Some(55_000),
            },
        });
        roundtrip(Message::Failed {
            job: 3,
            task_idx: 1,
            msg: "app 'x' failed on in/a.txt: poisoned".into(),
        });
        roundtrip(Message::Shutdown);
    }

    #[test]
    fn paths_with_escapes_survive() {
        roundtrip(Message::Assign {
            job: 1,
            task_idx: 0,
            task_id: 1,
            work: WireWork::Map {
                mapper: "wordcount".into(),
                pairs: vec![(
                    "in/sp ace/\"quoted\".txt".into(),
                    "out/uni-é😀.out".into(),
                )],
                mode: "siso".into(),
            },
        });
    }

    #[test]
    fn legacy_mimo_bool_frames_still_decode() {
        // A protocol-v1 coordinator sends `mimo` instead of `mode`.
        for (legacy, expect) in [("true", "mimo"), ("false", "siso")] {
            let line = format!(
                r#"{{"type":"assign","job":1,"task_idx":0,"task_id":1,"work":{{"kind":"map","mapper":"cat","pairs":[["a","b"]],"mimo":{legacy}}}}}"#
            );
            let Message::Assign { work, .. } =
                Message::decode(&line).unwrap()
            else {
                panic!("assign stays assign");
            };
            assert_eq!(
                work,
                WireWork::Map {
                    mapper: "cat".into(),
                    pairs: vec![("a".into(), "b".into())],
                    mode: expect.into(),
                }
            );
        }
        // A map frame with neither field is malformed.
        let bad = r#"{"type":"assign","job":1,"task_idx":0,"task_id":1,"work":{"kind":"map","mapper":"cat","pairs":[]}}"#;
        assert!(Message::decode(bad).is_err());
    }

    #[test]
    fn worker_timestamps_roundtrip_across_every_presence_combination() {
        // Property-style sweep: each of the three optional stamps is
        // independently present or absent and the frame must survive a
        // roundtrip either way (workers may be upgraded piecemeal, so
        // no coordinator/worker version lockstep).
        for bits in 0u8..8 {
            let some = |b: u8, v: u64| (bits & b != 0).then_some(v);
            roundtrip(Message::Complete {
                job: 9,
                task_idx: bits as usize,
                outcome: WireOutcome {
                    startup_us: 10,
                    compute_us: 20,
                    launches: 1,
                    items: 2,
                    recv_us: some(1, 111),
                    exec_start_us: some(2, 222),
                    exec_end_us: some(4, 333),
                },
            });
        }
        for bits in 0u8..4 {
            let some = |b: u8, v: u64| (bits & b != 0).then_some(v);
            roundtrip(Message::Heartbeat {
                worker_id: bits as u64,
                sent_us: some(1, 444),
                rtt_us: some(2, 555),
            });
        }
    }

    #[test]
    fn pre_pr9_frames_without_timestamps_still_decode() {
        // Raw frames as a pre-PR-9 peer would emit them: no sent_us /
        // rtt_us on heartbeats, no worker stamps in the outcome.
        let hb = r#"{"type":"heartbeat","worker_id":3}"#;
        assert_eq!(
            Message::decode(hb).unwrap(),
            Message::Heartbeat {
                worker_id: 3,
                sent_us: None,
                rtt_us: None,
            }
        );
        let done = r#"{"type":"complete","job":2,"task_idx":4,"outcome":{"startup_us":900,"compute_us":8100,"launches":1,"items":3}}"#;
        assert_eq!(
            Message::decode(done).unwrap(),
            Message::Complete {
                job: 2,
                task_idx: 4,
                outcome: WireOutcome {
                    startup_us: 900,
                    compute_us: 8100,
                    launches: 1,
                    items: 3,
                    recv_us: None,
                    exec_start_us: None,
                    exec_end_us: None,
                },
            }
        );
        // And the other direction: a stamped frame from a new worker
        // decodes on this side with every stamp intact.
        let stamped = r#"{"type":"complete","job":2,"task_idx":4,"outcome":{"startup_us":900,"compute_us":8100,"launches":1,"items":3,"recv_us":70,"exec_start_us":80,"exec_end_us":9000}}"#;
        let Message::Complete { outcome, .. } =
            Message::decode(stamped).unwrap()
        else {
            panic!("complete stays complete");
        };
        assert_eq!(outcome.recv_us, Some(70));
        assert_eq!(outcome.exec_start_us, Some(80));
        assert_eq!(outcome.exec_end_us, Some(9000));
    }

    #[test]
    fn malformed_frames_are_format_errors_not_panics() {
        for bad in [
            "",
            "not json",
            "{}",
            "[1,2,3]",
            r#"{"type":"warp"}"#,
            r#"{"type":"register","name":"w"}"#, // missing slots/version
            r#"{"type":"assign","job":1,"task_idx":0,"task_id":1}"#,
            r#"{"type":"assign","job":1,"task_idx":0,"task_id":1,"work":{"kind":"map"}}"#,
            r#"{"type":"complete","job":1,"task_idx":0}"#,
            r#"{"type":"register","name":"w","slots":-2,"version":1}"#,
            r#"{"type":"register","name":"w","slots":1.5,"version":1}"#,
        ] {
            let err = Message::decode(bad).unwrap_err();
            assert!(
                matches!(err, Error::Format { kind: "wire", .. }),
                "{bad:?} -> {err}"
            );
        }
    }

    #[test]
    fn wire_work_mirrors_task_work() {
        use crate::options::AppType;
        use crate::scheduler::TaskWork;
        use std::path::PathBuf;
        use std::sync::Arc;
        let work = TaskWork::Map {
            app: crate::apps::wordcount::WordCountApp::new(Some(
                PathBuf::from("/refs/ign.txt"),
            )),
            pairs: vec![(
                PathBuf::from("/data/a"),
                PathBuf::from("/data/a.out"),
            )],
            mode: AppType::Mimo,
        };
        assert_eq!(
            WireWork::from_work(&work),
            WireWork::Map {
                mapper: "wordcount:/refs/ign.txt".into(),
                pairs: vec![("/data/a".into(), "/data/a.out".into())],
                mode: "mimo".into(),
            }
        );
        let spmd = TaskWork::Map {
            app: crate::apps::wordcount::WordCountApp::new(None),
            pairs: vec![(
                PathBuf::from("/data/b"),
                PathBuf::from("/data/b.out"),
            )],
            mode: AppType::Spmd,
        };
        let WireWork::Map { mode, .. } = WireWork::from_work(&spmd) else {
            panic!("map stays map");
        };
        assert_eq!(mode, "spmd");
        let red = TaskWork::Reduce {
            app: Arc::new(crate::apps::wordcount::WordCountReducer),
            input_dir: PathBuf::from("/data/out"),
            out_file: PathBuf::from("/data/out/red"),
        };
        assert_eq!(
            WireWork::from_work(&red),
            WireWork::Reduce {
                reducer: "wordcount-reducer".into(),
                input_dir: "/data/out".into(),
                out_file: "/data/out/red".into(),
            }
        );
    }

    #[test]
    fn relative_paths_absolutize_against_coordinator_cwd() {
        use crate::options::AppType;
        use crate::scheduler::TaskWork;
        use std::path::PathBuf;
        let work = TaskWork::Map {
            app: crate::apps::wordcount::WordCountApp::new(None),
            pairs: vec![(PathBuf::from("in/a"), PathBuf::from("out/a"))],
            mode: AppType::Siso,
        };
        let WireWork::Map { pairs, .. } = WireWork::from_work(&work)
        else {
            panic!("map stays map");
        };
        let cwd = std::env::current_dir().unwrap();
        assert_eq!(pairs[0].0, cwd.join("in/a").to_string_lossy());
        assert_eq!(pairs[0].1, cwd.join("out/a").to_string_lossy());
    }
}
