//! The coordinator↔worker wire protocol.
//!
//! One [`Message`] per frame.  The default framing is one compact JSON
//! object per line, over [`crate::util::json`] — no external
//! dependencies, human-readable in a packet capture, and trivially
//! framed: a `BufRead::read_line` loop is the whole parser (DESIGN.md
//! §6 discusses why line-delimited JSON over a binary format).  For
//! many-small-task hot paths a length-prefixed binary framing can be
//! negotiated per connection ([`WireMode`], DESIGN.md §13); the
//! handshake itself always stays line-JSON so legacy peers
//! interoperate.  Malformed frames surface as [`Error::Format`] with
//! `kind = "wire"`, never a panic — a coordinator must survive a
//! garbage-spewing peer.
//!
//! [`WireWork`] is the serializable mirror of
//! [`crate::scheduler::TaskWork`]: app identity travels as a
//! [`crate::apps::MapApp::wire_spec`] string the worker re-resolves via
//! [`crate::apps::registry`], and paths travel as strings (coordinator
//! and workers share a filesystem — the paper's central-storage model,
//! §I's "central storage" assumption).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::scheduler::TaskWork;
use crate::util::json::{obj, Json};

/// Protocol revision, checked at registration.
pub const PROTOCOL_VERSION: usize = 1;

/// Framing for post-handshake traffic, negotiated at registration: a
/// worker advertises its preference in [`Message::Register`] and the
/// coordinator answers in kind in [`Message::Registered`].  The
/// handshake itself is always line-JSON, so legacy peers (which never
/// send or see the `wire` field) interoperate unchanged.  Line-JSON
/// stays the default: it is debuggable in a packet capture, and the
/// binary framing only pays off on many-small-task hot paths
/// (DESIGN.md §13).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireMode {
    /// One compact-JSON object per `\n`-terminated line (the default).
    #[default]
    Json,
    /// 4-byte big-endian length prefix + tag-based binary payload.
    Binary,
}

impl WireMode {
    /// Strict parse for option surfaces (`--wire=json|binary`).
    pub fn parse(s: &str) -> Result<WireMode> {
        match s {
            "json" => Ok(WireMode::Json),
            "binary" => Ok(WireMode::Binary),
            other => Err(crate::error::Error::opt(format!(
                "--wire must be json|binary, got '{other}'"
            ))),
        }
    }

    /// Lenient decode for wire frames: an unknown advertisement from a
    /// future peer degrades to JSON instead of failing registration.
    fn lenient(s: &str) -> WireMode {
        if s == "binary" {
            WireMode::Binary
        } else {
            WireMode::Json
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            WireMode::Json => "json",
            WireMode::Binary => "binary",
        }
    }
}

/// One task inside an [`Message::AssignBatch`] frame (the same fields
/// as a standalone [`Message::Assign`]).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskAssign {
    pub job: u64,
    pub task_idx: usize,
    pub task_id: usize,
    pub work: WireWork,
}

/// One completion inside a [`Message::CompleteBatch`] frame.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskComplete {
    pub job: u64,
    pub task_idx: usize,
    pub outcome: WireOutcome,
}

/// A malformed-frame error (the only error shape this module emits;
/// the transport layer reuses it for oversize / non-UTF8 frames).
pub(crate) fn frame_err(reason: impl Into<String>) -> Error {
    Error::Format {
        kind: "wire",
        path: PathBuf::from("<frame>"),
        reason: reason.into(),
    }
}

/// Serializable task payload: [`TaskWork`] minus the in-process `Arc`s.
#[derive(Debug, Clone, PartialEq)]
pub enum WireWork {
    /// A map task; `mode` is the [`crate::options::AppType`] spelling
    /// (`"siso"`, `"mimo"`, or `"spmd"`), so batched SPMD tasks gang on
    /// the worker exactly as they would locally.  Decoding accepts the
    /// protocol-v1 boolean `mimo` field as a fallback.
    Map {
        mapper: String,
        pairs: Vec<(String, String)>,
        mode: String,
    },
    /// The final reduce over a directory.
    Reduce {
        reducer: String,
        input_dir: String,
        out_file: String,
    },
    /// An overlapped partial fold over one mapper task's outputs.
    ReducePartial {
        reducer: String,
        files: Vec<String>,
        out_file: String,
    },
    /// Timing-only payload (benchmarks, simulator parity tests).
    Synthetic {
        startup_us: u64,
        per_item_us: u64,
        items: usize,
        launches: usize,
    },
}

impl WireWork {
    /// Serialize an in-process payload for shipping.  App identity is
    /// the app's [`crate::apps::MapApp::wire_spec`]; the worker-side
    /// registry resolves it back (or fails the task with a clear error
    /// for in-process-only apps).  Relative paths are absolutized
    /// against the coordinator's working directory before shipping —
    /// workers share the filesystem but not necessarily the cwd.
    pub fn from_work(work: &TaskWork) -> WireWork {
        // One cwd lookup per serialization, not per path — this runs
        // under the coordinator's state lock.
        let cwd = std::env::current_dir().ok();
        let s = |p: &std::path::Path| -> String {
            crate::util::absolutize_in(cwd.as_deref(), p)
                .to_string_lossy()
                .into_owned()
        };
        match work {
            TaskWork::Map { app, pairs, mode } => WireWork::Map {
                mapper: app.wire_spec(),
                pairs: pairs
                    .iter()
                    .map(|(i, o)| (s(i), s(o)))
                    .collect(),
                mode: mode.as_str().to_string(),
            },
            TaskWork::Reduce {
                app,
                input_dir,
                out_file,
            } => WireWork::Reduce {
                reducer: app.wire_spec(),
                input_dir: s(input_dir),
                out_file: s(out_file),
            },
            TaskWork::ReducePartial {
                app,
                files,
                out_file,
            } => WireWork::ReducePartial {
                reducer: app.wire_spec(),
                files: files.iter().map(|f| s(f)).collect(),
                out_file: s(out_file),
            },
            TaskWork::Synthetic {
                startup,
                per_item,
                items,
                launches,
            } => WireWork::Synthetic {
                startup_us: startup.as_micros() as u64,
                per_item_us: per_item.as_micros() as u64,
                items: *items,
                launches: *launches,
            },
        }
    }

    fn to_json(&self) -> Json {
        match self {
            WireWork::Map {
                mapper,
                pairs,
                mode,
            } => obj(vec![
                ("kind", "map".into()),
                ("mapper", mapper.as_str().into()),
                (
                    "pairs",
                    Json::Arr(
                        pairs
                            .iter()
                            .map(|(i, o)| {
                                Json::Arr(vec![
                                    i.as_str().into(),
                                    o.as_str().into(),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("mode", mode.as_str().into()),
            ]),
            WireWork::Reduce {
                reducer,
                input_dir,
                out_file,
            } => obj(vec![
                ("kind", "reduce".into()),
                ("reducer", reducer.as_str().into()),
                ("input_dir", input_dir.as_str().into()),
                ("out_file", out_file.as_str().into()),
            ]),
            WireWork::ReducePartial {
                reducer,
                files,
                out_file,
            } => obj(vec![
                ("kind", "reduce_partial".into()),
                ("reducer", reducer.as_str().into()),
                (
                    "files",
                    Json::Arr(
                        files.iter().map(|f| f.as_str().into()).collect(),
                    ),
                ),
                ("out_file", out_file.as_str().into()),
            ]),
            WireWork::Synthetic {
                startup_us,
                per_item_us,
                items,
                launches,
            } => obj(vec![
                ("kind", "synthetic".into()),
                ("startup_us", (*startup_us as usize).into()),
                ("per_item_us", (*per_item_us as usize).into()),
                ("items", (*items).into()),
                ("launches", (*launches).into()),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<WireWork> {
        match str_field(v, "kind")? {
            "map" => Ok(WireWork::Map {
                mapper: str_field(v, "mapper")?.to_string(),
                pairs: arr_field(v, "pairs")?
                    .iter()
                    .map(|p| {
                        let pair = p.as_arr().ok_or_else(|| {
                            frame_err("pair is not an array")
                        })?;
                        match pair {
                            [Json::Str(i), Json::Str(o)] => {
                                Ok((i.clone(), o.clone()))
                            }
                            _ => Err(frame_err(
                                "pair is not [input, output]",
                            )),
                        }
                    })
                    .collect::<Result<_>>()?,
                // Protocol v1 peers send a boolean `mimo`; newer peers
                // send the AppType spelling in `mode`.
                mode: match str_field(v, "mode") {
                    Ok(m) => m.to_string(),
                    Err(_) => if bool_field(v, "mimo")? {
                        "mimo"
                    } else {
                        "siso"
                    }
                    .to_string(),
                },
            }),
            "reduce" => Ok(WireWork::Reduce {
                reducer: str_field(v, "reducer")?.to_string(),
                input_dir: str_field(v, "input_dir")?.to_string(),
                out_file: str_field(v, "out_file")?.to_string(),
            }),
            "reduce_partial" => Ok(WireWork::ReducePartial {
                reducer: str_field(v, "reducer")?.to_string(),
                files: arr_field(v, "files")?
                    .iter()
                    .map(|f| {
                        f.as_str().map(str::to_string).ok_or_else(|| {
                            frame_err("file entry is not a string")
                        })
                    })
                    .collect::<Result<_>>()?,
                out_file: str_field(v, "out_file")?.to_string(),
            }),
            "synthetic" => Ok(WireWork::Synthetic {
                startup_us: usize_field(v, "startup_us")? as u64,
                per_item_us: usize_field(v, "per_item_us")? as u64,
                items: usize_field(v, "items")?,
                launches: usize_field(v, "launches")?,
            }),
            other => Err(frame_err(format!("unknown work kind '{other}'"))),
        }
    }
}

/// Worker-measured execution outcome, mirrored from
/// [`crate::scheduler::exec::ExecOutcome`] in integer microseconds.
///
/// The three `*_us` timestamps are worker-side monotonic readings
/// relative to the worker's *connection epoch* (the instant it dialed
/// the coordinator): when the assignment was read off the socket, when
/// execution started, and when it finished.  They exist so the tracing
/// layer can split the coordinator-observed round trip into ship-out /
/// queue / execute / ship-back segments on one timeline (DESIGN.md
/// §12).  They are optional on the wire — pre-PR-9 peers omit them and
/// both sides still interoperate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct WireOutcome {
    pub startup_us: u64,
    pub compute_us: u64,
    pub launches: usize,
    pub items: usize,
    /// Worker clock (µs since its connection epoch) when the assign
    /// frame was received.
    pub recv_us: Option<u64>,
    /// Worker clock when a slot picked the task up and began executing.
    pub exec_start_us: Option<u64>,
    /// Worker clock when execution finished, just before the complete
    /// frame was written.
    pub exec_end_us: Option<u64>,
}

impl WireOutcome {
    pub fn startup(&self) -> Duration {
        Duration::from_micros(self.startup_us)
    }

    pub fn compute(&self) -> Duration {
        Duration::from_micros(self.compute_us)
    }
}

/// Everything that crosses the wire, in both directions.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker → coordinator, first frame of a connection.  `wire` is
    /// the PR-10 capability advertisement: its *presence* marks a peer
    /// that understands batch frames and [`Message::Revoke`], its value
    /// is the preferred post-handshake framing.  Legacy workers omit
    /// it and keep the per-task line-JSON protocol.
    Register {
        name: String,
        slots: usize,
        version: usize,
        wire: Option<WireMode>,
    },
    /// Coordinator → worker, the registration reply.  `wire` answers
    /// the advertisement in kind (absent from legacy coordinators, so
    /// a new worker talking to an old coordinator stays on per-task
    /// line-JSON).
    Registered {
        worker_id: u64,
        wire: Option<WireMode>,
    },
    /// Worker → coordinator liveness beacon; a lapse triggers
    /// reassignment of the worker's in-flight tasks.  Newer workers
    /// also stamp the beacon with their monotonic send time (µs since
    /// connection epoch) and the round-trip they measured off the last
    /// [`Message::HeartbeatAck`], which is what the coordinator's
    /// clock-offset estimator consumes; both fields are absent from
    /// pre-PR-9 beacons.
    Heartbeat {
        worker_id: u64,
        sent_us: Option<u64>,
        rtt_us: Option<u64>,
    },
    /// Coordinator → worker: echo of a heartbeat's `sent_us`, letting
    /// the worker measure the round trip.  Sent *only* to workers whose
    /// beacons carry `sent_us` — an unknown frame type breaks an old
    /// worker's read loop, so the capability is advertised first.
    HeartbeatAck { echo_us: u64 },
    /// Coordinator → worker: run this task.
    Assign {
        job: u64,
        task_idx: usize,
        task_id: usize,
        work: WireWork,
    },
    /// Coordinator → worker: run all of these tasks — one write+flush
    /// for a whole dispatch round instead of one frame per task.  Only
    /// sent to workers whose `Register` advertised the capability.
    AssignBatch { tasks: Vec<TaskAssign> },
    /// Coordinator → worker: forget this task if it is still queued
    /// (it was stolen by an idle peer).  Racing with execution is
    /// benign — the coordinator's ownership gate drops the losing
    /// completion.
    Revoke { job: u64, task_idx: usize },
    /// Worker → coordinator: the task succeeded.
    Complete {
        job: u64,
        task_idx: usize,
        outcome: WireOutcome,
    },
    /// Worker → coordinator: several tasks finished close together and
    /// their completions coalesced into one frame.  Only sent when the
    /// coordinator's `Registered` reply carried a `wire` answer.
    CompleteBatch { done: Vec<TaskComplete> },
    /// Worker → coordinator: the task raised a real (non-injected)
    /// error; the coordinator fails the job and cascades.
    Failed {
        job: u64,
        task_idx: usize,
        msg: String,
    },
    /// Coordinator → worker: drain and exit.
    Shutdown,
}

impl Message {
    /// One frame: compact JSON plus the terminating newline.
    pub fn encode(&self) -> String {
        let mut s = self.to_json().to_string_compact();
        s.push('\n');
        s
    }

    /// Parse one frame (without or with its trailing newline).  All
    /// failure modes return [`Error::Format`]; none panic.
    pub fn decode(line: &str) -> Result<Message> {
        let v = Json::parse(line.trim_end_matches(['\r', '\n']))
            .map_err(|e| frame_err(format!("bad frame json: {e}")))?;
        Message::from_json(&v)
    }

    pub fn to_json(&self) -> Json {
        match self {
            Message::Register {
                name,
                slots,
                version,
                wire,
            } => {
                let mut f = vec![
                    ("type", "register".into()),
                    ("name", name.as_str().into()),
                    ("slots", (*slots).into()),
                    ("version", (*version).into()),
                ];
                if let Some(w) = wire {
                    f.push(("wire", w.as_str().into()));
                }
                obj(f)
            }
            Message::Registered { worker_id, wire } => {
                let mut f = vec![
                    ("type", "registered".into()),
                    ("worker_id", (*worker_id as usize).into()),
                ];
                if let Some(w) = wire {
                    f.push(("wire", w.as_str().into()));
                }
                obj(f)
            }
            Message::Heartbeat {
                worker_id,
                sent_us,
                rtt_us,
            } => {
                let mut f = vec![
                    ("type", "heartbeat".into()),
                    ("worker_id", (*worker_id as usize).into()),
                ];
                if let Some(us) = sent_us {
                    f.push(("sent_us", (*us as usize).into()));
                }
                if let Some(us) = rtt_us {
                    f.push(("rtt_us", (*us as usize).into()));
                }
                obj(f)
            }
            Message::HeartbeatAck { echo_us } => obj(vec![
                ("type", "heartbeat_ack".into()),
                ("echo_us", (*echo_us as usize).into()),
            ]),
            Message::Assign {
                job,
                task_idx,
                task_id,
                work,
            } => obj(vec![
                ("type", "assign".into()),
                ("job", (*job as usize).into()),
                ("task_idx", (*task_idx).into()),
                ("task_id", (*task_id).into()),
                ("work", work.to_json()),
            ]),
            Message::AssignBatch { tasks } => obj(vec![
                ("type", "assign_batch".into()),
                (
                    "tasks",
                    Json::Arr(
                        tasks.iter().map(assign_to_json).collect(),
                    ),
                ),
            ]),
            Message::Revoke { job, task_idx } => obj(vec![
                ("type", "revoke".into()),
                ("job", (*job as usize).into()),
                ("task_idx", (*task_idx).into()),
            ]),
            Message::Complete {
                job,
                task_idx,
                outcome,
            } => obj(vec![
                ("type", "complete".into()),
                ("job", (*job as usize).into()),
                ("task_idx", (*task_idx).into()),
                ("outcome", outcome_to_json(outcome)),
            ]),
            Message::CompleteBatch { done } => obj(vec![
                ("type", "complete_batch".into()),
                (
                    "done",
                    Json::Arr(
                        done.iter()
                            .map(|c| {
                                obj(vec![
                                    ("job", (c.job as usize).into()),
                                    ("task_idx", c.task_idx.into()),
                                    (
                                        "outcome",
                                        outcome_to_json(&c.outcome),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Message::Failed {
                job,
                task_idx,
                msg,
            } => obj(vec![
                ("type", "failed".into()),
                ("job", (*job as usize).into()),
                ("task_idx", (*task_idx).into()),
                ("msg", msg.as_str().into()),
            ]),
            Message::Shutdown => obj(vec![("type", "shutdown".into())]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Message> {
        match str_field(v, "type")? {
            "register" => Ok(Message::Register {
                name: str_field(v, "name")?.to_string(),
                slots: usize_field(v, "slots")?,
                version: usize_field(v, "version")?,
                wire: opt_wire_field(v),
            }),
            "registered" => Ok(Message::Registered {
                worker_id: usize_field(v, "worker_id")? as u64,
                wire: opt_wire_field(v),
            }),
            "heartbeat" => Ok(Message::Heartbeat {
                worker_id: usize_field(v, "worker_id")? as u64,
                sent_us: opt_us_field(v, "sent_us"),
                rtt_us: opt_us_field(v, "rtt_us"),
            }),
            "heartbeat_ack" => Ok(Message::HeartbeatAck {
                echo_us: usize_field(v, "echo_us")? as u64,
            }),
            "assign" => {
                let t = assign_from_json(v)?;
                Ok(Message::Assign {
                    job: t.job,
                    task_idx: t.task_idx,
                    task_id: t.task_id,
                    work: t.work,
                })
            }
            "assign_batch" => Ok(Message::AssignBatch {
                tasks: arr_field(v, "tasks")?
                    .iter()
                    .map(assign_from_json)
                    .collect::<Result<_>>()?,
            }),
            "revoke" => Ok(Message::Revoke {
                job: usize_field(v, "job")? as u64,
                task_idx: usize_field(v, "task_idx")?,
            }),
            "complete" => Ok(Message::Complete {
                job: usize_field(v, "job")? as u64,
                task_idx: usize_field(v, "task_idx")?,
                outcome: outcome_from_json(v)?,
            }),
            "complete_batch" => Ok(Message::CompleteBatch {
                done: arr_field(v, "done")?
                    .iter()
                    .map(|c| {
                        Ok(TaskComplete {
                            job: usize_field(c, "job")? as u64,
                            task_idx: usize_field(c, "task_idx")?,
                            outcome: outcome_from_json(c)?,
                        })
                    })
                    .collect::<Result<_>>()?,
            }),
            "failed" => Ok(Message::Failed {
                job: usize_field(v, "job")? as u64,
                task_idx: usize_field(v, "task_idx")?,
                msg: str_field(v, "msg")?.to_string(),
            }),
            "shutdown" => Ok(Message::Shutdown),
            other => {
                Err(frame_err(format!("unknown message type '{other}'")))
            }
        }
    }
}

// -- shared (de)serializers for single and batched frames ------------------

fn assign_to_json(t: &TaskAssign) -> Json {
    obj(vec![
        ("job", (t.job as usize).into()),
        ("task_idx", t.task_idx.into()),
        ("task_id", t.task_id.into()),
        ("work", t.work.to_json()),
    ])
}

fn assign_from_json(v: &Json) -> Result<TaskAssign> {
    Ok(TaskAssign {
        job: usize_field(v, "job")? as u64,
        task_idx: usize_field(v, "task_idx")?,
        task_id: usize_field(v, "task_id")?,
        work: WireWork::from_json(
            v.get("work")
                .ok_or_else(|| frame_err("assign without work"))?,
        )?,
    })
}

fn outcome_to_json(outcome: &WireOutcome) -> Json {
    let mut f: Vec<(&str, Json)> = vec![
        ("startup_us", (outcome.startup_us as usize).into()),
        ("compute_us", (outcome.compute_us as usize).into()),
        ("launches", outcome.launches.into()),
        ("items", outcome.items.into()),
    ];
    for (k, us) in [
        ("recv_us", outcome.recv_us),
        ("exec_start_us", outcome.exec_start_us),
        ("exec_end_us", outcome.exec_end_us),
    ] {
        if let Some(us) = us {
            f.push((k, (us as usize).into()));
        }
    }
    obj(f)
}

/// Decode the `outcome` object of a complete frame (or batch entry).
fn outcome_from_json(v: &Json) -> Result<WireOutcome> {
    let o = v
        .get("outcome")
        .ok_or_else(|| frame_err("complete without outcome"))?;
    Ok(WireOutcome {
        startup_us: usize_field(o, "startup_us")? as u64,
        compute_us: usize_field(o, "compute_us")? as u64,
        launches: usize_field(o, "launches")?,
        items: usize_field(o, "items")?,
        // Optional on the wire: pre-PR-9 workers don't stamp their
        // frames.
        recv_us: opt_us_field(o, "recv_us"),
        exec_start_us: opt_us_field(o, "exec_start_us"),
        exec_end_us: opt_us_field(o, "exec_end_us"),
    })
}

// -- field accessors that turn shape errors into Error::Format ------------

fn fields(v: &Json) -> Result<&BTreeMap<String, Json>> {
    v.as_obj()
        .ok_or_else(|| frame_err("frame is not a JSON object"))
}

fn str_field<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    fields(v)?
        .get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| frame_err(format!("missing string field '{key}'")))
}

fn usize_field(v: &Json, key: &str) -> Result<usize> {
    fields(v)?
        .get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| {
            frame_err(format!("missing non-negative int field '{key}'"))
        })
}

/// An optional microsecond field: `None` when absent or non-numeric
/// (older peers simply omit these keys).
fn opt_us_field(v: &Json, key: &str) -> Option<u64> {
    v.as_obj()?.get(key).and_then(Json::as_usize).map(|n| n as u64)
}

/// The optional `wire` capability field: `None` when absent (a legacy
/// peer), lenient on unknown values (a future peer's preference we
/// don't know degrades to JSON rather than failing registration).
fn opt_wire_field(v: &Json) -> Option<WireMode> {
    v.as_obj()?
        .get("wire")
        .and_then(Json::as_str)
        .map(WireMode::lenient)
}

fn bool_field(v: &Json, key: &str) -> Result<bool> {
    fields(v)?
        .get(key)
        .and_then(Json::as_bool)
        .ok_or_else(|| frame_err(format!("missing bool field '{key}'")))
}

fn arr_field<'a>(v: &'a Json, key: &str) -> Result<&'a [Json]> {
    fields(v)?
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| frame_err(format!("missing array field '{key}'")))
}

// -- binary codec ----------------------------------------------------------
//
// Payload encoding for the negotiated `--wire=binary` framing: one tag
// byte, then the variant's fields in order.  Integers are LEB128
// varints, strings are varint-length-prefixed UTF-8, options carry a
// presence byte.  The transport adds the 4-byte big-endian frame
// length (DESIGN.md §13 documents the full grammar).  Decoding is
// bounds-checked at every read — truncation, trailing garbage, and
// unknown tags all surface as [`Error::Format`], never a panic.

const TAG_REGISTER: u8 = 1;
const TAG_REGISTERED: u8 = 2;
const TAG_HEARTBEAT: u8 = 3;
const TAG_HEARTBEAT_ACK: u8 = 4;
const TAG_ASSIGN: u8 = 5;
const TAG_COMPLETE: u8 = 6;
const TAG_FAILED: u8 = 7;
const TAG_SHUTDOWN: u8 = 8;
const TAG_ASSIGN_BATCH: u8 = 9;
const TAG_COMPLETE_BATCH: u8 = 10;
const TAG_REVOKE: u8 = 11;

const WORK_MAP: u8 = 0;
const WORK_REDUCE: u8 = 1;
const WORK_REDUCE_PARTIAL: u8 = 2;
const WORK_SYNTHETIC: u8 = 3;

fn put_u64(b: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            b.push(byte);
            return;
        }
        b.push(byte | 0x80);
    }
}

fn put_str(b: &mut Vec<u8>, s: &str) {
    put_u64(b, s.len() as u64);
    b.extend_from_slice(s.as_bytes());
}

fn put_opt_u64(b: &mut Vec<u8>, v: Option<u64>) {
    match v {
        None => b.push(0),
        Some(v) => {
            b.push(1);
            put_u64(b, v);
        }
    }
}

fn put_assign(b: &mut Vec<u8>, t: &TaskAssign) {
    put_u64(b, t.job);
    put_u64(b, t.task_idx as u64);
    put_u64(b, t.task_id as u64);
    put_work(b, &t.work);
}

fn put_work(b: &mut Vec<u8>, w: &WireWork) {
    match w {
        WireWork::Map {
            mapper,
            pairs,
            mode,
        } => {
            b.push(WORK_MAP);
            put_str(b, mapper);
            put_u64(b, pairs.len() as u64);
            for (i, o) in pairs {
                put_str(b, i);
                put_str(b, o);
            }
            put_str(b, mode);
        }
        WireWork::Reduce {
            reducer,
            input_dir,
            out_file,
        } => {
            b.push(WORK_REDUCE);
            put_str(b, reducer);
            put_str(b, input_dir);
            put_str(b, out_file);
        }
        WireWork::ReducePartial {
            reducer,
            files,
            out_file,
        } => {
            b.push(WORK_REDUCE_PARTIAL);
            put_str(b, reducer);
            put_u64(b, files.len() as u64);
            for f in files {
                put_str(b, f);
            }
            put_str(b, out_file);
        }
        WireWork::Synthetic {
            startup_us,
            per_item_us,
            items,
            launches,
        } => {
            b.push(WORK_SYNTHETIC);
            put_u64(b, *startup_us);
            put_u64(b, *per_item_us);
            put_u64(b, *items as u64);
            put_u64(b, *launches as u64);
        }
    }
}

fn put_outcome(b: &mut Vec<u8>, o: &WireOutcome) {
    put_u64(b, o.startup_us);
    put_u64(b, o.compute_us);
    put_u64(b, o.launches as u64);
    put_u64(b, o.items as u64);
    put_opt_u64(b, o.recv_us);
    put_opt_u64(b, o.exec_start_us);
    put_opt_u64(b, o.exec_end_us);
}

/// Bounds-checked cursor over a binary frame payload.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn u8(&mut self) -> Result<u8> {
        let v = *self
            .b
            .get(self.i)
            .ok_or_else(|| frame_err("binary frame truncated"))?;
        self.i += 1;
        Ok(v)
    }

    fn u64(&mut self) -> Result<u64> {
        let mut v = 0u64;
        for shift in (0..64).step_by(7) {
            let byte = self.u8()?;
            v |= u64::from(byte & 0x7f) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
        }
        Err(frame_err("varint too long"))
    }

    fn count(&mut self) -> Result<usize> {
        let n = self.u64()? as usize;
        // Every element consumes at least one byte, so a count larger
        // than the remaining payload is hostile — reject it before
        // reserving anything.
        if n > self.remaining() {
            return Err(frame_err("binary frame truncated"));
        }
        Ok(n)
    }

    fn str(&mut self) -> Result<String> {
        let n = self.count()?;
        let bytes = &self.b[self.i..self.i + n];
        self.i += n;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| frame_err("binary frame is not valid UTF-8"))
    }

    fn opt_u64(&mut self) -> Result<Option<u64>> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.u64()?)),
            _ => Err(frame_err("bad option discriminant")),
        }
    }

    fn assign(&mut self) -> Result<TaskAssign> {
        Ok(TaskAssign {
            job: self.u64()?,
            task_idx: self.u64()? as usize,
            task_id: self.u64()? as usize,
            work: self.work()?,
        })
    }

    fn work(&mut self) -> Result<WireWork> {
        match self.u8()? {
            WORK_MAP => {
                let mapper = self.str()?;
                let n = self.count()?;
                let mut pairs = Vec::with_capacity(n);
                for _ in 0..n {
                    pairs.push((self.str()?, self.str()?));
                }
                Ok(WireWork::Map {
                    mapper,
                    pairs,
                    mode: self.str()?,
                })
            }
            WORK_REDUCE => Ok(WireWork::Reduce {
                reducer: self.str()?,
                input_dir: self.str()?,
                out_file: self.str()?,
            }),
            WORK_REDUCE_PARTIAL => {
                let reducer = self.str()?;
                let n = self.count()?;
                let mut files = Vec::with_capacity(n);
                for _ in 0..n {
                    files.push(self.str()?);
                }
                Ok(WireWork::ReducePartial {
                    reducer,
                    files,
                    out_file: self.str()?,
                })
            }
            WORK_SYNTHETIC => Ok(WireWork::Synthetic {
                startup_us: self.u64()?,
                per_item_us: self.u64()?,
                items: self.u64()? as usize,
                launches: self.u64()? as usize,
            }),
            other => {
                Err(frame_err(format!("unknown work tag {other}")))
            }
        }
    }

    fn outcome(&mut self) -> Result<WireOutcome> {
        Ok(WireOutcome {
            startup_us: self.u64()?,
            compute_us: self.u64()?,
            launches: self.u64()? as usize,
            items: self.u64()? as usize,
            recv_us: self.opt_u64()?,
            exec_start_us: self.opt_u64()?,
            exec_end_us: self.opt_u64()?,
        })
    }
}

impl Message {
    /// Binary frame payload (the transport prepends the 4-byte
    /// big-endian length).
    pub fn encode_binary(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(64);
        match self {
            Message::Register {
                name,
                slots,
                version,
                wire,
            } => {
                b.push(TAG_REGISTER);
                put_str(&mut b, name);
                put_u64(&mut b, *slots as u64);
                put_u64(&mut b, *version as u64);
                b.push(match wire {
                    None => 0,
                    Some(WireMode::Json) => 1,
                    Some(WireMode::Binary) => 2,
                });
            }
            Message::Registered { worker_id, wire } => {
                b.push(TAG_REGISTERED);
                put_u64(&mut b, *worker_id);
                b.push(match wire {
                    None => 0,
                    Some(WireMode::Json) => 1,
                    Some(WireMode::Binary) => 2,
                });
            }
            Message::Heartbeat {
                worker_id,
                sent_us,
                rtt_us,
            } => {
                b.push(TAG_HEARTBEAT);
                put_u64(&mut b, *worker_id);
                put_opt_u64(&mut b, *sent_us);
                put_opt_u64(&mut b, *rtt_us);
            }
            Message::HeartbeatAck { echo_us } => {
                b.push(TAG_HEARTBEAT_ACK);
                put_u64(&mut b, *echo_us);
            }
            Message::Assign {
                job,
                task_idx,
                task_id,
                work,
            } => {
                b.push(TAG_ASSIGN);
                put_u64(&mut b, *job);
                put_u64(&mut b, *task_idx as u64);
                put_u64(&mut b, *task_id as u64);
                put_work(&mut b, work);
            }
            Message::AssignBatch { tasks } => {
                b.push(TAG_ASSIGN_BATCH);
                put_u64(&mut b, tasks.len() as u64);
                for t in tasks {
                    put_assign(&mut b, t);
                }
            }
            Message::Revoke { job, task_idx } => {
                b.push(TAG_REVOKE);
                put_u64(&mut b, *job);
                put_u64(&mut b, *task_idx as u64);
            }
            Message::Complete {
                job,
                task_idx,
                outcome,
            } => {
                b.push(TAG_COMPLETE);
                put_u64(&mut b, *job);
                put_u64(&mut b, *task_idx as u64);
                put_outcome(&mut b, outcome);
            }
            Message::CompleteBatch { done } => {
                b.push(TAG_COMPLETE_BATCH);
                put_u64(&mut b, done.len() as u64);
                for c in done {
                    put_u64(&mut b, c.job);
                    put_u64(&mut b, c.task_idx as u64);
                    put_outcome(&mut b, &c.outcome);
                }
            }
            Message::Failed {
                job,
                task_idx,
                msg,
            } => {
                b.push(TAG_FAILED);
                put_u64(&mut b, *job);
                put_u64(&mut b, *task_idx as u64);
                put_str(&mut b, msg);
            }
            Message::Shutdown => b.push(TAG_SHUTDOWN),
        }
        b
    }

    /// Parse one binary frame payload.  All failure modes — truncation,
    /// unknown tags, trailing bytes, bad UTF-8 — return
    /// [`Error::Format`]; none panic.
    pub fn decode_binary(bytes: &[u8]) -> Result<Message> {
        let mut c = Cur { b: bytes, i: 0 };
        let opt_wire = |c: &mut Cur| -> Result<Option<WireMode>> {
            match c.u8()? {
                0 => Ok(None),
                1 => Ok(Some(WireMode::Json)),
                2 => Ok(Some(WireMode::Binary)),
                _ => Err(frame_err("bad wire discriminant")),
            }
        };
        let msg = match c.u8()? {
            TAG_REGISTER => Message::Register {
                name: c.str()?,
                slots: c.u64()? as usize,
                version: c.u64()? as usize,
                wire: opt_wire(&mut c)?,
            },
            TAG_REGISTERED => Message::Registered {
                worker_id: c.u64()?,
                wire: opt_wire(&mut c)?,
            },
            TAG_HEARTBEAT => Message::Heartbeat {
                worker_id: c.u64()?,
                sent_us: c.opt_u64()?,
                rtt_us: c.opt_u64()?,
            },
            TAG_HEARTBEAT_ACK => {
                Message::HeartbeatAck { echo_us: c.u64()? }
            }
            TAG_ASSIGN => {
                let t = c.assign()?;
                Message::Assign {
                    job: t.job,
                    task_idx: t.task_idx,
                    task_id: t.task_id,
                    work: t.work,
                }
            }
            TAG_ASSIGN_BATCH => {
                let n = c.count()?;
                let mut tasks = Vec::with_capacity(n);
                for _ in 0..n {
                    tasks.push(c.assign()?);
                }
                Message::AssignBatch { tasks }
            }
            TAG_REVOKE => Message::Revoke {
                job: c.u64()?,
                task_idx: c.u64()? as usize,
            },
            TAG_COMPLETE => Message::Complete {
                job: c.u64()?,
                task_idx: c.u64()? as usize,
                outcome: c.outcome()?,
            },
            TAG_COMPLETE_BATCH => {
                let n = c.count()?;
                let mut done = Vec::with_capacity(n);
                for _ in 0..n {
                    done.push(TaskComplete {
                        job: c.u64()?,
                        task_idx: c.u64()? as usize,
                        outcome: c.outcome()?,
                    });
                }
                Message::CompleteBatch { done }
            }
            TAG_FAILED => Message::Failed {
                job: c.u64()?,
                task_idx: c.u64()? as usize,
                msg: c.str()?,
            },
            TAG_SHUTDOWN => Message::Shutdown,
            other => {
                return Err(frame_err(format!(
                    "unknown binary message tag {other}"
                )))
            }
        };
        if c.remaining() != 0 {
            return Err(frame_err("trailing bytes after binary frame"));
        }
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Message) {
        let line = msg.encode();
        assert!(line.ends_with('\n'), "framed");
        assert_eq!(Message::decode(&line).unwrap(), msg, "{line}");
        // Every message must survive the binary codec identically.
        let bin = msg.encode_binary();
        assert_eq!(Message::decode_binary(&bin).unwrap(), msg, "{line}");
    }

    #[test]
    fn all_message_shapes_roundtrip() {
        roundtrip(Message::Register {
            name: "worker-1".into(),
            slots: 4,
            version: PROTOCOL_VERSION,
            wire: None,
        });
        roundtrip(Message::Register {
            name: "worker-2".into(),
            slots: 2,
            version: PROTOCOL_VERSION,
            wire: Some(WireMode::Binary),
        });
        roundtrip(Message::Register {
            name: "worker-3".into(),
            slots: 2,
            version: PROTOCOL_VERSION,
            wire: Some(WireMode::Json),
        });
        roundtrip(Message::Registered {
            worker_id: 7,
            wire: None,
        });
        roundtrip(Message::Registered {
            worker_id: 7,
            wire: Some(WireMode::Binary),
        });
        roundtrip(Message::Heartbeat {
            worker_id: 7,
            sent_us: None,
            rtt_us: None,
        });
        roundtrip(Message::Heartbeat {
            worker_id: 7,
            sent_us: Some(1_000_123),
            rtt_us: Some(850),
        });
        roundtrip(Message::HeartbeatAck { echo_us: 1_000_123 });
        roundtrip(Message::Assign {
            job: 3,
            task_idx: 0,
            task_id: 1,
            work: WireWork::Map {
                mapper: "wordcount:ign.txt".into(),
                pairs: vec![("in/a.txt".into(), "out/a.txt.out".into())],
                mode: "mimo".into(),
            },
        });
        roundtrip(Message::Assign {
            job: 3,
            task_idx: 1,
            task_id: 2,
            work: WireWork::Map {
                mapper: "stream:./mapper.sh ref.txt".into(),
                pairs: vec![("in/b.txt".into(), "out/b.txt.out".into())],
                mode: "spmd".into(),
            },
        });
        roundtrip(Message::Assign {
            job: 4,
            task_idx: 2,
            task_id: 3,
            work: WireWork::Reduce {
                reducer: "wordcount-reducer".into(),
                input_dir: "out".into(),
                out_file: "out/llmapreduce.out".into(),
            },
        });
        roundtrip(Message::Assign {
            job: 5,
            task_idx: 1,
            task_id: 2,
            work: WireWork::ReducePartial {
                reducer: "wordcount-reducer".into(),
                files: vec!["a.out".into(), "b.out".into()],
                out_file: ".partials.9/part_00001".into(),
            },
        });
        roundtrip(Message::Assign {
            job: 6,
            task_idx: 0,
            task_id: 1,
            work: WireWork::Synthetic {
                startup_us: 1500,
                per_item_us: 10,
                items: 8,
                launches: 1,
            },
        });
        roundtrip(Message::Complete {
            job: 3,
            task_idx: 0,
            outcome: WireOutcome {
                startup_us: 1200,
                compute_us: 3400,
                launches: 1,
                items: 5,
                recv_us: None,
                exec_start_us: None,
                exec_end_us: None,
            },
        });
        roundtrip(Message::Complete {
            job: 3,
            task_idx: 1,
            outcome: WireOutcome {
                startup_us: 1200,
                compute_us: 3400,
                launches: 1,
                items: 5,
                recv_us: Some(50_000),
                exec_start_us: Some(50_400),
                exec_end_us: Some(55_000),
            },
        });
        roundtrip(Message::Failed {
            job: 3,
            task_idx: 1,
            msg: "app 'x' failed on in/a.txt: poisoned".into(),
        });
        roundtrip(Message::Revoke { job: 3, task_idx: 7 });
        roundtrip(Message::Shutdown);
    }

    fn synth_assign(i: usize) -> TaskAssign {
        TaskAssign {
            job: 9,
            task_idx: i,
            task_id: i + 1,
            work: WireWork::Synthetic {
                startup_us: 100,
                per_item_us: 10,
                items: i,
                launches: 1,
            },
        }
    }

    #[test]
    fn batch_frames_roundtrip_with_zero_one_and_many_entries() {
        for n in [0usize, 1, 37] {
            roundtrip(Message::AssignBatch {
                tasks: (0..n).map(synth_assign).collect(),
            });
            roundtrip(Message::CompleteBatch {
                done: (0..n)
                    .map(|i| TaskComplete {
                        job: 9,
                        task_idx: i,
                        outcome: WireOutcome {
                            startup_us: 5,
                            compute_us: 17,
                            launches: 1,
                            items: i,
                            recv_us: (i % 2 == 0).then_some(40),
                            exec_start_us: None,
                            exec_end_us: (i % 2 == 0).then_some(90),
                        },
                    })
                    .collect(),
            });
        }
    }

    #[test]
    fn pre_pr10_register_frames_decode_as_legacy() {
        // A pre-PR-10 worker registers without the `wire` field; the
        // decoded capability must be None so the coordinator keeps
        // speaking per-task line-JSON to it.
        let line = r#"{"type":"register","name":"w0","slots":2,"version":1}"#;
        assert_eq!(
            Message::decode(line).unwrap(),
            Message::Register {
                name: "w0".into(),
                slots: 2,
                version: 1,
                wire: None,
            }
        );
        // Same for a legacy coordinator's reply.
        let line = r#"{"type":"registered","worker_id":4}"#;
        assert_eq!(
            Message::decode(line).unwrap(),
            Message::Registered {
                worker_id: 4,
                wire: None,
            }
        );
        // A future peer's unknown preference degrades to json instead
        // of failing the handshake.
        let line = r#"{"type":"register","name":"w0","slots":2,"version":1,"wire":"zstd"}"#;
        let Message::Register { wire, .. } =
            Message::decode(line).unwrap()
        else {
            panic!("register stays register");
        };
        assert_eq!(wire, Some(WireMode::Json));
    }

    #[test]
    fn malformed_binary_frames_are_format_errors_not_panics() {
        // Truncations of a real frame at every split point, plus raw
        // garbage, must all fail cleanly.
        let full = Message::AssignBatch {
            tasks: (0..3).map(synth_assign).collect(),
        }
        .encode_binary();
        for cut in 0..full.len() {
            let err = Message::decode_binary(&full[..cut]).unwrap_err();
            assert!(
                matches!(err, Error::Format { kind: "wire", .. }),
                "cut at {cut} -> {err}"
            );
        }
        for bad in [
            &[0xffu8][..],              // unknown tag
            &[TAG_SHUTDOWN, 0x01],      // trailing bytes
            &[TAG_HEARTBEAT_ACK, 0x80], // dangling varint
            &[TAG_REGISTER, 0x02, b'h'], // truncated string
            &[TAG_HEARTBEAT, 0x01, 0x03, 0x02], // bad option byte
        ] {
            let err = Message::decode_binary(bad).unwrap_err();
            assert!(
                matches!(err, Error::Format { kind: "wire", .. }),
                "{bad:?} -> {err}"
            );
        }
    }

    #[test]
    fn paths_with_escapes_survive() {
        roundtrip(Message::Assign {
            job: 1,
            task_idx: 0,
            task_id: 1,
            work: WireWork::Map {
                mapper: "wordcount".into(),
                pairs: vec![(
                    "in/sp ace/\"quoted\".txt".into(),
                    "out/uni-é😀.out".into(),
                )],
                mode: "siso".into(),
            },
        });
    }

    #[test]
    fn legacy_mimo_bool_frames_still_decode() {
        // A protocol-v1 coordinator sends `mimo` instead of `mode`.
        for (legacy, expect) in [("true", "mimo"), ("false", "siso")] {
            let line = format!(
                r#"{{"type":"assign","job":1,"task_idx":0,"task_id":1,"work":{{"kind":"map","mapper":"cat","pairs":[["a","b"]],"mimo":{legacy}}}}}"#
            );
            let Message::Assign { work, .. } =
                Message::decode(&line).unwrap()
            else {
                panic!("assign stays assign");
            };
            assert_eq!(
                work,
                WireWork::Map {
                    mapper: "cat".into(),
                    pairs: vec![("a".into(), "b".into())],
                    mode: expect.into(),
                }
            );
        }
        // A map frame with neither field is malformed.
        let bad = r#"{"type":"assign","job":1,"task_idx":0,"task_id":1,"work":{"kind":"map","mapper":"cat","pairs":[]}}"#;
        assert!(Message::decode(bad).is_err());
    }

    #[test]
    fn worker_timestamps_roundtrip_across_every_presence_combination() {
        // Property-style sweep: each of the three optional stamps is
        // independently present or absent and the frame must survive a
        // roundtrip either way (workers may be upgraded piecemeal, so
        // no coordinator/worker version lockstep).
        for bits in 0u8..8 {
            let some = |b: u8, v: u64| (bits & b != 0).then_some(v);
            roundtrip(Message::Complete {
                job: 9,
                task_idx: bits as usize,
                outcome: WireOutcome {
                    startup_us: 10,
                    compute_us: 20,
                    launches: 1,
                    items: 2,
                    recv_us: some(1, 111),
                    exec_start_us: some(2, 222),
                    exec_end_us: some(4, 333),
                },
            });
        }
        for bits in 0u8..4 {
            let some = |b: u8, v: u64| (bits & b != 0).then_some(v);
            roundtrip(Message::Heartbeat {
                worker_id: bits as u64,
                sent_us: some(1, 444),
                rtt_us: some(2, 555),
            });
        }
    }

    #[test]
    fn pre_pr9_frames_without_timestamps_still_decode() {
        // Raw frames as a pre-PR-9 peer would emit them: no sent_us /
        // rtt_us on heartbeats, no worker stamps in the outcome.
        let hb = r#"{"type":"heartbeat","worker_id":3}"#;
        assert_eq!(
            Message::decode(hb).unwrap(),
            Message::Heartbeat {
                worker_id: 3,
                sent_us: None,
                rtt_us: None,
            }
        );
        let done = r#"{"type":"complete","job":2,"task_idx":4,"outcome":{"startup_us":900,"compute_us":8100,"launches":1,"items":3}}"#;
        assert_eq!(
            Message::decode(done).unwrap(),
            Message::Complete {
                job: 2,
                task_idx: 4,
                outcome: WireOutcome {
                    startup_us: 900,
                    compute_us: 8100,
                    launches: 1,
                    items: 3,
                    recv_us: None,
                    exec_start_us: None,
                    exec_end_us: None,
                },
            }
        );
        // And the other direction: a stamped frame from a new worker
        // decodes on this side with every stamp intact.
        let stamped = r#"{"type":"complete","job":2,"task_idx":4,"outcome":{"startup_us":900,"compute_us":8100,"launches":1,"items":3,"recv_us":70,"exec_start_us":80,"exec_end_us":9000}}"#;
        let Message::Complete { outcome, .. } =
            Message::decode(stamped).unwrap()
        else {
            panic!("complete stays complete");
        };
        assert_eq!(outcome.recv_us, Some(70));
        assert_eq!(outcome.exec_start_us, Some(80));
        assert_eq!(outcome.exec_end_us, Some(9000));
    }

    #[test]
    fn malformed_frames_are_format_errors_not_panics() {
        for bad in [
            "",
            "not json",
            "{}",
            "[1,2,3]",
            r#"{"type":"warp"}"#,
            r#"{"type":"register","name":"w"}"#, // missing slots/version
            r#"{"type":"assign","job":1,"task_idx":0,"task_id":1}"#,
            r#"{"type":"assign","job":1,"task_idx":0,"task_id":1,"work":{"kind":"map"}}"#,
            r#"{"type":"complete","job":1,"task_idx":0}"#,
            r#"{"type":"register","name":"w","slots":-2,"version":1}"#,
            r#"{"type":"register","name":"w","slots":1.5,"version":1}"#,
        ] {
            let err = Message::decode(bad).unwrap_err();
            assert!(
                matches!(err, Error::Format { kind: "wire", .. }),
                "{bad:?} -> {err}"
            );
        }
    }

    #[test]
    fn wire_work_mirrors_task_work() {
        use crate::options::AppType;
        use crate::scheduler::TaskWork;
        use std::path::PathBuf;
        use std::sync::Arc;
        let work = TaskWork::Map {
            app: crate::apps::wordcount::WordCountApp::new(Some(
                PathBuf::from("/refs/ign.txt"),
            )),
            pairs: vec![(
                PathBuf::from("/data/a"),
                PathBuf::from("/data/a.out"),
            )],
            mode: AppType::Mimo,
        };
        assert_eq!(
            WireWork::from_work(&work),
            WireWork::Map {
                mapper: "wordcount:/refs/ign.txt".into(),
                pairs: vec![("/data/a".into(), "/data/a.out".into())],
                mode: "mimo".into(),
            }
        );
        let spmd = TaskWork::Map {
            app: crate::apps::wordcount::WordCountApp::new(None),
            pairs: vec![(
                PathBuf::from("/data/b"),
                PathBuf::from("/data/b.out"),
            )],
            mode: AppType::Spmd,
        };
        let WireWork::Map { mode, .. } = WireWork::from_work(&spmd) else {
            panic!("map stays map");
        };
        assert_eq!(mode, "spmd");
        let red = TaskWork::Reduce {
            app: Arc::new(crate::apps::wordcount::WordCountReducer),
            input_dir: PathBuf::from("/data/out"),
            out_file: PathBuf::from("/data/out/red"),
        };
        assert_eq!(
            WireWork::from_work(&red),
            WireWork::Reduce {
                reducer: "wordcount-reducer".into(),
                input_dir: "/data/out".into(),
                out_file: "/data/out/red".into(),
            }
        );
    }

    #[test]
    fn relative_paths_absolutize_against_coordinator_cwd() {
        use crate::options::AppType;
        use crate::scheduler::TaskWork;
        use std::path::PathBuf;
        let work = TaskWork::Map {
            app: crate::apps::wordcount::WordCountApp::new(None),
            pairs: vec![(PathBuf::from("in/a"), PathBuf::from("out/a"))],
            mode: AppType::Siso,
        };
        let WireWork::Map { pairs, .. } = WireWork::from_work(&work)
        else {
            panic!("map stays map");
        };
        let cwd = std::env::current_dir().unwrap();
        assert_eq!(pairs[0].0, cwd.join("in/a").to_string_lossy());
        assert_eq!(pairs[0].1, cwd.join("out/a").to_string_lossy());
    }
}
