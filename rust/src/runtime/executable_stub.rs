//! Stub XLA executable: compiled when the `xla-runtime` feature is off.
//!
//! Mirrors the public surface of the real
//! `runtime::executable::XlaExecutable` so the apps layer compiles
//! unchanged; `load` always fails, so SISO/MIMO launch accounting and the
//! schedulers stay testable without the native XLA library.

use std::path::Path;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::runtime::artifacts::{ArtifactEntry, InputSpec};

/// A compiled, executable artifact (stub: cannot be constructed).
pub struct XlaExecutable {
    name: String,
    inputs: Vec<InputSpec>,
}

impl XlaExecutable {
    /// Parse and compile the HLO text at `path` — always fails in the
    /// stub build.
    pub fn load(
        name: &str,
        path: &Path,
        _inputs: &[InputSpec],
    ) -> Result<Self> {
        Err(Error::Runtime(format!(
            "cannot compile '{name}' from {}: built without the \
             `xla-runtime` cargo feature",
            path.display()
        )))
    }

    /// Load straight from a manifest entry.
    pub fn from_entry(entry: &ArtifactEntry) -> Result<Self> {
        Self::load(&entry.name, &entry.path, &entry.inputs)
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn compile_time(&self) -> Duration {
        Duration::ZERO
    }

    pub fn input_specs(&self) -> &[InputSpec] {
        &self.inputs
    }

    /// Execute on f32 buffers — unreachable in the stub build (`load`
    /// never succeeds), kept for API parity.
    pub fn run_f32(&self, _args: &[&[f32]]) -> Result<Vec<f32>> {
        Err(Error::Runtime(format!(
            "cannot execute '{}': built without the `xla-runtime` \
             cargo feature",
            self.name
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_load_fails_with_feature_hint() {
        let err = XlaExecutable::load(
            "matmul_pair",
            Path::new("/nonexistent.hlo.txt"),
            &[],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("xla-runtime"), "{err}");
    }
}
