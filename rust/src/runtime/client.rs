//! Per-thread PJRT client.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based — not `Send`/`Sync` — so
//! each engine worker thread boots its own client on first use.  This is
//! faithful to the paper's cost model: every concurrently-running array
//! task on a real cluster boots its own MATLAB/JVM; here every worker
//! thread boots its own PJRT client, and the per-*application-launch*
//! start-up cost that MIMO amortizes is the XLA **compile** in
//! [`super::executable`], paid per `MapApp::startup()`.

use std::cell::OnceCell;

use crate::error::{Error, Result};

thread_local! {
    static CLIENT: OnceCell<xla::PjRtClient> = const { OnceCell::new() };
}

/// Get this thread's PJRT CPU client (booted on first use).
pub fn thread_client() -> Result<xla::PjRtClient> {
    CLIENT.with(|cell| {
        if cell.get().is_none() {
            let client = xla::PjRtClient::cpu().map_err(|e| {
                Error::Runtime(format!("PjRtClient::cpu: {e}"))
            })?;
            let _ = cell.set(client);
        }
        // PjRtClient is an Rc handle; cloning is cheap and shares the
        // underlying client.
        Ok(cell.get().expect("just set").clone())
    })
}

/// Back-compat alias used by `main.rs` inspect.
pub fn global_client() -> Result<xla::PjRtClient> {
    thread_client()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_is_cpu() {
        let c = thread_client().unwrap();
        assert_eq!(c.platform_name(), "cpu");
        assert!(c.device_count() >= 1);
    }

    #[test]
    fn second_call_reuses() {
        // Same underlying client (thread-local cache): platform data
        // agrees and no panic on repeated boot.
        let a = thread_client().unwrap();
        let b = thread_client().unwrap();
        assert_eq!(a.platform_name(), b.platform_name());
    }

    #[test]
    fn each_thread_gets_a_client() {
        std::thread::scope(|s| {
            for _ in 0..2 {
                s.spawn(|| {
                    let c = thread_client().unwrap();
                    assert_eq!(c.platform_name(), "cpu");
                });
            }
        });
    }
}
