//! Artifact discovery and manifest validation.
//!
//! `make artifacts` (python, build time) writes `artifacts/<name>.hlo.txt`
//! plus `manifest.json` describing each entry's input shapes/dtypes.  The
//! Rust runtime never regenerates these — python is not on the request
//! path — it only locates and validates them here.

use std::path::{Path, PathBuf};

use crate::error::{Error, IoContext, Result};
use crate::util::json::Json;

/// Input signature of one artifact entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl InputSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One entry from the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    /// Absolute path to the `.hlo.txt` file.
    pub path: PathBuf,
    pub inputs: Vec<InputSpec>,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

/// Locate the artifacts directory:
/// 1. `$LLMR_ARTIFACTS` if set;
/// 2. `./artifacts` upward from the current directory (so examples work
///    from anywhere inside the repo).
pub fn find_artifacts_dir() -> Result<PathBuf> {
    if let Ok(dir) = std::env::var("LLMR_ARTIFACTS") {
        let p = PathBuf::from(dir);
        if p.join("manifest.json").is_file() {
            return Ok(p);
        }
        return Err(Error::Artifact {
            name: "manifest.json".into(),
            reason: format!("$LLMR_ARTIFACTS={} has no manifest", p.display()),
        });
    }
    let mut cur = std::env::current_dir()
        .map_err(|e| Error::io(PathBuf::from("."), e))?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").is_file() {
            return Ok(cand);
        }
        if !cur.pop() {
            return Err(Error::Artifact {
                name: "manifest.json".into(),
                reason: "no artifacts/ directory found — run `make artifacts`"
                    .into(),
            });
        }
    }
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).at(&manifest_path)?;
        let doc = Json::parse(&text)?;
        if doc.get("format").and_then(Json::as_str) != Some("hlo-text") {
            return Err(Error::Artifact {
                name: "manifest.json".into(),
                reason: "format != hlo-text".into(),
            });
        }
        let entries_obj = doc
            .get("entries")
            .and_then(Json::as_obj)
            .ok_or_else(|| Error::Artifact {
                name: "manifest.json".into(),
                reason: "missing entries object".into(),
            })?;
        let mut entries = Vec::with_capacity(entries_obj.len());
        for (name, entry) in entries_obj {
            let file =
                entry.get("file").and_then(Json::as_str).ok_or_else(|| {
                    Error::Artifact {
                        name: name.clone(),
                        reason: "missing file field".into(),
                    }
                })?;
            let path = dir.join(file);
            if !path.is_file() {
                return Err(Error::Artifact {
                    name: name.clone(),
                    reason: format!("{} does not exist", path.display()),
                });
            }
            let inputs = entry
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::Artifact {
                    name: name.clone(),
                    reason: "missing inputs array".into(),
                })?
                .iter()
                .map(|spec| -> Result<InputSpec> {
                    let shape = spec
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| Error::Artifact {
                            name: name.clone(),
                            reason: "input missing shape".into(),
                        })?
                        .iter()
                        .map(|d| {
                            d.as_usize().ok_or_else(|| Error::Artifact {
                                name: name.clone(),
                                reason: "non-integer dim".into(),
                            })
                        })
                        .collect::<Result<Vec<usize>>>()?;
                    let dtype = spec
                        .get("dtype")
                        .and_then(Json::as_str)
                        .unwrap_or("float32")
                        .to_string();
                    Ok(InputSpec { shape, dtype })
                })
                .collect::<Result<Vec<_>>>()?;
            entries.push(ArtifactEntry {
                name: name.clone(),
                path,
                inputs,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            entries,
        })
    }

    /// Load from the auto-discovered artifacts directory.
    pub fn discover() -> Result<Manifest> {
        Manifest::load(&find_artifacts_dir()?)
    }

    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| Error::Artifact {
                name: name.to_string(),
                reason: format!(
                    "not in manifest (have: {})",
                    self.entries
                        .iter()
                        .map(|e| e.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                ),
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-art-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn write_manifest(dir: &Path, body: &str) {
        fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn loads_valid_manifest() {
        let d = tmp("valid");
        fs::write(d.join("m.hlo.txt"), "HloModule m\n").unwrap();
        write_manifest(
            &d,
            r#"{"format":"hlo-text","entries":{
                "m":{"file":"m.hlo.txt",
                     "inputs":[{"shape":[128,128],"dtype":"float32"}]}}}"#,
        );
        let m = Manifest::load(&d).unwrap();
        let e = m.entry("m").unwrap();
        assert_eq!(e.inputs[0].shape, vec![128, 128]);
        assert_eq!(e.inputs[0].element_count(), 16384);
    }

    #[test]
    fn missing_hlo_file_rejected() {
        let d = tmp("nohlo");
        write_manifest(
            &d,
            r#"{"format":"hlo-text","entries":{
                "m":{"file":"gone.hlo.txt","inputs":[]}}}"#,
        );
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn wrong_format_rejected() {
        let d = tmp("badfmt");
        write_manifest(&d, r#"{"format":"proto","entries":{}}"#);
        let err = Manifest::load(&d).unwrap_err().to_string();
        assert!(err.contains("hlo-text"), "{err}");
    }

    #[test]
    fn unknown_entry_lists_alternatives() {
        let d = tmp("unknown");
        fs::write(d.join("a.hlo.txt"), "HloModule a\n").unwrap();
        write_manifest(
            &d,
            r#"{"format":"hlo-text","entries":{
                "a":{"file":"a.hlo.txt","inputs":[]}}}"#,
        );
        let m = Manifest::load(&d).unwrap();
        let err = m.entry("nope").unwrap_err().to_string();
        assert!(err.contains("have: a"), "{err}");
    }

    #[test]
    fn real_repo_manifest_loads() {
        // The actual artifacts built by `make artifacts`, when present.
        if let Ok(dir) = find_artifacts_dir() {
            let m = Manifest::load(&dir).unwrap();
            for name in ["image_convert", "matmul_pair", "matmul_chain"] {
                assert!(m.entry(name).is_ok(), "{name} missing");
            }
        }
    }
}
