//! Stub PJRT client: compiled when the `xla-runtime` feature is off.
//!
//! Keeps the crate buildable with zero external dependencies (DESIGN.md
//! §3): every call reports the runtime as unavailable, so artifact-backed
//! apps fail at `MapApp::startup()` with a clear message while the
//! launcher, planner, engines, simulator and text/bench workloads — the
//! parts under study — run fully.

use crate::error::{Error, Result};

/// Stand-in for `xla::PjRtClient`; never successfully constructed.
#[derive(Debug, Clone)]
pub struct StubClient;

impl StubClient {
    pub fn platform_name(&self) -> &'static str {
        "stub"
    }

    pub fn device_count(&self) -> usize {
        0
    }
}

fn unavailable() -> Error {
    Error::Runtime(
        "PJRT unavailable: this binary was built without the \
         `xla-runtime` cargo feature (rebuild with \
         `--features xla-runtime` where the xla crate and \
         xla_extension library are installed)"
            .into(),
    )
}

/// Get this thread's PJRT CPU client — always unavailable in the stub.
pub fn thread_client() -> Result<StubClient> {
    Err(unavailable())
}

/// Back-compat alias used by `main.rs` inspect.
pub fn global_client() -> Result<StubClient> {
    thread_client()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = thread_client().unwrap_err().to_string();
        assert!(err.contains("xla-runtime"), "{err}");
    }
}
