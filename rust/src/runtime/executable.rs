//! HLO artifact loading, compilation and execution.
//!
//! The interchange format is HLO *text* (never serialized protos — jax's
//! 64-bit instruction ids crash xla_extension 0.5.1's proto path; the text
//! parser reassigns ids).  See `python/compile/aot.py` and
//! /opt/xla-example/README.md.
//!
//! [`XlaExecutable::load`] is deliberately the *expensive* call: it parses
//! and XLA-compiles the module.  The map applications call it from
//! `MapApp::startup()`, so SISO mode pays compilation per input file and
//! MIMO pays it once per array task — the mechanism under test in the
//! paper (DESIGN.md §3, substitution table).

use std::path::Path;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::runtime::artifacts::{ArtifactEntry, InputSpec};
use crate::runtime::client::thread_client;

/// A compiled, executable artifact.
pub struct XlaExecutable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
    inputs: Vec<InputSpec>,
    /// How long parse+compile took (the "application start-up" cost).
    compile_time: Duration,
}

impl XlaExecutable {
    /// Parse the HLO text at `path` and compile it on the global client.
    pub fn load(name: &str, path: &Path, inputs: &[InputSpec]) -> Result<Self> {
        let t0 = std::time::Instant::now();
        let client = thread_client()?;
        let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
            Error::Runtime(format!("parse {}: {e}", path.display()))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| {
            Error::Runtime(format!("compile {name}: {e}"))
        })?;
        Ok(XlaExecutable {
            exe,
            name: name.to_string(),
            inputs: inputs.to_vec(),
            compile_time: t0.elapsed(),
        })
    }

    /// Load straight from a manifest entry.
    pub fn from_entry(entry: &ArtifactEntry) -> Result<Self> {
        Self::load(&entry.name, &entry.path, &entry.inputs)
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn compile_time(&self) -> Duration {
        self.compile_time
    }

    pub fn input_specs(&self) -> &[InputSpec] {
        &self.inputs
    }

    /// Execute on f32 buffers, one per declared input, shapes validated
    /// against the manifest.  Returns the flattened f32 elements of the
    /// single tuple output (`return_tuple=True` in aot.py).
    pub fn run_f32(&self, args: &[&[f32]]) -> Result<Vec<f32>> {
        if args.len() != self.inputs.len() {
            return Err(Error::Runtime(format!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.inputs.len(),
                args.len()
            )));
        }
        let mut literals = Vec::with_capacity(args.len());
        for (i, (arg, spec)) in args.iter().zip(&self.inputs).enumerate() {
            if arg.len() != spec.element_count() {
                return Err(Error::Runtime(format!(
                    "{}: input {i} has {} elements, shape {:?} needs {}",
                    self.name,
                    arg.len(),
                    spec.shape,
                    spec.element_count()
                )));
            }
            // One host->literal copy straight into the target shape
            // (vec1 + reshape would copy twice — §Perf iteration 3).
            let bytes = unsafe {
                std::slice::from_raw_parts(
                    arg.as_ptr() as *const u8,
                    std::mem::size_of_val(*arg),
                )
            };
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &spec.shape,
                bytes,
            )
            .map_err(|e| {
                Error::Runtime(format!("literal for input {i}: {e}"))
            })?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("execute {}: {e}", self.name)))?;
        let buffer = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::Runtime("empty result".into()))?;
        let literal = buffer
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("to_literal: {e}")))?;
        let out = literal
            .to_tuple1()
            .map_err(|e| Error::Runtime(format!("to_tuple1: {e}")))?;
        out.to_vec::<f32>()
            .map_err(|e| Error::Runtime(format!("to_vec: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::Manifest;

    fn manifest() -> Option<Manifest> {
        Manifest::discover().ok()
    }

    #[test]
    fn matmul_pair_roundtrip() {
        // The CORE integration point: python-AOT HLO text executes in rust
        // with correct numerics.
        let Some(m) = manifest() else { return };
        let entry = m.entry("matmul_pair").unwrap();
        let exe = XlaExecutable::from_entry(entry).unwrap();
        let n = entry.inputs[0].shape[0];
        // a = I, b = arbitrary -> out == b.
        let mut a = vec![0f32; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let b: Vec<f32> = (0..n * n).map(|i| (i % 97) as f32 * 0.5).collect();
        let out = exe.run_f32(&[&a, &b]).unwrap();
        assert_eq!(out.len(), n * n);
        for (x, y) in out.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
        assert!(exe.compile_time() > Duration::ZERO);
    }

    #[test]
    fn image_convert_white_is_white() {
        let Some(m) = manifest() else { return };
        let entry = m.entry("image_convert").unwrap();
        let exe = XlaExecutable::from_entry(entry).unwrap();
        let hw3 = entry.inputs[0].element_count();
        let img = vec![1f32; hw3];
        let out = exe.run_f32(&[&img]).unwrap();
        assert_eq!(out.len(), hw3 / 3);
        for v in &out {
            assert!((v - 1.0).abs() < 1e-5, "{v}");
        }
    }

    #[test]
    fn wrong_arity_rejected() {
        let Some(m) = manifest() else { return };
        let exe =
            XlaExecutable::from_entry(m.entry("matmul_pair").unwrap()).unwrap();
        let err = exe.run_f32(&[&[0.0]]).unwrap_err().to_string();
        assert!(err.contains("expected 2 inputs"), "{err}");
    }

    #[test]
    fn wrong_shape_rejected() {
        let Some(m) = manifest() else { return };
        let exe =
            XlaExecutable::from_entry(m.entry("matmul_pair").unwrap()).unwrap();
        let a = vec![0f32; 3];
        let b = vec![0f32; 3];
        let err = exe.run_f32(&[&a, &b]).unwrap_err().to_string();
        assert!(err.contains("elements"), "{err}");
    }
}
