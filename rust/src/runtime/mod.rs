//! The XLA/PJRT runtime layer.
//!
//! Loads the HLO-text artifacts that `python/compile/aot.py` produced at
//! build time, compiles them on the PJRT CPU client, and executes them
//! from the coordinator's hot path.  Python never runs here.
//!
//! ```text
//! artifacts/*.hlo.txt  --parse-->  HloModuleProto  --compile-->  PJRT exe
//!        ^                                                          |
//!   make artifacts (python, once)                        MapApp::process
//! ```

pub mod artifacts;
pub mod client;
pub mod executable;

pub use artifacts::{find_artifacts_dir, ArtifactEntry, InputSpec, Manifest};
pub use client::{global_client, thread_client};
pub use executable::XlaExecutable;
