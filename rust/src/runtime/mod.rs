//! The XLA/PJRT runtime layer.
//!
//! Loads the HLO-text artifacts that `python/compile/aot.py` produced at
//! build time, compiles them on the PJRT CPU client, and executes them
//! from the coordinator's hot path.  Python never runs here.
//!
//! ```text
//! artifacts/*.hlo.txt  --parse-->  HloModuleProto  --compile-->  PJRT exe
//!        ^                                                          |
//!   make artifacts (python, once)                        MapApp::process
//! ```

pub mod artifacts;

// The PJRT client and executable need the external `xla` crate (and the
// xla_extension native library).  They are gated behind the
// `xla-runtime` cargo feature so the default build compiles offline with
// a bare toolchain; without the feature, drop-in stubs report the
// runtime as unavailable and every artifact-backed app fails cleanly at
// `startup()` (callers already skip when artifacts are absent).
#[cfg(feature = "xla-runtime")]
pub mod client;
#[cfg(not(feature = "xla-runtime"))]
#[path = "client_stub.rs"]
pub mod client;
#[cfg(feature = "xla-runtime")]
pub mod executable;
#[cfg(not(feature = "xla-runtime"))]
#[path = "executable_stub.rs"]
pub mod executable;

pub use artifacts::{find_artifacts_dir, ArtifactEntry, InputSpec, Manifest};
pub use client::{global_client, thread_client};
pub use executable::XlaExecutable;
