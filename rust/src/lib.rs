//! # llmapreduce — LLMapReduce on a Rust + JAX + Pallas stack
//!
//! Reproduction of *LLMapReduce: Multi-Level Map-Reduce for High
//! Performance Data Analysis* (Byun, Kepner et al., IEEE HPEC 2016) as a
//! three-layer system:
//!
//! * **L3 (this crate)** — the LLMapReduce launcher: option surface
//!   ([`options`]), input scanning and `.MAPRED.PID` script generation
//!   ([`workdir`]), planning and distribution ([`mapreduce`]), scheduler
//!   dialects plus a discrete-event cluster simulator and a threaded local
//!   engine ([`scheduler`]), applications ([`apps`]), workload generators
//!   ([`workload`]), metrics ([`metrics`]) and live telemetry
//!   ([`telemetry`]).
//! * **L2 (python/compile/model.py, build time)** — JAX compute graphs for
//!   the paper's map applications, AOT-lowered to HLO text artifacts.
//! * **L1 (python/compile/kernels/, build time)** — Pallas kernels (tiled
//!   matmul, grayscale) the L2 graphs call.
//!
//! The [`runtime`] module loads the AOT artifacts via the PJRT C API;
//! python never runs at request time.
//!
//! ## Quick start
//!
//! ```no_run
//! use llmapreduce::prelude::*;
//!
//! // The Fig 7 one-liner: map imageConvert over a directory of images.
//! let manifest = Manifest::discover().unwrap();
//! let opts = Options::new("input", "output", "imageconvert").np(2);
//! let apps = Apps {
//!     mapper: ImageConvertApp::new(&manifest).unwrap(),
//!     reducer: None,
//! };
//! // Handle API: submit returns before anything executes; wait()
//! // assembles the report.  Submit N invocations before waiting and
//! // they share the engine's slot cap concurrently.
//! let engine = LocalEngine::new(2);
//! let session = Session::new(&engine);
//! let invocation = session.submit(&opts, &apps).unwrap();
//! let report = invocation.wait().unwrap();
//! println!("processed {} files", report.map.total_items());
//!
//! // One-shot blocking form (submit-and-wait wrapper over the same):
//! let report = llmapreduce::mapreduce::run(&opts, &apps, &engine).unwrap();
//! # let _ = report;
//! ```

pub mod apps;
pub mod bench;
pub mod config;
pub mod error;
pub mod mapreduce;
pub mod metrics;
pub mod options;
pub mod runtime;
pub mod scheduler;
pub mod telemetry;
pub mod util;
pub mod workdir;
pub mod workload;

pub use error::{Error, Result};

/// The commonly-used surface in one import.
pub mod prelude {
    pub use crate::apps::image::ImageConvertApp;
    pub use crate::apps::matmul::{FrobeniusSumReducer, MatmulChainApp};
    pub use crate::apps::wordcount::{WordCountApp, WordCountReducer};
    pub use crate::apps::{MapApp, MapInstance, ReduceApp};
    pub use crate::error::{Error, Result};
    pub use crate::mapreduce::{
        dlq_reprocess, resume, run, run_nested, Apps, Invocation,
        InvocationStatus, MapReduceReport, MultiLevelReport, Session,
    };
    pub use crate::scheduler::journal::{ErrorPolicy, OnError};
    pub use crate::options::{AppType, Distribution, Options, SchedulerKind};
    pub use crate::runtime::Manifest;
    pub use crate::scheduler::failure::FailurePolicy;
    pub use crate::scheduler::local::LocalEngine;
    pub use crate::scheduler::remote::{
        run_worker, CoordinatorConfig, RemoteCoordinator, WorkerConfig,
    };
    pub use crate::scheduler::sim::{ClusterConfig, SimEngine};
    pub use crate::scheduler::{Engine, JobReport};
    pub use crate::telemetry::{Collector, Event, EventBus, MetricsListener, Registry};
}
