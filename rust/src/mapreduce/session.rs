//! Handle-based, non-blocking invocation API.
//!
//! [`crate::mapreduce::pipeline::run`] is one blocking call: it plans,
//! submits *and waits*.  That shape throws away the background
//! dispatcher underneath it — the engine can interleave any number of
//! jobs under its slot cap, but a blocking caller only ever gives it one
//! invocation at a time.  This module splits the lifecycle into handles:
//!
//! * [`Session::new`] wraps a shared [`Engine`] (`&dyn Engine` — the
//!   engine trait is `&self`-based, so one engine serves many sessions
//!   and threads);
//! * [`Session::submit`] plans the invocation, writes the `.MAPRED.PID`
//!   artifacts, submits the whole job chain (map → optional partials →
//!   reduce) and **returns before any task executes**;
//! * [`Invocation::wait`] blocks for completion and assembles the
//!   [`MapReduceReport`]; [`Invocation::status`] polls without blocking;
//! * [`Session::wait_all`] blocks until everything submitted through
//!   the session has finished.
//!
//! Submitting N invocations before waiting on any of them is the whole
//! point: their map/partial/reduce jobs share the engine's slot cap
//! *concurrently*, which is what the multi-level path
//! ([`crate::mapreduce::multilevel`]) uses to run every subdirectory
//! pipeline of a hierarchy at once instead of serially.
//!
//! # Scratch-space rules for concurrent invocations
//!
//! Each invocation owns two pieces of scratch: the `.MAPRED.<pid>`
//! artifact directory (in the workdir) and, in overlapped mode, a
//! `.partials.<pid>` staging directory (in the output dir).  Both are
//! pid-suffixed, so invocations with distinct pids can share a workdir
//! and even an output directory without clobbering each other.  When
//! `Options::pid` is unset a pid is derived from a **process-wide**
//! counter (sessions are created freely — one per [`run`] call — so
//! per-session uniqueness would not protect concurrent callers): the
//! first unpinned submit in the process uses the real process id (the
//! paper's naming), and every further unpinned submit strides to a
//! distinct derived pid.  Two invocations explicitly pinned to the
//! *same* pid must not run concurrently in the same workdir — pin
//! distinct pids instead (the multi-level driver does exactly that).
//!
//! [`run`]: crate::mapreduce::pipeline::run
//!
//! Dropping an [`Invocation`] without waiting is safe: `Drop` blocks
//! until the submitted chain settles, then removes the scratch
//! directories (unless `--keep`), so nothing leaks.  On *success* the
//! chain settles only after its last task finished, so no task is
//! still using the scratch.  On *failure* the engine settles the chain
//! as soon as the failure cascades, so straggler tasks of the failed
//! chain may still be draining while scratch is removed — harmless by
//! construction (nothing executes out of `.MAPRED.<pid>`, and a
//! straggler's write into a removed `.partials.<pid>` just turns into
//! one more error on an already-failed invocation), and identical to
//! the blocking path's failure behaviour.

use std::fs;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::error::Result;
use crate::mapreduce::pipeline::{Apps, MapReduceReport};
use crate::mapreduce::planner::{plan, Plan};
use crate::mapreduce::subdir::replicate_output_tree;
use crate::options::Options;
use crate::scheduler::dialect::dialect_for;
use crate::scheduler::journal::{Journal, Record, JOURNAL_FILE};
use crate::scheduler::{Engine, JobId, JobReport, JobSpec, TaskSpec, TaskWork};
use crate::telemetry::{EventBus, InvocationTelemetry, STATUS_FILE};
use crate::workdir::scan::scan_input;
use crate::workdir::scripts::{reduce_run_script, write_all};
use crate::workdir::MapRedDir;

/// Reports of one waited-out chain: (map, partials, reduce).
type WaitedChain = (JobReport, Option<JobReport>, Option<JobReport>);

/// Process-wide counter behind auto-derived pids.  Sessions are cheap
/// and created freely (every [`crate::mapreduce::pipeline::run`] call
/// makes one), so uniqueness must span the process, not one session:
/// two threads running unpinned invocations concurrently would
/// otherwise both claim `.MAPRED.<process id>`.
static AUTO_PID_SEQ: AtomicU32 = AtomicU32::new(0);

/// Process-unique pid derivation shared by sessions and the multilevel
/// driver: an explicit pid wins; otherwise the process's first unpinned
/// caller keeps the paper's process-id naming and later ones stride to
/// distinct values (an odd stride is a bijection over `u32`).
pub(crate) fn auto_pid(explicit: Option<u32>) -> u32 {
    if let Some(pid) = explicit {
        return pid;
    }
    let seq = AUTO_PID_SEQ.fetch_add(1, Ordering::Relaxed);
    std::process::id().wrapping_add(seq.wrapping_mul(0x9E37_79B9))
}

/// A submission context over a shared engine.  Cheap to create; many
/// sessions may wrap the same engine, and one session may be shared by
/// reference across threads (all methods take `&self`).
pub struct Session<'e> {
    engine: &'e dyn Engine,
    /// Final job of every invocation submitted through this session
    /// (what [`Session::wait_all`] blocks on).
    submitted: Mutex<Vec<JobId>>,
}

impl<'e> Session<'e> {
    /// Wrap a shared engine.
    pub fn new(engine: &'e dyn Engine) -> Self {
        Session {
            engine,
            submitted: Mutex::new(Vec::new()),
        }
    }

    /// The engine this session submits to.
    pub fn engine(&self) -> &'e dyn Engine {
        self.engine
    }

    /// Effective pid for one submit: [`auto_pid`] over `Options::pid`
    /// (see the module docs on scratch-space rules).
    fn derive_pid(&self, opts: &Options) -> u32 {
        auto_pid(opts.pid)
    }

    /// Plan one LLMapReduce invocation, write its `.MAPRED.<pid>`
    /// artifacts, submit the whole job chain, and return a handle
    /// **before any task executes** (steps 1–3 of Fig 1; steps 4–5
    /// happen on the engine in the background).
    ///
    /// The overlapped path (`--overlap=true`) and its fallbacks are
    /// identical to the classic call — see
    /// [`crate::mapreduce::pipeline`] for the semantics; only the
    /// waiting moved out of this function.
    pub fn submit(&self, opts: &Options, apps: &Apps) -> Result<Invocation<'e>> {
        let engine = self.engine;
        opts.validate()?;
        let dialect = dialect_for(opts.scheduler);

        // Step 1: identify input files.
        let files = scan_input(&opts.input, opts.subdir)?;

        // Plan tasks and output naming.
        let the_plan = plan(&files, opts, dialect.as_ref())?;

        // Generate the .MAPRED.PID artifacts (Figs 8/9/12), output dirs.
        let base = opts.workdir.clone().unwrap_or_else(|| {
            std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."))
        });
        let pid = self.derive_pid(opts);
        let wd = MapRedDir::create(&base, pid, opts.keep)?;
        write_all(&wd, &the_plan, opts, dialect.as_ref())?;
        replicate_output_tree(&the_plan)?;

        // Crash journal: every table transition of this chain is
        // appended (fsync'd) under the workdir so `llmapreduce resume`
        // can reconstruct in-flight state after a coordinator death.
        // The header record carries everything resume needs to rebuild
        // the invocation: apps by wire spec, the full option set, and
        // the planned task count (a re-plan sanity check).
        let journal = if opts.journal {
            let j = Arc::new(Journal::create(
                wd.path().join(JOURNAL_FILE),
            )?);
            j.record(&Record::Invocation {
                pid,
                mapper: apps.mapper.wire_spec(),
                reducer: apps.reducer.as_ref().map(|r| r.wire_spec()),
                ntasks: the_plan.tasks.len(),
                options: opts.to_json(),
            });
            Some(j)
        } else {
            None
        };

        // Live telemetry: the chain publishes its transitions to the
        // engine's bus (or a standalone one on engines without a bus),
        // and a collector + status-writer pair mirrors them into
        // `status.json` next to the journal so `llmapreduce status` /
        // `top` can watch the run (DESIGN.md §9).
        let telemetry = if opts.telemetry {
            let bus = engine
                .event_bus()
                .unwrap_or_else(|| Arc::new(EventBus::new()));
            Some(InvocationTelemetry::attach(
                bus,
                wd.path().join(STATUS_FILE),
            ))
        } else {
            None
        };

        // Step 2: the mapper array job.  The plan's apptype, not the raw
        // option, is the execution mode: under `--spmd` the planner
        // packed batches and switched the plan to `AppType::Spmd`, so
        // every engine (and the wire) sees the ganged mode transparently.
        let map_tasks: Vec<TaskSpec> = the_plan
            .tasks
            .iter()
            .map(|t| TaskSpec {
                task_id: t.task_id,
                work: TaskWork::Map {
                    app: apps.mapper.clone(),
                    pairs: t.pairs.clone(),
                    mode: the_plan.apptype,
                },
            })
            .collect();
        let mut map_spec = JobSpec::new(apps.mapper.name(), map_tasks)
            .exclusive(opts.exclusive)
            .error_policy(opts.effective_error_policy())
            .trace(opts.trace);
        if let Some(j) = &journal {
            map_spec = map_spec.journal(j.clone());
        }
        if let Some(t) = &telemetry {
            map_spec = map_spec.telemetry(t.bus().clone());
        }
        let map_id = engine.submit(map_spec)?;

        // Step 3: the dependent reduce — barriered (Fig 1) or
        // overlapped.  --overlap must not change *what* gets reduced, so
        // it falls back to the barrier when it would: under --subdir
        // (the classic reducer contract scans only the top level of the
        // output dir, while partials would consume the nested per-task
        // outputs explicitly) and for reducers that cannot fold partials
        // (external command reducers, whose contract is a directory of
        // real mapper outputs).
        let overlap = opts.overlap
            && !opts.subdir
            && apps
                .reducer
                .as_ref()
                .is_some_and(|r| r.supports_partial());
        let mut partials_dir: Option<PathBuf> = None;
        let (reduce_id, partial_id, redout_path) = if let Some(reducer) =
            &apps.reducer
        {
            let redout = opts.output.join(&opts.redout);
            wd.write(
                "run_reduce",
                &reduce_run_script(
                    reducer.name(),
                    &opts.output,
                    &redout,
                ),
            )?;
            // The (final) reduce job is identical in both modes except
            // for the directory it scans and the job it depends on.
            let reduce_spec = |input_dir: PathBuf| {
                let spec = JobSpec::new(
                    reducer.name(),
                    vec![TaskSpec {
                        task_id: 1,
                        work: TaskWork::Reduce {
                            app: reducer.clone(),
                            input_dir,
                            out_file: redout.clone(),
                        },
                    }],
                )
                .trace(opts.trace);
                let spec = match &journal {
                    Some(j) => spec.journal(j.clone()),
                    None => spec,
                };
                match &telemetry {
                    Some(t) => spec.telemetry(t.bus().clone()),
                    None => spec,
                }
            };
            if overlap {
                // Step 3a: one partial-reduce task per mapper task, each
                // released the moment *its* mapper task completes.  The
                // staging dir is pid-suffixed so concurrent invocations
                // sharing an output directory keep separate scratch;
                // clear it first so stale partials from an earlier run
                // (a failure, or --keep) cannot leak into the merge.
                let pdir = opts.output.join(format!(".partials.{pid}"));
                let _ = fs::remove_dir_all(&pdir);
                fs::create_dir_all(&pdir)
                    .map_err(|e| crate::error::Error::io(pdir.clone(), e))?;
                let partial_tasks: Vec<TaskSpec> = (0..the_plan
                    .tasks
                    .len())
                    .map(|i| TaskSpec {
                        task_id: i + 1,
                        work: TaskWork::ReducePartial {
                            app: reducer.clone(),
                            files: the_plan.task_outputs(i),
                            out_file: pdir
                                .join(format!("part_{:05}", i + 1)),
                        },
                    })
                    .collect();
                let mut partial_spec = JobSpec::new(
                    format!("{}.partial", reducer.name()),
                    partial_tasks,
                )
                .after_tasks(map_id, the_plan.overlap_edges())
                .trace(opts.trace);
                if let Some(j) = &journal {
                    partial_spec = partial_spec.journal(j.clone());
                }
                if let Some(t) = &telemetry {
                    partial_spec = partial_spec.telemetry(t.bus().clone());
                }
                let pid_job = engine.submit(partial_spec)?;
                // Step 3b: the final merge over the partials directory.
                let final_spec = reduce_spec(pdir.clone()).after(pid_job);
                partials_dir = Some(pdir);
                (
                    Some(engine.submit(final_spec)?),
                    Some(pid_job),
                    Some(redout),
                )
            } else {
                let spec = reduce_spec(opts.output.clone()).after(map_id);
                (Some(engine.submit(spec)?), None, Some(redout))
            }
        } else {
            (None, None, None)
        };

        let final_id = reduce_id.unwrap_or(map_id);
        self.submitted
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(final_id);
        Ok(Invocation {
            engine,
            map_id,
            partial_id,
            reduce_id,
            final_id,
            plan: Some(the_plan),
            redout_path,
            partials_dir,
            telemetry,
            workdir: Some(wd),
            keep: opts.keep,
            overlapped: overlap,
            virtual_time: engine.virtual_time(),
            finished: false,
        })
    }

    /// Block until every invocation submitted through this session has
    /// settled (including ones whose handles were already waited or
    /// dropped).  Returns the first failure, after still waiting out the
    /// rest — the engine-side analogue of joining a scatter of handles.
    /// Per-invocation reports still come from [`Invocation::wait`].
    pub fn wait_all(&self) -> Result<()> {
        let ids: Vec<JobId> = self
            .submitted
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone();
        let mut first_err = None;
        for id in ids {
            if let Err(e) = self.engine.wait(id) {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Non-blocking view of an invocation's lifecycle
/// ([`Invocation::status`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InvocationStatus {
    /// Some job of the chain is still queued or running.  Lazily
    /// executed virtual-time engines report `Running` until a `wait`
    /// forces the simulation.
    Running,
    /// The whole chain completed; [`Invocation::wait`] returns promptly.
    Succeeded,
    /// The chain failed; [`Invocation::wait`] returns the error.
    Failed,
}

/// Handle to one submitted LLMapReduce invocation.
///
/// Obtained from [`Session::submit`]; consume it with
/// [`Invocation::wait`] to get the [`MapReduceReport`].  Dropping it
/// without waiting blocks until the submitted jobs settle and then
/// cleans up the invocation's scratch directories — no leaks (see the
/// module docs for the failure-path straggler caveat).
pub struct Invocation<'e> {
    engine: &'e dyn Engine,
    map_id: JobId,
    partial_id: Option<JobId>,
    reduce_id: Option<JobId>,
    /// Last job of the chain (reduce when present, else map).
    final_id: JobId,
    plan: Option<Plan>,
    redout_path: Option<PathBuf>,
    partials_dir: Option<PathBuf>,
    /// Declared before `workdir` so the status writer's final flush
    /// (on drop) lands before `.MAPRED.<pid>` is removed.
    telemetry: Option<InvocationTelemetry>,
    workdir: Option<MapRedDir>,
    keep: bool,
    overlapped: bool,
    virtual_time: bool,
    finished: bool,
}

impl Invocation<'_> {
    /// Non-blocking lifecycle probe (see [`InvocationStatus`]).
    pub fn status(&self) -> InvocationStatus {
        match self.engine.try_wait(self.final_id) {
            Ok(Some(_)) => InvocationStatus::Succeeded,
            Ok(None) => InvocationStatus::Running,
            Err(_) => InvocationStatus::Failed,
        }
    }

    /// The mapper array job's id on the engine.
    pub fn map_job(&self) -> JobId {
        self.map_id
    }

    /// Whether this invocation runs the overlapped map→reduce path.
    pub fn overlapped(&self) -> bool {
        self.overlapped
    }

    /// Block until the whole chain finishes and assemble the report
    /// (steps 4–5 of Fig 1 happened on the engine; this collects them).
    ///
    /// End-to-end elapsed mirrors `pipeline::run`: virtual-time engines
    /// sum their chained job makespans; wall-clock engines report the
    /// span covered by the chain (the makespans overlap, so the longest
    /// one — submission to last completion — *is* the span, independent
    /// of how late `wait` is called).
    pub fn wait(mut self) -> Result<MapReduceReport> {
        self.finished = true;
        let waited = self.wait_jobs();
        // Detach telemetry first: the chain has settled, and the status
        // writer's final snapshot must land before the workdir is
        // removed or persisted below.
        self.telemetry = None;
        // The partials staging dir is scratch like .MAPRED.PID: clear it
        // on the failure path too, not just after a clean run.
        if !self.keep {
            if let Some(pdir) = &self.partials_dir {
                let _ = fs::remove_dir_all(pdir);
            }
        }
        // Scratch survives --keep, a failed chain (the journal inside
        // is what `llmapreduce resume` replays), and any run that
        // dead-lettered tasks (the queue file lives there and
        // `llmapreduce dlq reprocess` consumes it).
        let keep_scratch = self.keep
            || match &waited {
                Ok((m, p, r)) => {
                    m.dead_lettered() > 0
                        || p.as_ref().is_some_and(|j| j.dead_lettered() > 0)
                        || r.as_ref().is_some_and(|j| j.dead_lettered() > 0)
                }
                Err(_) => true,
            };
        let mapred_dir = match self.workdir.take() {
            Some(wd) if keep_scratch => Some(wd.persist()),
            _ => None, // dropped -> deleted, the paper's default
        };
        let (map_report, partial_report, reduce_report) = waited?;

        let chain_makespans = |acc: fn(Duration, Duration) -> Duration| {
            let mut total = map_report.makespan;
            for r in partial_report.iter().chain(reduce_report.iter()) {
                total = acc(total, r.makespan);
            }
            total
        };
        let total_elapsed = if self.virtual_time {
            chain_makespans(|a, b| a + b)
        } else {
            chain_makespans(Duration::max)
        };

        Ok(MapReduceReport {
            map: map_report,
            partials: partial_report,
            reduce: reduce_report,
            plan: self.plan.take().expect("plan is set until wait"),
            redout_path: self.redout_path.clone(),
            mapred_dir,
            overlapped: self.overlapped,
            total_elapsed,
        })
    }

    /// Wait out the chain, reduce-first so a dependency failure
    /// surfaces as the downstream error the caller sees.
    fn wait_jobs(&self) -> Result<WaitedChain> {
        if let Some(rid) = self.reduce_id {
            let reduce_report = self.engine.wait(rid)?;
            let partial_report = match self.partial_id {
                Some(pid) => Some(self.engine.wait(pid)?),
                None => None,
            };
            Ok((
                self.engine.wait(self.map_id)?,
                partial_report,
                Some(reduce_report),
            ))
        } else {
            Ok((self.engine.wait(self.map_id)?, None, None))
        }
    }
}

impl Drop for Invocation<'_> {
    fn drop(&mut self) {
        if self.finished {
            return;
        }
        // Block until the submitted chain settles — on success that
        // means every task finished, so scratch is no longer in use
        // (on failure, see the module docs' straggler caveat).  The
        // engine outlives this handle (it is borrowed), so the jobs make
        // progress and this terminates.
        let _ = self.engine.wait(self.final_id);
        if !self.keep {
            if let Some(pdir) = &self.partials_dir {
                let _ = fs::remove_dir_all(pdir);
            }
        }
        // `self.workdir` drops next: MapRedDir removes .MAPRED.<pid>
        // unless --keep was requested.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::testutil::{ConcatReducer, CountingApp};
    use crate::scheduler::local::LocalEngine;
    use std::sync::Arc;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-session-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn setup(tag: &str, nfiles: usize) -> (PathBuf, PathBuf, PathBuf) {
        let root = tmp(tag);
        let input = root.join("input");
        fs::create_dir_all(&input).unwrap();
        for i in 0..nfiles {
            fs::write(input.join(format!("f{i:02}.txt")), format!("{i}\n"))
                .unwrap();
        }
        let output = root.join("output");
        (root, input, output)
    }

    #[test]
    fn submit_then_wait_matches_blocking_run() {
        let (root, input, output) = setup("basic", 4);
        let opts = Options::new(&input, &output, "counting-app")
            .np(2)
            .reducer("concat-reducer")
            .workdir(&root)
            .pid(91001);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: Some(Arc::new(ConcatReducer)),
        };
        let engine = LocalEngine::new(2);
        let session = Session::new(&engine);
        let inv = session.submit(&opts, &apps).unwrap();
        let report = inv.wait().unwrap();
        assert_eq!(report.map.total_items(), 4);
        let merged =
            fs::read_to_string(report.redout_path.unwrap()).unwrap();
        assert_eq!(merged.matches("#mapped").count(), 4);
        assert!(!root.join(".MAPRED.91001").exists(), "scratch cleaned");
    }

    #[test]
    fn status_reaches_succeeded_and_wait_all_blocks_everything() {
        let (root, input, output) = setup("status", 3);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: None,
        };
        let engine = LocalEngine::new(2);
        let session = Session::new(&engine);
        let opts = Options::new(&input, &output, "counting-app")
            .np(3)
            .workdir(&root)
            .pid(91002);
        let inv = session.submit(&opts, &apps).unwrap();
        session.wait_all().unwrap();
        assert_eq!(inv.status(), InvocationStatus::Succeeded);
        let report = inv.wait().unwrap();
        assert_eq!(report.map.total_items(), 3);
    }

    #[test]
    fn failed_chain_reports_failed_status() {
        let (root, input, output) = setup("fail", 2);
        let mut app = CountingApp::new();
        app.poison = Some("f00".into());
        let apps = Apps {
            mapper: Arc::new(app),
            reducer: None,
        };
        let engine = LocalEngine::new(1);
        let session = Session::new(&engine);
        let opts = Options::new(&input, &output, "counting-app")
            .workdir(&root)
            .pid(91003);
        let inv = session.submit(&opts, &apps).unwrap();
        assert!(session.wait_all().is_err());
        assert_eq!(inv.status(), InvocationStatus::Failed);
        assert!(inv.wait().is_err());
        assert!(!root.join(".MAPRED.91003").exists(), "scratch cleaned");
    }

    #[test]
    fn unpinned_pids_are_process_unique() {
        // Parallel tests share the process-wide counter, so this cannot
        // assume it sees seq 0 — only that every derivation is fresh,
        // across sessions as much as within one.
        let engine = LocalEngine::new(1);
        let a = Session::new(&engine);
        let b = Session::new(&engine);
        let unpinned = Options::new("i", "o", "m");
        let mut seen = std::collections::HashSet::new();
        for _ in 0..8 {
            assert!(seen.insert(a.derive_pid(&unpinned)));
            assert!(seen.insert(b.derive_pid(&unpinned)));
        }
        let pinned = Options::new("i", "o", "m").pid(77);
        assert_eq!(a.derive_pid(&pinned), 77, "explicit pid wins");
    }
}
