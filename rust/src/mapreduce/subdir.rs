//! Output-tree replication for `--subdir=true` (§II-A, Fig 3).
//!
//! "LLMapReduce will scan the input directory recursively and list all
//! the files under the input directory as input data to the map process.
//! In addition, LLMapReduce will duplicate the input data structure to
//! the output directory."

use std::collections::BTreeSet;
use std::fs;
use std::path::PathBuf;

use crate::error::{IoContext, Result};
use crate::mapreduce::planner::Plan;

/// Create every directory the plan's outputs need.  Returns the set of
/// directories created (sorted), which with `--subdir` mirrors the input
/// hierarchy.
pub fn replicate_output_tree(plan: &Plan) -> Result<Vec<PathBuf>> {
    let mut dirs: BTreeSet<PathBuf> = BTreeSet::new();
    for task in &plan.tasks {
        for (_, output) in &task.pairs {
            if let Some(parent) = output.parent() {
                dirs.insert(parent.to_path_buf());
            }
        }
    }
    for d in &dirs {
        fs::create_dir_all(d).at(d)?;
    }
    Ok(dirs.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::planner::plan;
    use crate::options::{Options, SchedulerKind};
    use crate::scheduler::dialect::dialect_for;
    use crate::workdir::scan::InputFile;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-subdir-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn replicates_hierarchy() {
        let out_root = tmp("tree").join("out");
        let files = vec![
            InputFile {
                path: PathBuf::from("/in/a/1.dat"),
                relative: PathBuf::from("a/1.dat"),
            },
            InputFile {
                path: PathBuf::from("/in/a/b/2.dat"),
                relative: PathBuf::from("a/b/2.dat"),
            },
            InputFile {
                path: PathBuf::from("/in/3.dat"),
                relative: PathBuf::from("3.dat"),
            },
        ];
        let opts = Options::new("/in", &out_root, "m").subdir(true);
        let d = dialect_for(SchedulerKind::GridEngine);
        let p = plan(&files, &opts, d.as_ref()).unwrap();
        let dirs = replicate_output_tree(&p).unwrap();
        assert!(out_root.join("a").is_dir());
        assert!(out_root.join("a/b").is_dir());
        assert_eq!(dirs.len(), 3); // out, out/a, out/a/b
    }

    #[test]
    fn flat_plan_creates_only_root() {
        let out_root = tmp("flat").join("out");
        let files = vec![InputFile {
            path: PathBuf::from("/in/deep/x.dat"),
            relative: PathBuf::from("deep/x.dat"),
        }];
        let opts = Options::new("/in", &out_root, "m"); // no --subdir
        let d = dialect_for(SchedulerKind::GridEngine);
        let p = plan(&files, &opts, d.as_ref()).unwrap();
        let dirs = replicate_output_tree(&p).unwrap();
        assert_eq!(dirs, vec![out_root.clone()]);
        assert!(!out_root.join("deep").exists());
    }
}
