//! Crash recovery: `llmapreduce resume` and `llmapreduce dlq
//! reprocess` (DESIGN.md §8).
//!
//! A crashed coordinator leaves its `.MAPRED.<pid>` directory behind
//! (SIGKILL skips [`MapRedDir`]'s drop), and inside it the fsync'd
//! journal of every table transition the run made.  [`resume`] folds
//! that journal back into per-task completion state, re-plans the
//! invocation from the recorded options (planning is deterministic:
//! same input scan + same options → same task ids), and resubmits
//! **only the tasks without a `done` record** under the original task
//! ids — finished work is never repeated, and SPMD batches re-run
//! whole because the batch *is* the task.  The reduce step always
//! re-runs barriered over the full output directory: mapper outputs
//! from before and after the crash are indistinguishable there, which
//! is what makes resumed output byte-identical to an uninterrupted
//! run (overlap is not resumed — partials staged by the crashed run
//! are untrusted scratch).
//!
//! [`dlq_reprocess`] drains the per-job dead-letter queue instead: it
//! re-plans the same way, but resubmits exactly the dead-lettered
//! task ids.  The queue file is consumed at submission — a
//! reprocessed task that fails again is dead-lettered anew by the
//! normal policy path, so entries re-enqueue rather than duplicate.

use std::collections::HashSet;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use crate::apps::registry::{resolve_mapper, resolve_reducer};
use crate::error::{Error, IoContext, Result};
use crate::mapreduce::pipeline::{Apps, MapReduceReport};
use crate::mapreduce::planner::{plan, Plan};
use crate::mapreduce::subdir::replicate_output_tree;
use crate::options::Options;
use crate::scheduler::dialect::dialect_for;
use crate::scheduler::journal::{
    DeadLetter, Journal, Record, Replay, DLQ_FILE, JOURNAL_FILE,
};
use crate::scheduler::{Engine, JobSpec, TaskSpec, TaskWork};
use crate::telemetry::{Event, EventBus, InvocationTelemetry, STATUS_FILE};
use crate::workdir::scan::scan_input;
use crate::workdir::MapRedDir;

/// Everything reconstructed from a crashed run's journal header.
struct Recovered {
    opts: Options,
    apps: Apps,
    replay: Replay,
    journal_path: PathBuf,
}

/// Load the journal under `workdir` and rebuild options + apps from
/// its invocation header.
fn recover(workdir: &Path) -> Result<Recovered> {
    let journal_path = workdir.join(JOURNAL_FILE);
    let replay = Replay::load(&journal_path)?;
    let inv = replay.invocation.clone().ok_or_else(|| Error::Format {
        kind: "journal",
        path: journal_path.clone(),
        reason: "journal has no invocation header record".into(),
    })?;
    let mut opts = Options::from_json(&inv.options)?;
    // Pin the crashed run's pid so scratch naming lines up.
    opts.pid = Some(inv.pid);
    let mapper = resolve_mapper(&inv.mapper)?;
    let reducer = match &inv.reducer {
        Some(spec) => Some(resolve_reducer(spec)?),
        None => None,
    };
    Ok(Recovered {
        opts,
        apps: Apps { mapper, reducer },
        replay,
        journal_path,
    })
}

/// Re-plan the recovered invocation.  Planning is deterministic, so
/// this reproduces the crashed run's task ids; the recorded task
/// count is the sanity check that the input set did not change
/// underneath the journal.
fn replan(opts: &Options) -> Result<Plan> {
    let dialect = dialect_for(opts.scheduler);
    let files = scan_input(&opts.input, opts.subdir)?;
    plan(&files, opts, dialect.as_ref())
}

/// Check the re-plan against the journaled task count.
fn check_ntasks(
    the_plan: &Plan,
    recorded: usize,
    journal_path: &Path,
) -> Result<()> {
    if the_plan.tasks.len() != recorded {
        return Err(Error::Format {
            kind: "journal",
            path: journal_path.to_path_buf(),
            reason: format!(
                "input changed since the crashed run: re-plan produced \
                 {} tasks but the journal recorded {recorded}",
                the_plan.tasks.len()
            ),
        });
    }
    Ok(())
}

/// Submit the selected mapper tasks plus the barriered reduce, wait
/// the chain out reduce-first, and assemble the report.  Shared by
/// [`resume`] and [`dlq_reprocess`] — both are "re-run this subset of
/// the planned tasks, then re-reduce everything".
fn run_subset(
    engine: &dyn Engine,
    opts: &Options,
    apps: &Apps,
    the_plan: Plan,
    select: &HashSet<usize>,
    journal: Option<Arc<Journal>>,
    telemetry: Option<&InvocationTelemetry>,
    replayed: usize,
) -> Result<MapReduceReport> {
    replicate_output_tree(&the_plan)?;
    let map_tasks: Vec<TaskSpec> = the_plan
        .tasks
        .iter()
        .filter(|t| select.contains(&t.task_id))
        .map(|t| TaskSpec {
            task_id: t.task_id,
            work: TaskWork::Map {
                app: apps.mapper.clone(),
                pairs: t.pairs.clone(),
                mode: the_plan.apptype,
            },
        })
        .collect();
    let mut map_spec = JobSpec::new(apps.mapper.name(), map_tasks)
        .exclusive(opts.exclusive)
        .error_policy(opts.effective_error_policy());
    if let Some(j) = &journal {
        map_spec = map_spec.journal(j.clone());
    }
    if let Some(t) = telemetry {
        map_spec = map_spec.telemetry(t.bus().clone());
    }
    let map_id = engine.submit(map_spec)?;

    let (reduce_id, redout_path) = match &apps.reducer {
        Some(reducer) => {
            let redout = opts.output.join(&opts.redout);
            let mut spec = JobSpec::new(
                reducer.name(),
                vec![TaskSpec {
                    task_id: 1,
                    work: TaskWork::Reduce {
                        app: reducer.clone(),
                        input_dir: opts.output.clone(),
                        out_file: redout.clone(),
                    },
                }],
            )
            .after(map_id);
            if let Some(j) = &journal {
                spec = spec.journal(j.clone());
            }
            if let Some(t) = telemetry {
                spec = spec.telemetry(t.bus().clone());
            }
            (Some(engine.submit(spec)?), Some(redout))
        }
        None => (None, None),
    };

    // Reduce-first, like `Invocation::wait_jobs`: a dependency failure
    // surfaces as the downstream error the caller sees.
    let reduce_report = match reduce_id {
        Some(rid) => Some(engine.wait(rid)?),
        None => None,
    };
    let mut map_report = engine.wait(map_id)?;
    map_report.replayed = replayed;

    let reduce_makespan = reduce_report
        .as_ref()
        .map(|r| r.makespan)
        .unwrap_or(Duration::ZERO);
    let total_elapsed = if engine.virtual_time() {
        map_report.makespan + reduce_makespan
    } else {
        map_report.makespan.max(reduce_makespan)
    };

    Ok(MapReduceReport {
        map: map_report,
        partials: None,
        reduce: reduce_report,
        plan: the_plan,
        redout_path,
        mapred_dir: None,
        overlapped: false,
        total_elapsed,
    })
}

/// On a clean finish the crashed run's scratch is no longer needed:
/// adopt and drop `.MAPRED.<pid>` (unless `--keep`), exactly like the
/// normal path's end-of-invocation cleanup.  Failure paths never get
/// here, so the journal stays on disk for another `resume`.
fn finish_workdir(workdir: &Path, keep: bool) -> Option<PathBuf> {
    if keep {
        return Some(workdir.to_path_buf());
    }
    if let Ok(wd) = MapRedDir::adopt(workdir, false) {
        drop(wd);
    }
    None
}

/// Resume a crashed invocation from its `.MAPRED.<pid>` directory.
///
/// Re-runs only mapper tasks without a journaled `done` record (under
/// their original task ids), then re-reduces the full output
/// directory; the merged output is byte-identical to an uninterrupted
/// run.  Returns the report with [`crate::scheduler::JobReport::replayed`]
/// set to the number of tasks skipped as already complete.
pub fn resume(
    workdir: &Path,
    engine: &dyn Engine,
) -> Result<MapReduceReport> {
    let Recovered {
        opts,
        apps,
        replay,
        journal_path,
    } = recover(workdir)?;
    let recorded = replay.invocation.as_ref().map_or(0, |i| i.ntasks);
    let the_plan = replan(&opts)?;
    check_ntasks(&the_plan, recorded, &journal_path)?;

    let done = replay.done_task_ids(apps.mapper.name());
    let pending: HashSet<usize> = the_plan
        .tasks
        .iter()
        .map(|t| t.task_id)
        .filter(|id| !done.contains(id))
        .collect();

    // Continue the same journal (append — the history before the crash
    // is what makes resume-of-resume work).
    let journal = if opts.journal {
        let j = Arc::new(Journal::open_append(&journal_path)?);
        j.record(&Record::Resumed {
            done: done.len(),
            total: the_plan.tasks.len(),
        });
        Some(j)
    } else {
        None
    };
    // Telemetry rides the resumed chain too: the same status.json in the
    // same workdir, now opening with a `resumed` marker.
    let telemetry = if opts.telemetry {
        let bus = engine
            .event_bus()
            .unwrap_or_else(|| Arc::new(EventBus::new()));
        let t =
            InvocationTelemetry::attach(bus, workdir.join(STATUS_FILE));
        t.bus().emit(Event::Resumed {
            done: done.len(),
            total: the_plan.tasks.len(),
        });
        Some(t)
    } else {
        None
    };

    let mut report = run_subset(
        engine,
        &opts,
        &apps,
        the_plan,
        &pending,
        journal,
        telemetry.as_ref(),
        done.len(),
    )?;
    // Final status flush must land before the workdir is cleaned up.
    drop(telemetry);
    report.mapred_dir = finish_workdir(workdir, opts.keep);
    Ok(report)
}

/// Re-drive the dead-letter queue of a crashed-or-finished run: every
/// dead-lettered task is resubmitted through the normal planner path,
/// then the reduce re-runs over the full output directory.  The queue
/// file is consumed up front; tasks that fail again re-enqueue via
/// the normal policy path.
pub fn dlq_reprocess(
    workdir: &Path,
    engine: &dyn Engine,
) -> Result<MapReduceReport> {
    let Recovered {
        opts,
        apps,
        replay,
        journal_path,
    } = recover(workdir)?;
    let dlq_path = workdir.join(DLQ_FILE);
    let text = fs::read_to_string(&dlq_path).at(&dlq_path)?;
    let mut entries: Vec<DeadLetter> = Vec::new();
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        entries.push(DeadLetter::decode(line, &dlq_path)?);
    }
    if entries.is_empty() {
        return Err(Error::opt(format!(
            "dead-letter queue is empty: {}",
            dlq_path.display()
        )));
    }

    let recorded = replay.invocation.as_ref().map_or(0, |i| i.ntasks);
    let the_plan = replan(&opts)?;
    check_ntasks(&the_plan, recorded, &journal_path)?;
    let select: HashSet<usize> =
        entries.iter().map(|e| e.task_id).collect();

    // Consume the queue: reprocessing owns these entries now; a task
    // that fails again is re-enqueued by the policy path, not left as
    // a stale duplicate.
    fs::remove_file(&dlq_path).at(&dlq_path)?;

    let journal = if opts.journal {
        let j = Arc::new(Journal::open_append(&journal_path)?);
        j.record(&Record::Resumed {
            done: the_plan.tasks.len() - select.len(),
            total: the_plan.tasks.len(),
        });
        Some(j)
    } else {
        None
    };
    let telemetry = if opts.telemetry {
        let bus = engine
            .event_bus()
            .unwrap_or_else(|| Arc::new(EventBus::new()));
        let t =
            InvocationTelemetry::attach(bus, workdir.join(STATUS_FILE));
        t.bus().emit(Event::Resumed {
            done: the_plan.tasks.len() - select.len(),
            total: the_plan.tasks.len(),
        });
        Some(t)
    } else {
        None
    };

    run_subset(
        engine,
        &opts,
        &apps,
        the_plan,
        &select,
        journal,
        telemetry.as_ref(),
        0,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::local::LocalEngine;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-resume-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn seed_inputs(dir: &Path, n: usize) {
        for i in 0..n {
            fs::write(
                dir.join(format!("f{i:02}.txt")),
                format!("alpha beta x{i}\n"),
            )
            .unwrap();
        }
    }

    /// Registry-resolvable apps (resume rebuilds apps from the
    /// journaled wire specs, so test apps must round-trip through
    /// `resolve_mapper`/`resolve_reducer`).
    fn wordcount_apps() -> Apps {
        Apps {
            mapper: resolve_mapper("wordcount").unwrap(),
            reducer: Some(resolve_reducer("wordcount-reducer").unwrap()),
        }
    }

    #[test]
    fn resume_without_a_journal_is_a_clean_error() {
        let wd = tmp("nojournal");
        let engine = LocalEngine::new(2);
        assert!(resume(&wd, &engine).is_err());
    }

    #[test]
    fn resume_after_clean_submit_reruns_nothing_and_keeps() {
        let base = tmp("clean");
        let input = base.join("in");
        let output = base.join("out");
        fs::create_dir_all(&input).unwrap();
        seed_inputs(&input, 4);
        let opts = Options::new(&input, &output, "wordcount")
            .np(2)
            .pid(93001)
            .keep(true)
            .workdir(&base);
        let apps = wordcount_apps();
        let engine = LocalEngine::new(2);
        let report =
            crate::mapreduce::pipeline::run(&opts, &apps, &engine)
                .unwrap();
        assert_eq!(report.map.tasks.len(), 2);
        let wd = base.join(".MAPRED.93001");
        assert!(wd.is_dir(), "--keep preserves workdir + journal");

        // Everything is journaled done: resume re-runs zero map tasks
        // but still re-reduces, and reports the replayed count.
        let resumed = resume(&wd, &engine).unwrap();
        assert_eq!(resumed.map.replayed, 2);
        assert_eq!(resumed.map.tasks.len(), 0);
        assert!(resumed.reduce.is_some());
        assert!(
            wd.is_dir(),
            "journal recorded --keep, so resume also keeps"
        );
    }

    #[test]
    fn dlq_reprocess_needs_a_queue() {
        let base = tmp("dlqempty");
        let input = base.join("in");
        let output = base.join("out");
        fs::create_dir_all(&input).unwrap();
        seed_inputs(&input, 2);
        let opts = Options::new(&input, &output, "wordcount")
            .np(2)
            .pid(93002)
            .keep(true)
            .workdir(&base);
        let apps = Apps {
            mapper: resolve_mapper("wordcount").unwrap(),
            reducer: None,
        };
        let engine = LocalEngine::new(2);
        crate::mapreduce::pipeline::run(&opts, &apps, &engine).unwrap();
        let wd = base.join(".MAPRED.93002");
        // No task ever errored: there is no dlq.jsonl to reprocess.
        assert!(dlq_reprocess(&wd, &engine).is_err());
    }
}
