//! The LLMapReduce pipeline: one call = one map-reduce job (Fig 1).
//!
//! Steps, numbered as in the paper's schematic:
//!
//! 1. identify input files (scan directory / read list);
//! 2. create an array job of mapper tasks via the scheduler;
//! 3. submit the reduce task with a job dependency on the mappers;
//! 4. the reducer scans the mapper output directory;
//! 5. the reducer writes the final result.
//!
//! The `.MAPRED.PID` directory with submission and run scripts is
//! generated exactly as on a real cluster, then the job is *executed* on
//! the configured engine (local threads or the discrete-event simulator).

use std::path::PathBuf;
use std::sync::Arc;

use crate::apps::{MapApp, ReduceApp};
use crate::error::Result;
use crate::mapreduce::planner::{plan, Plan};
use crate::mapreduce::subdir::replicate_output_tree;
use crate::options::Options;
use crate::scheduler::dialect::dialect_for;
use crate::scheduler::{Engine, JobSpec, TaskSpec, TaskWork};
use crate::workdir::scan::scan_input;
use crate::workdir::scripts::{reduce_run_script, write_all};
use crate::workdir::MapRedDir;

/// Result of one LLMapReduce invocation.
#[derive(Debug)]
pub struct MapReduceReport {
    /// The mapper array job's report.
    pub map: crate::scheduler::JobReport,
    /// The reducer job's report, when a reducer was given.
    pub reduce: Option<crate::scheduler::JobReport>,
    /// The plan that produced the jobs.
    pub plan: Plan,
    /// Where the reduce output was written (if reducing).
    pub redout_path: Option<PathBuf>,
    /// The kept `.MAPRED.PID` directory (only with `--keep`).
    pub mapred_dir: Option<PathBuf>,
}

impl MapReduceReport {
    /// Total elapsed (virtual or wall) time: map + reduce makespans.
    pub fn elapsed(&self) -> std::time::Duration {
        self.map.makespan
            + self
                .reduce
                .as_ref()
                .map(|r| r.makespan)
                .unwrap_or_default()
    }
}

/// The applications an invocation binds to.  The paper resolves mapper /
/// reducer names to executables on disk; this API accepts the executable
/// objects directly (the CLI layer does the name resolution).
pub struct Apps {
    pub mapper: Arc<dyn MapApp>,
    pub reducer: Option<Arc<dyn ReduceApp>>,
}

/// Run one complete LLMapReduce invocation on `engine`.
pub fn run(
    opts: &Options,
    apps: &Apps,
    engine: &mut dyn Engine,
) -> Result<MapReduceReport> {
    opts.validate()?;
    let dialect = dialect_for(opts.scheduler);

    // Step 1: identify input files.
    let files = scan_input(&opts.input, opts.subdir)?;

    // Plan tasks and output naming.
    let the_plan = plan(&files, opts, dialect.as_ref())?;

    // Generate the .MAPRED.PID artifacts (Figs 8/9/12) and output dirs.
    let base = opts.workdir.clone().unwrap_or_else(|| {
        std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."))
    });
    let wd = MapRedDir::create(&base, opts.effective_pid(), opts.keep)?;
    write_all(&wd, &the_plan, opts, dialect.as_ref())?;
    replicate_output_tree(&the_plan)?;

    // Step 2: the mapper array job.
    let map_tasks: Vec<TaskSpec> = the_plan
        .tasks
        .iter()
        .map(|t| TaskSpec {
            task_id: t.task_id,
            work: TaskWork::Map {
                app: apps.mapper.clone(),
                pairs: t.pairs.clone(),
                mode: opts.apptype,
            },
        })
        .collect();
    let map_spec = JobSpec::new(apps.mapper.name(), map_tasks)
        .exclusive(opts.exclusive);
    let map_id = engine.submit(map_spec)?;

    // Step 3: the dependent reduce task.
    let (reduce_id, redout_path) = if let Some(reducer) = &apps.reducer {
        let redout = opts.output.join(&opts.redout);
        wd.write(
            "run_reduce",
            &reduce_run_script(
                reducer.name(),
                &opts.output,
                &redout,
            ),
        )?;
        let spec = JobSpec::new(
            reducer.name(),
            vec![TaskSpec {
                task_id: 1,
                work: TaskWork::Reduce {
                    app: reducer.clone(),
                    input_dir: opts.output.clone(),
                    out_file: redout.clone(),
                },
            }],
        )
        .after(map_id);
        (Some(engine.submit(spec)?), Some(redout))
    } else {
        (None, None)
    };

    // Wait for completion (reduce waits on map transitively).
    let map_report;
    let reduce_report;
    if let Some(rid) = reduce_id {
        reduce_report = Some(engine.wait(rid)?);
        map_report = engine.wait(map_id)?;
    } else {
        map_report = engine.wait(map_id)?;
        reduce_report = None;
    }

    let mapred_dir = if opts.keep {
        Some(wd.persist())
    } else {
        None // dropped -> deleted, the paper's default
    };

    Ok(MapReduceReport {
        map: map_report,
        reduce: reduce_report,
        plan: the_plan,
        redout_path,
        mapred_dir,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::testutil::{ConcatReducer, CountingApp};
    use crate::options::AppType;
    use crate::scheduler::local::LocalEngine;
    use crate::scheduler::sim::{ClusterConfig, SimEngine};
    use std::fs;
    use std::sync::atomic::Ordering;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-pipe-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn setup(tag: &str, nfiles: usize) -> (PathBuf, PathBuf) {
        let root = tmp(tag);
        let input = root.join("input");
        let output = root.join("output");
        fs::create_dir_all(&input).unwrap();
        for i in 0..nfiles {
            fs::write(input.join(format!("f{i:02}.txt")), format!("{i}\n"))
                .unwrap();
        }
        (input, output)
    }

    #[test]
    fn map_only_local() {
        let (input, output) = setup("maponly", 6);
        let opts = Options::new(&input, &output, "counting-app")
            .np(2)
            .pid(90001);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: None,
        };
        let mut eng = LocalEngine::new(2);
        let report = run(&opts, &apps, &mut eng).unwrap();
        assert_eq!(report.plan.tasks.len(), 2);
        assert_eq!(report.map.total_items(), 6);
        assert!(report.reduce.is_none());
        // All outputs exist with paper naming.
        for i in 0..6 {
            assert!(output.join(format!("f{i:02}.txt.out")).is_file());
        }
    }

    #[test]
    fn map_reduce_end_to_end_fig1() {
        let (input, output) = setup("fig1", 4);
        let opts = Options::new(&input, &output, "counting-app")
            .np(2)
            .reducer("concat-reducer")
            .pid(90002);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: Some(Arc::new(ConcatReducer)),
        };
        let mut eng = LocalEngine::new(2);
        let report = run(&opts, &apps, &mut eng).unwrap();
        let redout = report.redout_path.clone().unwrap();
        assert!(redout.ends_with("llmapreduce.out"));
        let merged = fs::read_to_string(&redout).unwrap();
        assert_eq!(merged.matches("#mapped").count(), 4);
        assert!(report.reduce.is_some());
    }

    #[test]
    fn mimo_reduces_launches() {
        let (input, output) = setup("mimo", 8);
        let app = Arc::new(CountingApp::new());
        let opts = Options::new(&input, &output, "counting-app")
            .np(2)
            .apptype(AppType::Mimo)
            .pid(90003);
        let apps = Apps {
            mapper: app.clone(),
            reducer: None,
        };
        let mut eng = LocalEngine::new(2);
        let report = run(&opts, &apps, &mut eng).unwrap();
        assert_eq!(report.map.total_launches(), 2);
        assert_eq!(app.startups.load(Ordering::SeqCst), 2);
        assert_eq!(report.map.total_items(), 8);
    }

    #[test]
    fn keep_preserves_mapred_dir() {
        let (input, output) = setup("keep", 2);
        let opts = Options::new(&input, &output, "counting-app")
            .keep(true)
            .pid(90004);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: None,
        };
        let mut eng = LocalEngine::new(1);
        let report = run(&opts, &apps, &mut eng).unwrap();
        let wd = report.mapred_dir.clone().unwrap();
        assert!(wd.ends_with(".MAPRED.90004"));
        assert!(wd.join("submit.sh").is_file());
        assert!(wd.join("run_llmap_1").is_file());
        fs::remove_dir_all(wd).unwrap();
    }

    #[test]
    fn default_cleanup_removes_mapred_dir() {
        let (input, output) = setup("clean", 2);
        let opts =
            Options::new(&input, &output, "counting-app").pid(90005);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: None,
        };
        let mut eng = LocalEngine::new(1);
        let report = run(&opts, &apps, &mut eng).unwrap();
        assert!(report.mapred_dir.is_none());
        let cwd = std::env::current_dir().unwrap();
        assert!(!cwd.join(".MAPRED.90005").exists());
    }

    #[test]
    fn sim_engine_executes_same_pipeline() {
        let (input, output) = setup("simexec", 6);
        let opts = Options::new(&input, &output, "counting-app")
            .np(3)
            .reducer("concat-reducer")
            .pid(90006);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: Some(Arc::new(ConcatReducer)),
        };
        let mut eng =
            SimEngine::new(ClusterConfig::with_width(3)).execute_payloads(true);
        let report = run(&opts, &apps, &mut eng).unwrap();
        // Virtual makespan is deterministic and real outputs exist.
        assert!(report.map.makespan > std::time::Duration::ZERO);
        let merged =
            fs::read_to_string(report.redout_path.unwrap()).unwrap();
        assert_eq!(merged.matches("#mapped").count(), 6);
    }

    #[test]
    fn subdir_pipeline_replicates() {
        let root = tmp("subdirpipe");
        let input = root.join("input");
        let output = root.join("output");
        fs::create_dir_all(input.join("a/b")).unwrap();
        fs::write(input.join("a/x.txt"), "x").unwrap();
        fs::write(input.join("a/b/y.txt"), "y").unwrap();
        let opts = Options::new(&input, &output, "counting-app")
            .subdir(true)
            .pid(90007);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: None,
        };
        let mut eng = LocalEngine::new(1);
        run(&opts, &apps, &mut eng).unwrap();
        assert!(output.join("a/x.txt.out").is_file());
        assert!(output.join("a/b/y.txt.out").is_file());
    }
}
