//! The LLMapReduce pipeline: one call = one map-reduce job (Fig 1).
//!
//! Steps, numbered as in the paper's schematic:
//!
//! 1. identify input files (scan directory / read list);
//! 2. create an array job of mapper tasks via the scheduler;
//! 3. submit the reduce task with a job dependency on the mappers;
//! 4. the reducer scans the mapper output directory;
//! 5. the reducer writes the final result.
//!
//! The `.MAPRED.PID` directory with submission and run scripts is
//! generated exactly as on a real cluster, then the job is *executed* on
//! the configured engine (local threads or the discrete-event simulator).
//!
//! [`run`] is the classic blocking surface, kept for one-shot callers:
//! it is a thin submit-and-wait over the handle-based API in
//! [`crate::mapreduce::session`].  Callers that want several invocations
//! in flight on one engine use [`crate::mapreduce::Session`] directly —
//! that is how [`crate::mapreduce::multilevel`] fans a hierarchy out
//! concurrently.
//!
//! # Overlapped reduce (`--overlap=true`, DESIGN.md §4)
//!
//! The classic path barriers the single reduce task on the *whole* map
//! array job (step 3).  The overlapped path instead submits one
//! partial-reduce task per mapper task with a task-granularity dependency
//! ([`crate::scheduler::JobSpec::after_tasks`]): each partial folds its
//! mapper task's outputs the moment that task lands, so reducer
//! consumption overlaps the remaining map work, and a final cheap merge
//! over the partials directory produces the same result for associative
//! reducers (for pure concatenation, record order follows task grouping
//! rather than global filename order — identical under block
//! distribution, interleaved under cyclic).  On engines that dispatch in
//! the background this cuts makespan and raises utilization; engines may
//! also run it conservatively barriered.  The flag is ignored — falling
//! back to the barrier — whenever overlap could change *what* is
//! reduced: no reducer, `--subdir`, or a reducer without partial support
//! (see [`crate::apps::ReduceApp::supports_partial`]).  The partials
//! staging directory is `<output>/.partials.<pid>` — pid-suffixed, so
//! concurrent invocations sharing an output directory keep separate
//! scratch.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use crate::apps::{MapApp, ReduceApp};
use crate::error::Result;
use crate::mapreduce::planner::Plan;
use crate::mapreduce::session::Session;
use crate::options::Options;
use crate::scheduler::Engine;

/// Result of one LLMapReduce invocation.
#[derive(Debug)]
pub struct MapReduceReport {
    /// The mapper array job's report.
    pub map: crate::scheduler::JobReport,
    /// The partial-reduce job's report (overlapped mode only).
    pub partials: Option<crate::scheduler::JobReport>,
    /// The (final) reducer job's report, when a reducer was given.
    pub reduce: Option<crate::scheduler::JobReport>,
    /// The plan that produced the jobs.
    pub plan: Plan,
    /// Where the reduce output was written (if reducing).
    pub redout_path: Option<PathBuf>,
    /// The kept `.MAPRED.PID` directory (only with `--keep`).
    pub mapred_dir: Option<PathBuf>,
    /// Whether the overlapped map→reduce path ran.
    pub overlapped: bool,
    /// End-to-end elapsed time of the whole invocation.  Wall-clock
    /// engines report the span the chain's jobs cover — the longest job
    /// makespan, i.e. submission to last completion (jobs overlap, so
    /// summing per-job makespans would double-count); virtual engines
    /// report the sum of job makespans (the simulator serializes chained
    /// jobs, so the sum *is* its chain elapsed).
    pub total_elapsed: Duration,
}

impl MapReduceReport {
    /// End-to-end elapsed (virtual or wall) time of the invocation.
    pub fn elapsed(&self) -> Duration {
        self.total_elapsed
    }

    /// Fraction of slot-time spent in application work (startup +
    /// compute) across all jobs of the invocation.  With everything else
    /// equal, the overlapped path shows higher utilization than the
    /// barriered one: reduce work fills slots the barrier left idle.
    pub fn utilization(&self) -> f64 {
        let slots = self.map.slots.max(1);
        if self.total_elapsed.is_zero() {
            return 0.0;
        }
        let mut busy = self.map.total_startup() + self.map.total_compute();
        for r in self.partials.iter().chain(self.reduce.iter()) {
            busy += r.total_startup() + r.total_compute();
        }
        (busy.as_secs_f64()
            / (self.total_elapsed.as_secs_f64() * slots as f64))
            .min(1.0)
    }
}

/// The applications an invocation binds to.  The paper resolves mapper /
/// reducer names to executables on disk; this API accepts the executable
/// objects directly (the CLI layer does the name resolution).
pub struct Apps {
    pub mapper: Arc<dyn MapApp>,
    pub reducer: Option<Arc<dyn ReduceApp>>,
}

/// Run one complete LLMapReduce invocation on `engine`, blocking until
/// it finishes — submit-and-wait over the handle API
/// ([`Session::submit`] / [`crate::mapreduce::Invocation::wait`]).
pub fn run(
    opts: &Options,
    apps: &Apps,
    engine: &dyn Engine,
) -> Result<MapReduceReport> {
    Session::new(engine).submit(opts, apps)?.wait()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::testutil::{ConcatReducer, CountingApp};
    use crate::options::AppType;
    use crate::scheduler::local::LocalEngine;
    use crate::scheduler::sim::{ClusterConfig, SimEngine};
    use std::fs;
    use std::sync::atomic::Ordering;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-pipe-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn setup(tag: &str, nfiles: usize) -> (PathBuf, PathBuf) {
        let root = tmp(tag);
        let input = root.join("input");
        let output = root.join("output");
        fs::create_dir_all(&input).unwrap();
        for i in 0..nfiles {
            fs::write(input.join(format!("f{i:02}.txt")), format!("{i}\n"))
                .unwrap();
        }
        (input, output)
    }

    #[test]
    fn map_only_local() {
        let (input, output) = setup("maponly", 6);
        let opts = Options::new(&input, &output, "counting-app")
            .np(2)
            .pid(90001);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: None,
        };
        let eng = LocalEngine::new(2);
        let report = run(&opts, &apps, &eng).unwrap();
        assert_eq!(report.plan.tasks.len(), 2);
        assert_eq!(report.map.total_items(), 6);
        assert!(report.reduce.is_none());
        // All outputs exist with paper naming.
        for i in 0..6 {
            assert!(output.join(format!("f{i:02}.txt.out")).is_file());
        }
    }

    #[test]
    fn map_reduce_end_to_end_fig1() {
        let (input, output) = setup("fig1", 4);
        let opts = Options::new(&input, &output, "counting-app")
            .np(2)
            .reducer("concat-reducer")
            .pid(90002);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: Some(Arc::new(ConcatReducer)),
        };
        let eng = LocalEngine::new(2);
        let report = run(&opts, &apps, &eng).unwrap();
        let redout = report.redout_path.clone().unwrap();
        assert!(redout.ends_with("llmapreduce.out"));
        let merged = fs::read_to_string(&redout).unwrap();
        assert_eq!(merged.matches("#mapped").count(), 4);
        assert!(report.reduce.is_some());
    }

    #[test]
    fn mimo_reduces_launches() {
        let (input, output) = setup("mimo", 8);
        let app = Arc::new(CountingApp::new());
        let opts = Options::new(&input, &output, "counting-app")
            .np(2)
            .apptype(AppType::Mimo)
            .pid(90003);
        let apps = Apps {
            mapper: app.clone(),
            reducer: None,
        };
        let eng = LocalEngine::new(2);
        let report = run(&opts, &apps, &eng).unwrap();
        assert_eq!(report.map.total_launches(), 2);
        assert_eq!(app.startups.load(Ordering::SeqCst), 2);
        assert_eq!(report.map.total_items(), 8);
    }

    #[test]
    fn keep_preserves_mapred_dir() {
        let (input, output) = setup("keep", 2);
        let opts = Options::new(&input, &output, "counting-app")
            .keep(true)
            .pid(90004);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: None,
        };
        let eng = LocalEngine::new(1);
        let report = run(&opts, &apps, &eng).unwrap();
        let wd = report.mapred_dir.clone().unwrap();
        assert!(wd.ends_with(".MAPRED.90004"));
        assert!(wd.join("submit.sh").is_file());
        assert!(wd.join("run_llmap_1").is_file());
        fs::remove_dir_all(wd).unwrap();
    }

    #[test]
    fn default_cleanup_removes_mapred_dir() {
        let (input, output) = setup("clean", 2);
        let opts =
            Options::new(&input, &output, "counting-app").pid(90005);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: None,
        };
        let eng = LocalEngine::new(1);
        let report = run(&opts, &apps, &eng).unwrap();
        assert!(report.mapred_dir.is_none());
        let cwd = std::env::current_dir().unwrap();
        assert!(!cwd.join(".MAPRED.90005").exists());
    }

    #[test]
    fn sim_engine_executes_same_pipeline() {
        let (input, output) = setup("simexec", 6);
        let opts = Options::new(&input, &output, "counting-app")
            .np(3)
            .reducer("concat-reducer")
            .pid(90006);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: Some(Arc::new(ConcatReducer)),
        };
        let eng =
            SimEngine::new(ClusterConfig::with_width(3)).execute_payloads(true);
        let report = run(&opts, &apps, &eng).unwrap();
        // Virtual makespan is deterministic and real outputs exist.
        assert!(report.map.makespan > std::time::Duration::ZERO);
        let merged =
            fs::read_to_string(report.redout_path.unwrap()).unwrap();
        assert_eq!(merged.matches("#mapped").count(), 6);
    }

    #[test]
    fn overlapped_reduce_end_to_end() {
        let (input, output) = setup("overlap", 6);
        let opts = Options::new(&input, &output, "counting-app")
            .np(3)
            .reducer("concat-reducer")
            .overlap(true)
            .pid(90008);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: Some(Arc::new(ConcatReducer)),
        };
        let eng = LocalEngine::new(2);
        let report = run(&opts, &apps, &eng).unwrap();
        assert!(report.overlapped);
        let partials = report.partials.as_ref().unwrap();
        assert_eq!(partials.tasks.len(), 3, "one partial per map task");
        // Same final answer as the barriered path.
        let merged =
            fs::read_to_string(report.redout_path.clone().unwrap())
                .unwrap();
        assert_eq!(merged.matches("#mapped").count(), 6);
        // Staging directory is scratch: cleaned up without --keep.
        assert!(!output.join(".partials.90008").exists());
        assert!(report.utilization() > 0.0);
        assert!(report.elapsed() > std::time::Duration::ZERO);
    }

    #[test]
    fn overlapped_reduce_correct_on_conservative_sim_engine() {
        let (input, output) = setup("overlapsim", 4);
        let opts = Options::new(&input, &output, "counting-app")
            .np(2)
            .reducer("concat-reducer")
            .overlap(true)
            .pid(90009);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: Some(Arc::new(ConcatReducer)),
        };
        let eng = SimEngine::new(ClusterConfig::with_width(2))
            .execute_payloads(true);
        let report = run(&opts, &apps, &eng).unwrap();
        let merged =
            fs::read_to_string(report.redout_path.unwrap()).unwrap();
        assert_eq!(merged.matches("#mapped").count(), 4);
        assert!(report.total_elapsed > std::time::Duration::ZERO);
    }

    #[test]
    fn overlap_without_reducer_is_a_noop() {
        let (input, output) = setup("overlapnop", 2);
        let opts = Options::new(&input, &output, "counting-app")
            .overlap(true)
            .pid(90010);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: None,
        };
        let eng = LocalEngine::new(1);
        let report = run(&opts, &apps, &eng).unwrap();
        assert!(!report.overlapped);
        assert!(report.partials.is_none());
        assert!(!output.join(".partials.90010").exists());
    }

    #[test]
    fn subdir_pipeline_replicates() {
        let root = tmp("subdirpipe");
        let input = root.join("input");
        let output = root.join("output");
        fs::create_dir_all(input.join("a/b")).unwrap();
        fs::write(input.join("a/x.txt"), "x").unwrap();
        fs::write(input.join("a/b/y.txt"), "y").unwrap();
        let opts = Options::new(&input, &output, "counting-app")
            .subdir(true)
            .pid(90007);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: None,
        };
        let eng = LocalEngine::new(1);
        run(&opts, &apps, &eng).unwrap();
        assert!(output.join("a/x.txt.out").is_file());
        assert!(output.join("a/b/y.txt.out").is_file());
    }
}
