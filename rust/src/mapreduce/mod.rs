//! The LLMapReduce coordinator — the paper's system contribution.
//!
//! * [`planner`] / [`distribution`] — files × `--np`/`--ndata` →
//!   balanced per-task assignments (block or cyclic);
//! * [`session`] — the handle-based invocation API:
//!   [`Session::submit`] returns an [`Invocation`] before anything
//!   executes, so N invocations share one engine concurrently;
//! * [`pipeline`] — the Fig 1 flow (scan → array job → dependent
//!   reducer) as a blocking submit-and-wait wrapper over [`session`];
//! * [`mimo`] — the SISO→MIMO morph that gives the paper its headline;
//! * [`resume`] — crash recovery: fold the append-only journal back
//!   into per-task state, re-run only what never finished, drain the
//!   dead-letter queue;
//! * [`subdir`] — `--subdir` output-tree replication;
//! * [`multilevel`] — nested LLMapReduce over directory hierarchies,
//!   fanning every subdirectory pipeline out concurrently.

pub mod distribution;
pub mod mimo;
pub mod multilevel;
pub mod pipeline;
pub mod planner;
pub mod resume;
pub mod session;
pub mod subdir;

pub use multilevel::{run_nested, run_nested_depth, MultiLevelReport};
pub use pipeline::{run, Apps, MapReduceReport};
pub use planner::{plan, Plan, PlannedTask};
pub use resume::{dlq_reprocess, resume};
pub use session::{Invocation, InvocationStatus, Session};
