//! The LLMapReduce coordinator — the paper's system contribution.
//!
//! * [`planner`] / [`distribution`] — files × `--np`/`--ndata` →
//!   balanced per-task assignments (block or cyclic);
//! * [`pipeline`] — the Fig 1 flow: scan → array job → dependent reducer;
//! * [`mimo`] — the SISO→MIMO morph that gives the paper its headline;
//! * [`subdir`] — `--subdir` output-tree replication;
//! * [`multilevel`] — nested LLMapReduce over directory hierarchies.

pub mod distribution;
pub mod mimo;
pub mod multilevel;
pub mod pipeline;
pub mod planner;
pub mod subdir;

pub use pipeline::{run, Apps, MapReduceReport};
pub use planner::{plan, Plan, PlannedTask};
