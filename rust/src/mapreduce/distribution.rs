//! File-to-task distribution: block and cyclic (§II, `--distribution`).
//!
//! "Workloads can be distributed in a block or cyclic fashion to improve
//! initial load balancing."  Block gives each task a contiguous run of the
//! (sorted) input list; cyclic deals files round-robin — better when file
//! sizes correlate with their position in the listing.

use crate::options::Distribution;

/// Assign `nfiles` file indices to `ntasks` tasks.
///
/// Returns one `Vec<usize>` of file indices per task.  Invariants (the
/// property tests in `rust/tests/` re-check these over random shapes):
///
/// * every index in `0..nfiles` appears exactly once across all tasks;
/// * task sizes differ by at most one;
/// * block assignments are contiguous and ordered; cyclic assignments
///   have stride `ntasks`.
pub fn distribute(
    nfiles: usize,
    ntasks: usize,
    dist: Distribution,
) -> Vec<Vec<usize>> {
    assert!(ntasks > 0, "ntasks must be positive");
    match dist {
        Distribution::Block => block(nfiles, ntasks),
        Distribution::Cyclic => cyclic(nfiles, ntasks),
    }
}

/// Contiguous blocks: with `r = nfiles % ntasks`, the first `r` tasks get
/// `ceil(nfiles/ntasks)` files, the rest get `floor(...)` — "The block
/// size is determined by LLMapReduce" (§III-A).
fn block(nfiles: usize, ntasks: usize) -> Vec<Vec<usize>> {
    let base = nfiles / ntasks;
    let rem = nfiles % ntasks;
    let mut out = Vec::with_capacity(ntasks);
    let mut next = 0usize;
    for t in 0..ntasks {
        let size = base + usize::from(t < rem);
        out.push((next..next + size).collect());
        next += size;
    }
    debug_assert_eq!(next, nfiles);
    out
}

/// Round-robin: file `i` goes to task `i % ntasks` (Fig 15's
/// `--distribution cyclic`).
fn cyclic(nfiles: usize, ntasks: usize) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::with_capacity(nfiles.div_ceil(ntasks)); ntasks];
    for i in 0..nfiles {
        out[i % ntasks].push(i);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flatten_sorted(assign: &[Vec<usize>]) -> Vec<usize> {
        let mut all: Vec<usize> =
            assign.iter().flatten().copied().collect();
        all.sort_unstable();
        all
    }

    #[test]
    fn block_contiguous_and_balanced() {
        let a = distribute(10, 3, Distribution::Block);
        assert_eq!(a, vec![vec![0, 1, 2, 3], vec![4, 5, 6], vec![7, 8, 9]]);
    }

    #[test]
    fn cyclic_round_robin() {
        let a = distribute(7, 3, Distribution::Cyclic);
        assert_eq!(a, vec![vec![0, 3, 6], vec![1, 4], vec![2, 5]]);
    }

    #[test]
    fn exact_division_equal_sizes() {
        for dist in [Distribution::Block, Distribution::Cyclic] {
            let a = distribute(12, 4, dist);
            assert!(a.iter().all(|t| t.len() == 3), "{dist:?}");
        }
    }

    #[test]
    fn partition_complete_and_disjoint() {
        for dist in [Distribution::Block, Distribution::Cyclic] {
            for (n, t) in [(0, 1), (1, 1), (5, 8), (512, 256), (43_580, 256)] {
                let a = distribute(n, t, dist);
                assert_eq!(a.len(), t);
                assert_eq!(
                    flatten_sorted(&a),
                    (0..n).collect::<Vec<_>>(),
                    "{dist:?} n={n} t={t}"
                );
            }
        }
    }

    #[test]
    fn sizes_differ_by_at_most_one() {
        for dist in [Distribution::Block, Distribution::Cyclic] {
            let a = distribute(43_580, 256, dist);
            let min = a.iter().map(Vec::len).min().unwrap();
            let max = a.iter().map(Vec::len).max().unwrap();
            assert!(max - min <= 1, "{dist:?}: {min}..{max}");
        }
    }

    #[test]
    fn more_tasks_than_files_leaves_empties() {
        let a = distribute(2, 5, Distribution::Block);
        assert_eq!(flatten_sorted(&a), vec![0, 1]);
        assert_eq!(a.iter().filter(|t| t.is_empty()).count(), 3);
    }

    #[test]
    fn block_is_order_preserving() {
        let a = distribute(100, 7, Distribution::Block);
        let flat: Vec<usize> = a.iter().flatten().copied().collect();
        assert_eq!(flat, (0..100).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "ntasks must be positive")]
    fn zero_tasks_panics() {
        distribute(4, 0, Distribution::Block);
    }
}
