//! Multi-level map-reduce (§II): nested LLMapReduce over hierarchies.
//!
//! "Many filesystems operate best when the number of files per directory
//! is less than 10,000.  LLMapReduce users can build a nested call to
//! LLMapReduce for processing whole hierarchies of data."
//!
//! The outer level maps over the immediate subdirectories of the input
//! root — one *inner* LLMapReduce invocation per subdirectory — and an
//! optional outer reducer merges the per-subdirectory reduce outputs.
//! This is the paper's title feature: map-reduce jobs whose mappers are
//! themselves map-reduce jobs.

use std::fs;
use std::path::PathBuf;
use std::sync::Arc;

use crate::apps::ReduceApp;
use crate::error::{Error, IoContext, Result};
use crate::mapreduce::pipeline::{run, Apps, MapReduceReport};
use crate::options::Options;
use crate::scheduler::Engine;

/// Report for a nested invocation.
#[derive(Debug)]
pub struct MultiLevelReport {
    /// (subdirectory name, inner report) per inner invocation.
    pub inner: Vec<(String, MapReduceReport)>,
    /// Path of the final merged output, when an outer reducer ran.
    pub final_out: Option<PathBuf>,
}

impl MultiLevelReport {
    pub fn total_items(&self) -> usize {
        self.inner.iter().map(|(_, r)| r.map.total_items()).sum()
    }

    pub fn elapsed(&self) -> std::time::Duration {
        self.inner.iter().map(|(_, r)| r.elapsed()).sum()
    }
}

/// Run a two-level map-reduce: one inner LLMapReduce per immediate
/// subdirectory of `opts.input`, then `outer_reducer` over the collected
/// inner reduce outputs.
///
/// Each inner invocation inherits all options but gets
/// `input = <subdir>`, `output = <output>/<subdir name>` and a derived
/// pid (`pid*1000 + k`) so the `.MAPRED` directories don't collide.
pub fn run_nested(
    opts: &Options,
    apps: &Apps,
    outer_reducer: Option<Arc<dyn ReduceApp>>,
    engine: &mut dyn Engine,
) -> Result<MultiLevelReport> {
    let mut subdirs: Vec<PathBuf> = fs::read_dir(&opts.input)
        .at(&opts.input)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.is_dir())
        .collect();
    subdirs.sort();
    if subdirs.is_empty() {
        return Err(Error::EmptyInput(opts.input.clone()));
    }

    let base_pid = opts.effective_pid();
    let mut inner_reports = Vec::with_capacity(subdirs.len());
    for (k, sub) in subdirs.iter().enumerate() {
        let name = sub
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or("sub")
            .to_string();
        let inner_opts = Options {
            input: sub.clone(),
            output: opts.output.join(&name),
            pid: Some(base_pid.wrapping_mul(1000).wrapping_add(k as u32 + 1)),
            ..opts.clone()
        };
        let report = run(&inner_opts, apps, engine)?;
        inner_reports.push((name, report));
    }

    // Outer reduce: merge the inner reduce outputs (or, without inner
    // reducers, the union of inner map outputs) into one file.
    let final_out = if let Some(outer) = outer_reducer {
        let collect_dir = opts.output.join(".multilevel");
        fs::create_dir_all(&collect_dir).at(&collect_dir)?;
        for (name, report) in &inner_reports {
            if let Some(redout) = &report.redout_path {
                let dst = collect_dir.join(format!("{name}.part"));
                fs::copy(redout, &dst).at(redout)?;
            }
        }
        let out = opts.output.join(&opts.redout);
        outer.reduce(&collect_dir, &out)?;
        fs::remove_dir_all(&collect_dir).ok();
        Some(out)
    } else {
        None
    };

    Ok(MultiLevelReport {
        inner: inner_reports,
        final_out,
    })
}

/// Run an N-level nested map-reduce: recurse `depth` levels of
/// subdirectories; the innermost level runs the ordinary pipeline over
/// its directory, and every enclosing level merges its children with
/// `outer_reducer` (when given).
///
/// `depth == 0` is a plain [`run`]; `depth == 1` equals [`run_nested`].
/// This is the paper's "whole hierarchies of data" taken literally.
pub fn run_nested_depth(
    opts: &Options,
    apps: &Apps,
    outer_reducer: Option<Arc<dyn ReduceApp>>,
    engine: &mut dyn Engine,
    depth: usize,
) -> Result<MultiLevelReport> {
    if depth <= 1 {
        if depth == 0 {
            let report = run(opts, apps, engine)?;
            let final_out = report.redout_path.clone();
            return Ok(MultiLevelReport {
                inner: vec![("".to_string(), report)],
                final_out,
            });
        }
        return run_nested(opts, apps, outer_reducer, engine);
    }

    let mut subdirs: Vec<PathBuf> = fs::read_dir(&opts.input)
        .at(&opts.input)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.is_dir())
        .collect();
    subdirs.sort();
    if subdirs.is_empty() {
        return Err(Error::EmptyInput(opts.input.clone()));
    }

    let base_pid = opts.effective_pid();
    let mut inner_all = Vec::new();
    let mut child_outs = Vec::new();
    for (k, sub) in subdirs.iter().enumerate() {
        let name = sub
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or("sub")
            .to_string();
        let inner_opts = Options {
            input: sub.clone(),
            output: opts.output.join(&name),
            pid: Some(
                base_pid
                    .wrapping_mul(100)
                    .wrapping_add(depth as u32 * 10 + k as u32 + 1),
            ),
            ..opts.clone()
        };
        let child = run_nested_depth(
            &inner_opts,
            apps,
            outer_reducer.clone(),
            engine,
            depth - 1,
        )?;
        if let Some(out) = &child.final_out {
            child_outs.push((name.clone(), out.clone()));
        }
        for (child_name, r) in child.inner {
            inner_all.push((format!("{name}/{child_name}"), r));
        }
    }

    let final_out = if let Some(outer) = outer_reducer {
        let collect_dir = opts.output.join(".multilevel");
        fs::create_dir_all(&collect_dir).at(&collect_dir)?;
        for (name, out) in &child_outs {
            let dst = collect_dir.join(format!("{name}.part"));
            fs::copy(out, &dst).at(out)?;
        }
        let out = opts.output.join(&opts.redout);
        outer.reduce(&collect_dir, &out)?;
        fs::remove_dir_all(&collect_dir).ok();
        Some(out)
    } else {
        None
    };

    Ok(MultiLevelReport {
        inner: inner_all,
        final_out,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::testutil::{ConcatReducer, CountingApp};
    use crate::scheduler::local::LocalEngine;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-ml-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn setup(tag: &str) -> (PathBuf, PathBuf) {
        let root = tmp(tag);
        let input = root.join("input");
        for (sub, n) in [("sensors-a", 3), ("sensors-b", 2)] {
            let d = input.join(sub);
            fs::create_dir_all(&d).unwrap();
            for i in 0..n {
                fs::write(d.join(format!("{sub}-{i}.txt")), format!("{i}\n"))
                    .unwrap();
            }
        }
        (input, root.join("output"))
    }

    #[test]
    fn nested_runs_one_inner_job_per_subdir() {
        let (input, output) = setup("basic");
        let opts = Options::new(&input, &output, "counting-app")
            .reducer("concat-reducer")
            .pid(70001);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: Some(Arc::new(ConcatReducer)),
        };
        let mut eng = LocalEngine::new(2);
        let report =
            run_nested(&opts, &apps, Some(Arc::new(ConcatReducer)), &mut eng)
                .unwrap();
        assert_eq!(report.inner.len(), 2);
        assert_eq!(report.total_items(), 5);
        // Inner outputs land in per-subdir output dirs.
        assert!(output.join("sensors-a/sensors-a-0.txt.out").is_file());
        assert!(output.join("sensors-b/sensors-b-1.txt.out").is_file());
        // Final merge exists and contains all mapped lines.
        let final_out = report.final_out.unwrap();
        let text = fs::read_to_string(final_out).unwrap();
        assert_eq!(text.matches("#mapped").count(), 5);
    }

    #[test]
    fn nested_without_outer_reducer() {
        let (input, output) = setup("noouter");
        let opts = Options::new(&input, &output, "counting-app").pid(70002);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: None,
        };
        let mut eng = LocalEngine::new(1);
        let report = run_nested(&opts, &apps, None, &mut eng).unwrap();
        assert!(report.final_out.is_none());
        assert_eq!(report.inner.len(), 2);
    }

    #[test]
    fn three_level_hierarchy_merges_to_one_file() {
        // input/site-X/sensor-Y/*.txt, depth 2.
        let root = tmp("deep");
        let input = root.join("input");
        for site in ["site-a", "site-b"] {
            for sensor in ["s1", "s2"] {
                let d = input.join(site).join(sensor);
                fs::create_dir_all(&d).unwrap();
                for i in 0..2 {
                    fs::write(
                        d.join(format!("{site}-{sensor}-{i}.txt")),
                        format!("{i}\n"),
                    )
                    .unwrap();
                }
            }
        }
        let opts = Options::new(&input, root.join("output"), "counting-app")
            .reducer("concat-reducer")
            .pid(70010);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: Some(Arc::new(ConcatReducer)),
        };
        let mut eng = LocalEngine::new(2);
        let report = run_nested_depth(
            &opts,
            &apps,
            Some(Arc::new(ConcatReducer)),
            &mut eng,
            2,
        )
        .unwrap();
        assert_eq!(report.inner.len(), 4, "2 sites x 2 sensors");
        assert_eq!(report.total_items(), 8);
        let final_out = report.final_out.unwrap();
        let text = fs::read_to_string(&final_out).unwrap();
        assert_eq!(text.matches("#mapped").count(), 8);
        // Inner names carry the hierarchy path.
        assert!(report.inner.iter().any(|(n, _)| n == "site-a/s1"));
    }

    #[test]
    fn depth_zero_is_plain_run() {
        let root = tmp("flat0");
        let input = root.join("input");
        fs::create_dir_all(&input).unwrap();
        fs::write(input.join("a.txt"), "a").unwrap();
        let opts =
            Options::new(&input, root.join("out"), "counting-app").pid(70011);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: None,
        };
        let mut eng = LocalEngine::new(1);
        let r =
            run_nested_depth(&opts, &apps, None, &mut eng, 0).unwrap();
        assert_eq!(r.total_items(), 1);
    }

    #[test]
    fn empty_hierarchy_is_error() {
        let root = tmp("empty");
        let input = root.join("input");
        fs::create_dir_all(&input).unwrap();
        let opts = Options::new(&input, root.join("out"), "m").pid(70003);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: None,
        };
        let mut eng = LocalEngine::new(1);
        assert!(run_nested(&opts, &apps, None, &mut eng).is_err());
    }
}
